"""Paper Table 2 / Section 2 analogue: the LeNet case study retold on
smollm-135m.

Three arms, mirroring the paper's Expert / Exhaustive / HIDA columns:

* ``expert``      — a hand-written Megatron-style plan (the layout an HLS
                    expert would write by hand in ~40 hours; here encoded
                    directly),
* ``exhaustive``  — bounded brute-force over axis→dim assignments applied
                    uniformly to all nodes (the paper's 210-hour TCL sweep,
                    bounded by the estimator instead of Vitis runs),
* ``hida``        — the automated pipeline (paper: 9.9 min; ours: <1 s of
                    optimizer time + one XLA compile).

The paper's observations to reproduce: exhaustive ≥ expert, HIDA ≥
exhaustive (HIDA explores per-node dims the uniform sweep cannot), and a
development-cycle gap of orders of magnitude."""
from __future__ import annotations

import itertools
import time

from repro.configs import SHAPES, get_config
from repro.core import SINGLE_POD, build_lm_graph, estimate, optimize
from repro.core.construct import construct_functional
from repro.core.fusion import fuse_tasks
from repro.core.lower import lower_to_structural
from repro.core.balance import balance_paths
from repro.core.multi_producer import eliminate_multi_producers
from repro.core.parallelize import _apply


def _structural(cfg, shape):
    g = build_lm_graph(cfg, shape)
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    eliminate_multi_producers(sched)
    balance_paths(sched)
    return sched


def _apply_uniform(sched, assign, mesh):
    for node in sched.nodes:
        dims = node.loop_dims()
        proposal = {d: a for d, a in assign.items() if d in dims
                    and dims[d] % _axes_size(mesh, a) == 0}
        _apply(node, proposal, mesh)


def _axes_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.size(a)
    return n


def run(report, arch: str = "smollm-135m") -> None:
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    mesh = SINGLE_POD

    # -- expert: hand Megatron plan -------------------------------------------
    t0 = time.perf_counter()
    sched = _structural(cfg, shape)
    expert_assign = {"batch": ("data",), "heads": ("model",),
                     "d_ff": ("model",), "vocab": ("model",)}
    _apply_uniform(sched, expert_assign, mesh)
    expert = estimate(sched, mesh, training=True)
    t_expert = time.perf_counter() - t0

    # -- exhaustive: uniform axis→dim sweep over the same legal space -------------
    # (the paper's TCL sweep also pruned to heuristically-legal points;
    # batch never takes the model axis — see parallelize._DIM_AXIS_PREF)
    from repro.core.parallelize import axis_pref
    t0 = time.perf_counter()
    dims_pool = ["batch", "seq", "heads", "d_head", "d_ff", "d_model",
                 "vocab", None]
    best = None
    tried = 0
    sched_x = _structural(cfg, shape)
    for d_data in dims_pool:
        for d_model_ax in dims_pool:
            assign = {}
            if d_data and "data" in axis_pref(d_data):
                assign[d_data] = ("data",)
            if d_model_ax and "model" in axis_pref(d_model_ax):
                if d_model_ax == d_data:
                    assign[d_model_ax] = ("data", "model")
                else:
                    assign.setdefault(d_model_ax, ())
                    assign[d_model_ax] = assign[d_model_ax] + ("model",)
            _apply_uniform(sched_x, assign, mesh)
            cost = estimate(sched_x, mesh, training=True)
            tried += 1
            if best is None or cost.total_s < best[0].total_s:
                best = (cost, dict(assign))
    exhaustive = best[0]
    t_exhaustive = time.perf_counter() - t0

    # -- hida ----------------------------------------------------------------------
    t0 = time.perf_counter()
    g = build_lm_graph(cfg, shape)
    _, plan, rep = optimize(g, mesh, training=True)
    hida = rep.cost
    t_hida = time.perf_counter() - t0

    report.add(
        f"case_study/{arch}",
        us_per_call=hida.total_s * 1e6,
        derived=(f"expert_ms={expert.total_s*1e3:.2f}(dev={t_expert:.1f}s)|"
                 f"exhaustive_ms={exhaustive.total_s*1e3:.2f}"
                 f"(dev={t_exhaustive:.1f}s,pts={tried})|"
                 f"hida_ms={hida.total_s*1e3:.2f}(dev={t_hida:.1f}s)|"
                 f"hida_vs_expert="
                 f"{expert.total_s/max(hida.total_s,1e-12):.2f}x|"
                 f"hida_vs_exhaustive="
                 f"{exhaustive.total_s/max(hida.total_s,1e-12):.2f}x"))
