"""Paper Fig. 10 analogue: parallel-factor and tile-size sweep.

Sweeps (max parallel factor × scan/attention chunk size) and reports the
estimated step time and the kernel-level VMEM working set per tile (the
TPU counterpart of the paper's BRAM/DSP-vs-tile trade: too-small tiles
starve the MXU and waste bandwidth on block overheads; too-large tiles
overflow VMEM)."""
from __future__ import annotations

from repro.configs import SHAPES, get_config
from repro.core import SINGLE_POD, build_lm_graph, optimize

VMEM_BYTES = 16 * 2 ** 20     # v5e ~16 MiB/core


def _vmem_working_set(chunk: int, d_block: int, n_state: int = 16) -> int:
    # ssd_scan tiles: x, dt (chunk × d_block), B/C (chunk × N), state.
    return 4 * (2 * chunk * d_block + 2 * chunk * n_state
                + d_block * n_state)


def run(report, arch: str = "jamba-v0.1-52b",
        factors=(4, 16, 64, 256), tiles=(32, 128, 512)) -> None:
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    for pf in factors:
        g = build_lm_graph(cfg, shape)
        _, _, rep = optimize(g, SINGLE_POD, training=True,
                             max_parallel_factor=pf)
        for tile in tiles:
            ws = _vmem_working_set(tile, 128)
            fits = ws <= VMEM_BYTES
            report.add(
                f"ablation_scale/{arch}/pf{pf}/tile{tile}",
                us_per_call=rep.cost.total_s * 1e6,
                derived=f"est_t_ms={rep.cost.total_s*1e3:.2f}|"
                        f"hbm={rep.cost.hbm_bytes_per_device/2**30:.2f}GiB|"
                        f"vmem_tile_bytes={ws}|fits_vmem={fits}")
