"""Paper Table 7 analogue: PolyBench kernels as dataflow graphs.

The paper compares HIDA against ScaleHLS/SOFF/Vitis on FPGA throughput.
Here each kernel is (a) optimized by HIDA-OPT vs the three ablation arms
with estimated throughput on the 16×16 mesh, and (b) run for real wall
time on CPU at a reduced size (single device) to anchor the jnp graphs.

Expected qualitative reproduction: multi-loop kernels (2mm/3mm/atax/bicg/
mvt/correlation) gain from dataflow-aware planning; single-loop
``gesummv`` shows parity (paper: 1.00×) because there is nothing to
pipeline.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import (POLYBENCH, POLYBENCH_FNS, evaluate_strategies, timed)


def run(report) -> None:
    rng = np.random.default_rng(0)
    n_small = 256
    for name, builder in POLYBENCH.items():
        res = evaluate_strategies(builder)
        hida = res["hida"]
        naive = res["naive"]
        speedup = naive.total_s / max(hida.total_s, 1e-12)
        wall_us = float("nan")
        if name in POLYBENCH_FNS:
            fn = POLYBENCH_FNS[name]
            n_args = fn.__code__.co_argcount
            args = []
            for i in range(n_args):
                shape = (n_small, n_small) if i < 2 or name in (
                    "2mm", "3mm") else (n_small,)
                if name in ("atax",) and i == 1:
                    shape = (n_small,)
                if name in ("bicg", "mvt", "gesummv") and i >= (
                        1 if name != "gesummv" else 2):
                    shape = (n_small,)
                args.append(jnp.asarray(rng.normal(size=shape),
                                        jnp.float32))
            import jax
            wall_us = timed(jax.jit(fn), *args) * 1e6
        report.add(
            f"polybench/{name}", us_per_call=hida.total_s * 1e6,
            derived=f"est_speedup_vs_naive={speedup:.2f}x|"
                    f"dominant={hida.dominant}|"
                    f"wall_us_n{n_small}={wall_us:.1f}|"
                    f"opt_time_s={hida.opt_time_s:.2f}")
