"""Paper Table 8 analogue: the assigned model zoo under HIDA-OPT vs the
naive (pure-DP) plan — estimated step time, throughput gain, HBM traffic
reduction, and HIDA compile time (the paper's productivity axis: minutes
not hours)."""
from __future__ import annotations

from repro.configs import SHAPES, get_config, list_archs
from repro.core import SINGLE_POD, build_lm_graph, optimize


def run(report, archs=None) -> None:
    shape = SHAPES["train_4k"]
    for arch in (archs or list_archs()):
        cfg = get_config(arch)

        def build():
            return build_lm_graph(cfg, shape)

        g = build()
        sched, plan, rep = optimize(g, SINGLE_POD, training=True)
        g2 = build()
        _, _, rep_naive = optimize(g2, SINGLE_POD, ia=False, ca=False,
                                   training=True)
        repeats = g.meta.repeat_factor
        hida_step = rep.cost.total_s * repeats
        naive_step = rep_naive.cost.total_s * repeats
        tput_gain = naive_step / max(hida_step, 1e-12)
        mem_gain = (rep_naive.cost.hbm_bytes_per_device
                    / max(rep.cost.hbm_bytes_per_device, 1))
        report.add(
            f"models/{arch}", us_per_call=hida_step * 1e6,
            derived=f"est_step_ms={hida_step*1e3:.1f}|"
                    f"tput_vs_naive={tput_gain:.2f}x|"
                    f"hbm_traffic_vs_naive={mem_gain:.2f}x|"
                    f"dominant={rep.cost.dominant}|"
                    f"opt_time_s={rep.compile_time_s:.1f}")
