"""Compiler compile-time benchmark: ``optimize()`` wall-time scaling.

This tracks the performance of the *compiler itself* (not the compiled
designs) PR-over-PR — the DSE is the whole hot path, and the incremental
QoR engine (``repro.core.incremental``) exists to keep it O(Δ) per
proposal.  Methodology:

* Model arms span the node-count axis: smollm-135m (6 nodes) →
  jamba-v0.1-52b (super-block hybrid, the widest graph) →
  deepseek-v3-671b (43 nodes, ~4k proposals — the arm the ≥10× target is
  stated against).  Shape is ``train_4k`` on the SINGLE_POD 16×16 mesh,
  ``training=True`` — the exact configuration of the paper-table runs.
* PolyBench arms cover the small-graph regime where fixed overheads
  (graph construction, connection analysis) dominate.
* Synthetic scale arms (``repro.core.generate``) extend the axis two
  orders of magnitude past the model zoo: ``synth_1k`` always runs,
  ``synth_5k`` runs in the full (non ``--fast``) suite, and
  ``synth_10k`` is opt-in via ``--scale`` — it is the headroom arm, not
  a per-PR gate.
* Each arm reports end-to-end ``optimize()`` seconds plus the DSE
  statistics (nodes, proposals evaluated) so a regression can be
  attributed to enumeration growth vs. per-proposal cost, plus
  ``index_bytes`` — the peak footprint of the compile's indexing layers
  (the fusion session's blocked closure rows + the schedule's cached
  topology), which ``--compare`` gates so closure-row or cache growth
  shows up as a number, not an OOM at 10k nodes.
* Results are also written to ``BENCH_compile_time.json`` (path
  overridable via ``REPRO_BENCH_OUT_DIR``) so the trajectory is diffable
  across PRs.

Regression gate (CI)::

    PYTHONPATH=src python -m benchmarks.bench_compile_time \
        --compare BENCH_compile_time.json [--threshold 2.0] [--fast]

re-runs the suite and exits nonzero when any arm's ``optimize()``
wall-time — or its total pre-DSE structural-pass time (``construct_s +
fuse_s + lower_s + mp_s + balance_s``, the passes on the transactional
rewrite substrate), or the fusion pass ``fuse_s`` alone (the balance
phase's Δ-maintained pair heap over the session's reachability index is
the dominant pre-DSE win, and a regression there must not hide under the
pre-DSE noise floor), or the exit-verifier time ``verify_s`` (the
plan-legality check of ``repro.core.verify`` runs on every ``optimize()``
return and must stay in the low milliseconds), or the exit-analyzer time
``analyze_s`` (the static hazard lint of ``repro.core.analyze``, same
every-compile contract) — exceeds ``threshold ×`` the committed baseline
(arms faster than ``--min-delta-s`` absolute growth are ignored — the
PolyBench arms run in single-digit milliseconds and would otherwise gate
on scheduler noise; the pre-DSE and fuse checks have their own
``PRE_DSE_MIN_DELTA_S`` / ``FUSE_MIN_DELTA_S`` guards).  QoR
(``total_s``) drift is reported alongside and fails the
gate when the estimated schedule got *worse* — compile-time wins must
not be bought with QoR.  Because the default DSE is the hierarchical
two-level search while older baselines were recorded with the flat
whole-schedule beam, these two checks together are the hierarchical
acceptance gate: the hierarchical wall must stay within threshold of
the flat baseline and the hierarchical QoR must never regress past it.
Each arm also reports the per-level split — ``inner_dse_s`` (per-region
inner searches), ``outer_dse_s`` (inter-region composition) and
``regions`` — so a DSE-time regression can be attributed to a level.  In compare mode the fresh results go to a
scratch dir (unless ``REPRO_BENCH_OUT_DIR`` is set) so a failing run
cannot overwrite the committed baseline it is being judged against.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.core import SINGLE_POD, analyze, build_lm_graph, optimize
from repro.core.generate import get_synth

from .common import POLYBENCH

MODEL_ARMS = ("smollm-135m", "jamba-v0.1-52b", "deepseek-v3-671b")
PB_ARMS = ("2mm", "3mm", "atax", "correlation")
#: synthetic scale-stress arms (repro.core.generate): 1k runs always,
#: 5k in the full suite, 10k only with --scale.
SYNTH_ARMS = ("synth_1k", "synth_5k", "synth_10k")


def _time_optimize(graph_builder, training: bool) -> dict:
    g = graph_builder()
    t0 = time.perf_counter()
    sched, _plan, rep = optimize(g, SINGLE_POD, training=training)
    dt = time.perf_counter() - t0
    # The in-pipeline rep.analyze_s rides on whatever GC pressure the
    # previous arms left behind (the invariant rule's from-scratch
    # rebuild allocates enough to trigger gen-2 scans over the whole
    # heap — 2-3x jitter on the synth arms).  Re-measure best-of-3 on
    # the idle analyzer so --compare gates the analyzer, not the heap.
    analyze_s = rep.analyze_s
    for _ in range(3):
        t1 = time.perf_counter()
        analyze(sched, _plan, SINGLE_POD)
        analyze_s = min(analyze_s, time.perf_counter() - t1)
    return {
        "wall_s": dt,
        "plan_s": rep.plan_time_s,
        # Per-pass wall time of the pre-DSE structural passes (all on the
        # transactional rewrite substrate); their sum gates in --compare,
        # and fuse_s additionally gates on its own so a reachability-index
        # regression can't hide under the pre-DSE noise floor.
        "construct_s": rep.construct_s,
        "fuse_s": rep.fuse_s,
        "lower_s": rep.lower_s,
        "mp_s": rep.mp_s,
        "balance_s": rep.balance_s,
        "pre_dse_s": rep.pre_dse_s,
        # Exit plan-legality verification (repro.core.verify) — runs on
        # every optimize() return, so it gates in --compare like fuse_s.
        "verify_s": rep.verify_s,
        # Exit static hazard analysis (repro.core.analyze) — same
        # every-compile contract as verify_s, gated the same way.
        "analyze_s": analyze_s,
        "nodes": len(sched.nodes),
        "evaluated": rep.parallelize.evaluated,
        "rejected_constraint": rep.parallelize.rejected_constraint,
        # Two-level DSE split (repro.core.parallelize): wall time of the
        # per-region inner searches vs. the inter-region composition, and
        # how many regions the partitioner produced (1 = flat path).
        "inner_dse_s": rep.inner_dse_s,
        "outer_dse_s": rep.outer_dse_s,
        "regions": rep.regions,
        # Peak indexing-layer footprint (fusion-session region indexes +
        # cached schedule topology) — gated by --compare like wall_s.
        "index_bytes": rep.index_bytes,
        "total_s": rep.cost.total_s,
    }


def run(report, archs=None, fast: bool = False,
        scale: bool = False) -> dict:
    # --fast skips the slower model-zoo arms (matching the other suites);
    # the full run keeps deepseek-v3-671b, the arm the 10x target tracks.
    archs = archs or (MODEL_ARMS[:2] if fast else MODEL_ARMS)
    results: dict[str, dict] = {}
    for arch in archs:
        cfg = get_config(arch)
        shape = SHAPES["train_4k"]
        r = _time_optimize(lambda: build_lm_graph(cfg, shape), training=True)
        results[f"model/{arch}"] = r
        report.add(f"compile_time/{arch}", us_per_call=r["wall_s"] * 1e6,
                   derived=f"nodes={r['nodes']}|evaluated={r['evaluated']}"
                           f"|plan_ms={r['plan_s'] * 1e3:.3f}"
                           f"|pre_dse_ms={r['pre_dse_s'] * 1e3:.3f}"
                           f"|regions={r['regions']}"
                           f"|inner_ms={r['inner_dse_s'] * 1e3:.3f}"
                           f"|outer_ms={r['outer_dse_s'] * 1e3:.3f}")
    for name in (PB_ARMS[:2] if fast else PB_ARMS):
        r = _time_optimize(POLYBENCH[name], training=False)
        results[f"polybench/{name}"] = r
        report.add(f"compile_time/pb_{name}", us_per_call=r["wall_s"] * 1e6,
                   derived=f"nodes={r['nodes']}|evaluated={r['evaluated']}"
                           f"|plan_ms={r['plan_s'] * 1e3:.3f}"
                           f"|pre_dse_ms={r['pre_dse_s'] * 1e3:.3f}"
                           f"|regions={r['regions']}"
                           f"|inner_ms={r['inner_dse_s'] * 1e3:.3f}"
                           f"|outer_ms={r['outer_dse_s'] * 1e3:.3f}")
    synths = (SYNTH_ARMS[:1] if fast
              else SYNTH_ARMS if scale else SYNTH_ARMS[:2])
    for name in synths:
        r = _time_optimize(lambda: get_synth(name), training=True)
        results[f"synth/{name}"] = r
        report.add(f"compile_time/{name}", us_per_call=r["wall_s"] * 1e6,
                   derived=f"nodes={r['nodes']}|evaluated={r['evaluated']}"
                           f"|pre_dse_ms={r['pre_dse_s'] * 1e3:.3f}"
                           f"|regions={r['regions']}"
                           f"|index_kb={r['index_bytes'] / 1024:.1f}")

    out_dir = Path(os.environ.get("REPRO_BENCH_OUT_DIR", "."))
    out = out_dir / "BENCH_compile_time.json"
    try:
        out.write_text(json.dumps(results, indent=2, sort_keys=True))
    except OSError as e:  # read-only CWD: keep the CSV rows, note the miss
        report.add("compile_time/json_write_failed", 0.0, derived=str(e))
    return results


#: absolute growth below this many seconds never gates the pre-DSE check
#: (the structural passes run in single-digit milliseconds; a 2x ratio of
#: noise is still noise).
PRE_DSE_MIN_DELTA_S = 0.05

#: absolute growth below this many seconds never gates the fuse_s check.
#: Fusion now runs in the low tens of milliseconds on the largest arm
#: (the incremental reachability index); this guard keeps millisecond
#: jitter from gating while still catching a slide back toward the old
#: ~0.3 s O(n²·DFS) balance phase.
FUSE_MIN_DELTA_S = 0.02

#: absolute growth below this many seconds never gates the verify_s
#: check.  The exit verifier runs in ~1–3 ms on every arm today; the
#: guard keeps sub-millisecond jitter from gating while catching any
#: future check family that makes verification a per-compile tax.
VERIFY_MIN_DELTA_S = 0.02

#: absolute growth below this many seconds never gates the analyze_s
#: check.  The exit hazard analyzer runs well under 10 ms on every
#: model/PolyBench arm (the synth arms pay the invariant family's
#: from-scratch topology rebuild, tens of ms); same role as
#: VERIFY_MIN_DELTA_S — a new rule must not become a per-compile tax.
ANALYZE_MIN_DELTA_S = 0.02

#: absolute growth below this many bytes never gates the index_bytes
#: check (the small model/PolyBench arms hold a few KB of index; a 2x
#: ratio there is noise-of-representation, not a leak).  64 KiB of real
#: growth on an unchanged arm is a closure-row / cache regression.
INDEX_BYTES_MIN_DELTA = 64 * 1024


def compare(results: dict, baseline: dict, threshold: float,
            min_delta_s: float, qor_tolerance: float = 1e-3,
            allow_missing: bool = False) -> list[str]:
    """Diff a fresh run against a committed baseline.  Returns the list
    of failure strings (empty = gate passes).  Baseline arms that were
    not re-run fail the gate unless ``allow_missing`` — otherwise a
    ``--fast`` invocation would silently exempt the slowest arms (the
    very ones the gate exists for)."""
    failures: list[str] = []
    for arm in sorted(set(results) & set(baseline)):
        new, old = results[arm], baseline[arm]
        ratio = new["wall_s"] / old["wall_s"] if old["wall_s"] else float("inf")
        # plan_s is reported (plan derivation is delta-projected and should
        # stay in the low milliseconds) but only wall_s/pre_dse_s/total_s
        # gate.
        plan = ""
        if "plan_s" in new:
            plan = (f", plan {old['plan_s']*1e3:.2f}ms -> " if "plan_s" in old
                    else ", plan ") + f"{new['plan_s']*1e3:.2f}ms"
        pre = ""
        if "pre_dse_s" in new:
            pre = (f", pre-dse {old['pre_dse_s']*1e3:.2f}ms -> "
                   if "pre_dse_s" in old else ", pre-dse ") \
                  + f"{new['pre_dse_s']*1e3:.2f}ms"
        fuse = ""
        if "fuse_s" in new:
            fuse = (f", fuse {old['fuse_s']*1e3:.2f}ms -> "
                    if "fuse_s" in old else ", fuse ") \
                   + f"{new['fuse_s']*1e3:.2f}ms"
        ver = ""
        if "verify_s" in new:
            ver = (f", verify {old['verify_s']*1e3:.2f}ms -> "
                   if "verify_s" in old else ", verify ") \
                  + f"{new['verify_s']*1e3:.2f}ms"
        ana = ""
        if "analyze_s" in new:
            ana = (f", analyze {old['analyze_s']*1e3:.2f}ms -> "
                   if "analyze_s" in old else ", analyze ") \
                  + f"{new['analyze_s']*1e3:.2f}ms"
        dse = ""
        if "regions" in new:
            dse = (f", dse r={new['regions']} "
                   f"inner {new['inner_dse_s']*1e3:.1f}ms "
                   f"outer {new['outer_dse_s']*1e3:.1f}ms")
        print(f"{arm}: wall {old['wall_s']:.3f}s -> {new['wall_s']:.3f}s "
              f"({ratio:.2f}x), qor {old['total_s']*1e3:.3f}ms -> "
              f"{new['total_s']*1e3:.3f}ms{plan}{pre}{fuse}{ver}{ana}{dse}")
        if (ratio > threshold
                and new["wall_s"] - old["wall_s"] > min_delta_s):
            failures.append(
                f"{arm}: optimize() wall-time {new['wall_s']:.3f}s is "
                f"{ratio:.2f}x the baseline {old['wall_s']:.3f}s "
                f"(threshold {threshold:.2f}x)")
        # Total pre-DSE structural-pass time gates too: the transactional
        # rewrite layer must not buy its invariants with compile time.
        if "pre_dse_s" in new and "pre_dse_s" in old:
            pre_ratio = (new["pre_dse_s"] / old["pre_dse_s"]
                         if old["pre_dse_s"] else float("inf"))
            if (pre_ratio > threshold
                    and new["pre_dse_s"] - old["pre_dse_s"]
                    > PRE_DSE_MIN_DELTA_S):
                failures.append(
                    f"{arm}: pre-DSE pass time {new['pre_dse_s']*1e3:.2f}ms "
                    f"is {pre_ratio:.2f}x the baseline "
                    f"{old['pre_dse_s']*1e3:.2f}ms (threshold "
                    f"{threshold:.2f}x)")
        # fuse_s gates on its own: the balance-phase pair heap + the
        # session's reachability index hold the dominant pre-DSE win, and
        # a regression there could hide under PRE_DSE_MIN_DELTA_S.
        if "fuse_s" in new and "fuse_s" in old:
            fuse_ratio = (new["fuse_s"] / old["fuse_s"]
                          if old["fuse_s"] else float("inf"))
            if (fuse_ratio > threshold
                    and new["fuse_s"] - old["fuse_s"] > FUSE_MIN_DELTA_S):
                failures.append(
                    f"{arm}: fusion pass time {new['fuse_s']*1e3:.2f}ms is "
                    f"{fuse_ratio:.2f}x the baseline "
                    f"{old['fuse_s']*1e3:.2f}ms (threshold {threshold:.2f}x)"
                    f" — reachability-index / pair-heap regression?")
        # verify_s gates on its own: the exit legality check runs on
        # every compile, so it must stay O(schedule), not O(search).
        if "verify_s" in new and "verify_s" in old:
            ver_ratio = (new["verify_s"] / old["verify_s"]
                         if old["verify_s"] else float("inf"))
            if (ver_ratio > threshold
                    and new["verify_s"] - old["verify_s"]
                    > VERIFY_MIN_DELTA_S):
                failures.append(
                    f"{arm}: exit-verify time {new['verify_s']*1e3:.2f}ms "
                    f"is {ver_ratio:.2f}x the baseline "
                    f"{old['verify_s']*1e3:.2f}ms (threshold "
                    f"{threshold:.2f}x)")
        # analyze_s gates like verify_s: the hazard lint runs on every
        # compile, so a rule that grows past O(schedule) shows up here.
        if "analyze_s" in new and "analyze_s" in old:
            ana_ratio = (new["analyze_s"] / old["analyze_s"]
                         if old["analyze_s"] else float("inf"))
            if (ana_ratio > threshold
                    and new["analyze_s"] - old["analyze_s"]
                    > ANALYZE_MIN_DELTA_S):
                failures.append(
                    f"{arm}: exit-analyze time "
                    f"{new['analyze_s']*1e3:.2f}ms is {ana_ratio:.2f}x "
                    f"the baseline {old['analyze_s']*1e3:.2f}ms "
                    f"(threshold {threshold:.2f}x)")
        # Peak index memory gates like wall time: the blocked closure
        # rows and topology caches must stay O(edges), and a
        # representation regression (say, rows going dense again) shows
        # up here long before it shows up as an OOM.
        if "index_bytes" in new and "index_bytes" in old:
            mem_ratio = (new["index_bytes"] / old["index_bytes"]
                         if old["index_bytes"] else float("inf"))
            if (mem_ratio > threshold
                    and new["index_bytes"] - old["index_bytes"]
                    > INDEX_BYTES_MIN_DELTA):
                failures.append(
                    f"{arm}: peak index memory "
                    f"{new['index_bytes'] / 1024:.1f}KiB is "
                    f"{mem_ratio:.2f}x the baseline "
                    f"{old['index_bytes'] / 1024:.1f}KiB (threshold "
                    f"{threshold:.2f}x)")
        if new["total_s"] > old["total_s"] * (1 + qor_tolerance):
            failures.append(
                f"{arm}: QoR regressed — estimated total_s "
                f"{new['total_s']*1e3:.3f}ms vs baseline "
                f"{old['total_s']*1e3:.3f}ms")
    missing = sorted(set(baseline) - set(results))
    if missing:
        if allow_missing:
            print(f"note: baseline arms not re-run: {missing}")
        else:
            failures.append(
                f"baseline arms not re-run: {missing} (drop --fast, or "
                f"pass --allow-missing-arms to gate on a subset)")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="optimize() compile-time benchmark / regression gate")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slower model-zoo arms")
    ap.add_argument("--scale", action="store_true",
                    help="include the synth_10k headroom arm")
    ap.add_argument("--compare", metavar="BASELINE_JSON", default=None,
                    help="diff against a committed BENCH_compile_time.json "
                         "and exit nonzero on regression")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="max allowed wall-time ratio vs baseline")
    ap.add_argument("--min-delta-s", type=float, default=0.25,
                    help="ignore wall-time growth below this many seconds "
                         "(absolute), so millisecond arms don't gate on "
                         "scheduler noise")
    ap.add_argument("--allow-missing-arms", action="store_true",
                    help="gate on the arms actually re-run even if the "
                         "baseline has more (e.g. with --fast); by "
                         "default missing baseline arms fail the gate")
    args = ap.parse_args(argv)

    # In compare mode the baseline must survive the run: run() writes its
    # results to BENCH_compile_time.json, usually the very file being
    # compared against — a failing gate would overwrite the baseline with
    # the regressed numbers and silently pass on the next invocation.
    # Redirect the write to a scratch dir (unless the caller already
    # redirected it) and read the baseline up front.
    baseline = None
    if args.compare is not None:
        baseline = json.loads(Path(args.compare).read_text())
        if "REPRO_BENCH_OUT_DIR" not in os.environ:
            os.environ["REPRO_BENCH_OUT_DIR"] = tempfile.mkdtemp(
                prefix="repro_bench_")

    from .run import Report
    report = Report()
    print("name,us_per_call,derived")
    results = run(report, fast=args.fast, scale=args.scale)
    if baseline is None:
        return 0
    failures = compare(results, baseline, args.threshold, args.min_delta_s,
                       allow_missing=args.allow_missing_arms)
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    if not failures:
        print("compile-time gate: OK", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
