"""Compiler compile-time benchmark: ``optimize()`` wall-time scaling.

This tracks the performance of the *compiler itself* (not the compiled
designs) PR-over-PR — the DSE is the whole hot path, and the incremental
QoR engine (``repro.core.incremental``) exists to keep it O(Δ) per
proposal.  Methodology:

* Model arms span the node-count axis: smollm-135m (6 nodes) →
  jamba-v0.1-52b (super-block hybrid, the widest graph) →
  deepseek-v3-671b (43 nodes, ~4k proposals — the arm the ≥10× target is
  stated against).  Shape is ``train_4k`` on the SINGLE_POD 16×16 mesh,
  ``training=True`` — the exact configuration of the paper-table runs.
* PolyBench arms cover the small-graph regime where fixed overheads
  (graph construction, connection analysis) dominate.
* Each arm reports end-to-end ``optimize()`` seconds plus the DSE
  statistics (nodes, proposals evaluated) so a regression can be
  attributed to enumeration growth vs. per-proposal cost.
* Results are also written to ``BENCH_compile_time.json`` (path
  overridable via ``REPRO_BENCH_OUT_DIR``) so the trajectory is diffable
  across PRs.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.core import SINGLE_POD, build_lm_graph, optimize

from .common import POLYBENCH

MODEL_ARMS = ("smollm-135m", "jamba-v0.1-52b", "deepseek-v3-671b")
PB_ARMS = ("2mm", "3mm", "atax", "correlation")


def _time_optimize(graph_builder, training: bool) -> dict:
    g = graph_builder()
    t0 = time.perf_counter()
    sched, _plan, rep = optimize(g, SINGLE_POD, training=training)
    dt = time.perf_counter() - t0
    return {
        "wall_s": dt,
        "nodes": len(sched.nodes),
        "evaluated": rep.parallelize.evaluated,
        "rejected_constraint": rep.parallelize.rejected_constraint,
        "total_s": rep.cost.total_s,
    }


def run(report, archs=None, fast: bool = False) -> dict:
    # --fast skips the slower model-zoo arms (matching the other suites);
    # the full run keeps deepseek-v3-671b, the arm the 10x target tracks.
    archs = archs or (MODEL_ARMS[:2] if fast else MODEL_ARMS)
    results: dict[str, dict] = {}
    for arch in archs:
        cfg = get_config(arch)
        shape = SHAPES["train_4k"]
        r = _time_optimize(lambda: build_lm_graph(cfg, shape), training=True)
        results[f"model/{arch}"] = r
        report.add(f"compile_time/{arch}", us_per_call=r["wall_s"] * 1e6,
                   derived=f"nodes={r['nodes']}|evaluated={r['evaluated']}")
    for name in (PB_ARMS[:2] if fast else PB_ARMS):
        r = _time_optimize(POLYBENCH[name], training=False)
        results[f"polybench/{name}"] = r
        report.add(f"compile_time/pb_{name}", us_per_call=r["wall_s"] * 1e6,
                   derived=f"nodes={r['nodes']}|evaluated={r['evaluated']}")

    out_dir = Path(os.environ.get("REPRO_BENCH_OUT_DIR", "."))
    out = out_dir / "BENCH_compile_time.json"
    try:
        out.write_text(json.dumps(results, indent=2, sort_keys=True))
    except OSError as e:  # read-only CWD: keep the CSV rows, note the miss
        report.add("compile_time/json_write_failed", 0.0, derived=str(e))
    return results
