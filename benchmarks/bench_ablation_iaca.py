"""Paper Fig. 11 analogue: IA+CA vs IA-only vs CA-only vs naive
parallelization.

Two measurement layers:

1. *Estimator layer* (always): the roofline QoR per arm.  Caveat — the
   naive arm *looks* competitive here, exactly as the paper observes that
   naive factor selection looks fine until the compiler has to implement
   it ("the compiler generates overly-complicated control logics …
   ultimately falling back to flawed designs").
2. *Compiled layer* (when dry-run artifacts exist, or ``--compile`` is
   passed): the real XLA SPMD compile per arm — temp bytes/device and
   collective bytes from the post-SPMD HLO.  This is where the CA-off
   arms collapse: GSPMD "involuntary full rematerialization" inflates
   temp memory by orders of magnitude (measured 2.3 TiB/device on the
   incoherent deepseek-v3 plan vs ~106 GiB coherent).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.core import SINGLE_POD, build_lm_graph, optimize

ARMS = (("hida", True, True), ("ia", True, False),
        ("ca", False, True), ("naive", False, False))
ARTIFACT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _artifact(arch, shape, strategy):
    suffix = "" if strategy == "hida" else f"__{strategy}"
    p = ARTIFACT_DIR / f"{arch}__{shape}__16x16{suffix}.json"
    if p.exists():
        return json.loads(p.read_text())
    return None


def _compile_arm(arch, shape, strategy):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--strategy", strategy],
        env=env, capture_output=True, text=True, timeout=1800)
    return _artifact(arch, shape, strategy)


def run(report, arch: str = "smollm-360m", factors=(4, 16, 64, 256),
        compile_arms: bool = False) -> None:
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]

    # -- estimator sweep over max parallel factor --------------------------------
    for pf in factors:
        row = {}
        for name, ia, ca in ARMS:
            g = build_lm_graph(cfg, shape)
            _, _, rep = optimize(g, SINGLE_POD, ia=ia, ca=ca,
                                 training=True, max_parallel_factor=pf)
            row[name] = rep
        derived = "|".join(
            f"{name}:t={r.cost.total_s*1e3:.2f}ms,"
            f"hbm={r.cost.hbm_bytes_per_device/2**30:.2f}GiB"
            for name, r in row.items())
        report.add(f"ablation_iaca_est/{arch}/pf{pf}",
                   us_per_call=row["hida"].cost.total_s * 1e6,
                   derived=derived)

    # -- compiled reality per arm --------------------------------------------------
    for name, _, _ in ARMS:
        art = _artifact(arch, "train_4k", name)
        if art is None and compile_arms:
            art = _compile_arm(arch, "train_4k", name)
        if art is None or art.get("status") != "ok":
            continue
        mem = art["memory_analysis"]
        temp = mem["temp_size_in_bytes"]
        coll = art["collectives"].get("scaled_total_bytes",
                                      art["collectives"]["total_bytes"])
        report.add(
            f"ablation_iaca_compiled/{arch}/{name}",
            us_per_call=art.get("compile_s", 0.0) * 1e6,
            derived=f"temp_GiB_per_dev={temp/2**30:.2f}|"
                    f"collective_GiB={coll/2**30:.2f}|"
                    f"compile_s={art.get('compile_s', 0):.0f}")
