"""Shared benchmark utilities: PolyBench-style dataflow graphs (the
paper's Table 7 kernels re-expressed as HIDA IR + jnp functions), plan
comparison helpers, and the estimated-throughput metric.

On this CPU-only container the large-scale numbers are roofline
*estimates* cross-checked against compiled-HLO collective bytes; the
PolyBench kernels additionally run for real wall time at reduced sizes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Graph, MeshSpec, SINGLE_POD, estimate, optimize)
from repro.core.ir import AccessMap

# PolyBench LARGE-ish dims (scaled to keep estimator numbers meaningful).
PB_N = 1024


def _g(name):
    return Graph(name)


def build_2mm(n: int = PB_N) -> Graph:
    """D = alpha*A*B*C + beta*D — two chained matmuls (dataflow!)."""
    g = _g("2mm")
    for nm, dims in [("A", ("i", "k")), ("B", ("k", "j")),
                     ("C", ("j", "l")), ("D", ("i", "l"))]:
        g.tensor(nm, (n, n), "f32", dims, is_input=True)
    g.tensor("tmp", (n, n), "f32", ("i", "j"))
    g.tensor("out", (n, n), "f32", ("i", "l"))
    g.op("matmul", ["A", "B"], ["tmp"], {"i": n, "j": n, "k": n},
         flops=2 * n ** 3, name="mm1")
    g.op("matmul", ["tmp", "C", "D"], ["out"], {"i": n, "l": n, "j": n},
         flops=2 * n ** 3, name="mm2")
    g.outputs = ["out"]
    return g


def build_3mm(n: int = PB_N) -> Graph:
    g = _g("3mm")
    for nm, dims in [("A", ("i", "k")), ("B", ("k", "j")),
                     ("C", ("j", "m")), ("D", ("m", "l"))]:
        g.tensor(nm, (n, n), "f32", dims, is_input=True)
    g.tensor("E", (n, n), "f32", ("i", "j"))
    g.tensor("F", (n, n), "f32", ("j", "l"))
    g.tensor("G", (n, n), "f32", ("i", "l"))
    g.op("matmul", ["A", "B"], ["E"], {"i": n, "j": n, "k": n},
         flops=2 * n ** 3, name="mm1")
    g.op("matmul", ["C", "D"], ["F"], {"j": n, "l": n, "m": n},
         flops=2 * n ** 3, name="mm2")
    g.op("matmul", ["E", "F"], ["G"], {"i": n, "l": n, "j": n},
         flops=2 * n ** 3, name="mm3")
    g.outputs = ["G"]
    return g


def build_atax(n: int = PB_N) -> Graph:
    """y = Aᵀ(Ax) — two dependent matvecs."""
    g = _g("atax")
    g.tensor("A", (n, n), "f32", ("i", "j"), is_input=True)
    g.tensor("x", (n,), "f32", ("j",), is_input=True)
    g.tensor("t", (n,), "f32", ("i",))
    g.tensor("y", (n,), "f32", ("j",))
    g.op("matmul", ["A", "x"], ["t"], {"i": n, "j": n}, flops=2 * n * n,
         name="Ax")
    g.op("matmul", ["A", "t"], ["y"], {"j": n, "i": n}, flops=2 * n * n,
         name="Atx",
         access={"A": AccessMap.of(("i", 1), ("j", 1)),
                 "t": AccessMap.of(("i", 1)),
                 "y": AccessMap.of(("j", 1))})
    g.outputs = ["y"]
    return g


def build_bicg(n: int = PB_N) -> Graph:
    g = _g("bicg")
    g.tensor("A", (n, n), "f32", ("i", "j"), is_input=True)
    g.tensor("p", (n,), "f32", ("j",), is_input=True)
    g.tensor("r", (n,), "f32", ("i",), is_input=True)
    g.tensor("q", (n,), "f32", ("i",))
    g.tensor("s", (n,), "f32", ("j",))
    g.op("matmul", ["A", "p"], ["q"], {"i": n, "j": n}, flops=2 * n * n,
         name="Ap")
    g.op("matmul", ["A", "r"], ["s"], {"j": n, "i": n}, flops=2 * n * n,
         name="Atr",
         access={"A": AccessMap.of(("i", 1), ("j", 1)),
                 "r": AccessMap.of(("i", 1)),
                 "s": AccessMap.of(("j", 1))})
    g.outputs = ["q", "s"]
    return g


def build_mvt(n: int = PB_N) -> Graph:
    g = _g("mvt")
    g.tensor("A", (n, n), "f32", ("i", "j"), is_input=True)
    g.tensor("y1", (n,), "f32", ("j",), is_input=True)
    g.tensor("y2", (n,), "f32", ("i",), is_input=True)
    g.tensor("x1", (n,), "f32", ("i",))
    g.tensor("x2", (n,), "f32", ("j",))
    g.op("matmul", ["A", "y1"], ["x1"], {"i": n, "j": n}, flops=2 * n * n,
         name="Ay1")
    g.op("matmul", ["A", "y2"], ["x2"], {"j": n, "i": n}, flops=2 * n * n,
         name="Aty2",
         access={"A": AccessMap.of(("i", 1), ("j", 1)),
                 "y2": AccessMap.of(("i", 1)),
                 "x2": AccessMap.of(("j", 1))})
    g.outputs = ["x1", "x2"]
    return g


def build_gesummv(n: int = PB_N) -> Graph:
    """y = alpha*A*x + beta*B*x — two independent matvecs + combine
    (single-loop class in the paper: no deep dataflow)."""
    g = _g("gesummv")
    g.tensor("A", (n, n), "f32", ("i", "j"), is_input=True)
    g.tensor("B", (n, n), "f32", ("i", "j"), is_input=True)
    g.tensor("x", (n,), "f32", ("j",), is_input=True)
    g.tensor("t1", (n,), "f32", ("i",))
    g.tensor("t2", (n,), "f32", ("i",))
    g.tensor("y", (n,), "f32", ("i",))
    g.op("matmul", ["A", "x"], ["t1"], {"i": n, "j": n}, flops=2 * n * n,
         name="Ax")
    g.op("matmul", ["B", "x"], ["t2"], {"i": n, "j": n}, flops=2 * n * n,
         name="Bx")
    g.op("elementwise", ["t1", "t2"], ["y"], {"i": n}, flops=2 * n,
         name="axpy")
    g.outputs = ["y"]
    return g


def build_correlation(n: int = PB_N) -> Graph:
    g = _g("correlation")
    g.tensor("data", (n, n), "f32", ("i", "j"), is_input=True)
    g.tensor("mean", (n,), "f32", ("j",))
    g.tensor("std", (n,), "f32", ("j",))
    g.tensor("norm", (n, n), "f32", ("i", "j"))
    g.tensor("corr", (n, n), "f32", ("j", "l"))
    g.op("elementwise", ["data"], ["mean"], {"i": n, "j": n}, flops=n * n,
         name="mean", reduce=("i",))
    g.op("elementwise", ["data", "mean"], ["std"], {"i": n, "j": n},
         flops=2 * n * n, name="std", reduce=("i",))
    g.op("elementwise", ["data", "mean", "std"], ["norm"],
         {"i": n, "j": n}, flops=2 * n * n, name="normalize")
    g.op("matmul", ["norm", "norm"], ["corr"], {"j": n, "l": n, "i": n},
         flops=2 * n ** 3, name="gram",
         access={"norm": AccessMap.of(("i", 1), ("j", 1)),
                 "corr": AccessMap.of(("j", 1), ("l", 1))})
    g.outputs = ["corr"]
    return g


POLYBENCH = {
    "2mm": build_2mm, "3mm": build_3mm, "atax": build_atax,
    "bicg": build_bicg, "mvt": build_mvt, "gesummv": build_gesummv,
    "correlation": build_correlation,
}

#: jnp implementations for wall-time micro-runs (reduced n)
POLYBENCH_FNS = {
    "2mm": lambda A, B, C, D: A @ B @ C + D,
    "3mm": lambda A, B, C, D: (A @ B) @ (C @ D),
    "atax": lambda A, x: A.T @ (A @ x),
    "bicg": lambda A, p, r: (A @ p, A.T @ r),
    "mvt": lambda A, y1, y2: (A @ y1, A.T @ y2),
    "gesummv": lambda A, B, x: 1.5 * (A @ x) + 1.2 * (B @ x),
}


@dataclass
class PlanResult:
    name: str
    total_s: float
    critical_s: float
    hbm_bytes: int
    dominant: str
    opt_time_s: float


def evaluate_strategies(graph_builder, mesh: MeshSpec = SINGLE_POD,
                        training: bool = False,
                        strategies=(("hida", True, True),
                                    ("ia", True, False),
                                    ("ca", False, True),
                                    ("naive", False, False)),
                        max_pf: int | None = None) -> dict[str, PlanResult]:
    out = {}
    for name, ia, ca in strategies:
        g = graph_builder()
        sched, plan, rep = optimize(g, mesh, ia=ia, ca=ca,
                                    training=training,
                                    max_parallel_factor=max_pf)
        out[name] = PlanResult(
            name, rep.cost.total_s, rep.cost.critical_s,
            rep.cost.hbm_bytes_per_device, rep.cost.dominant,
            rep.compile_time_s)
    return out


def timed(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters
