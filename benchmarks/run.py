"""Benchmark runner — one suite per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV.  Suites:

* ``case_study``      — Table 2 (expert vs exhaustive vs HIDA)
* ``polybench``       — Table 7 (C++ kernels as dataflow graphs)
* ``models``          — Table 8 (the 10-arch zoo, HIDA vs naive)
* ``ablation_iaca``   — Fig. 11 (IA+CA vs IA vs CA vs naive sweep)
* ``ablation_scale``  — Fig. 10 (parallel factor × tile size)
* ``roofline``        — §Roofline rows from dry-run artifacts (if present)
* ``train_smoke``     — real measured CPU training throughput (smoke cfg)
* ``compile_time``    — ``optimize()`` wall time per config (the compiler's
  own perf trajectory; also emits ``BENCH_compile_time.json``).  Run as
  ``python -m benchmarks.bench_compile_time --compare
  BENCH_compile_time.json`` to use it as a CI gate that exits nonzero on
  a >2× wall-time (or any QoR) regression against the committed baseline.
* ``serve``           — serving path: continuous-batching vs static-wave
  throughput + plan-cache tiers (cold/warm DSE wall, hit fetch time) on
  every zoo config; emits ``BENCH_serve.json`` with its own
  ``--compare`` gate (``python -m benchmarks.bench_serve --compare
  BENCH_serve.json``).
* ``lint``            — the ``python -m repro.lint`` hazard sweep over
  every config + ``synth_1k`` (static dataflow analysis:
  deadlock/FIFO-depth, shard races, write ordering, index invariants),
  plus a ``ruff check`` row when ruff is installed (skipped otherwise —
  the config lives in ``ruff.toml``).  Per-arm ``analyze_s`` is gated
  by ``bench_compile_time --compare`` like ``verify_s``.

``python -m benchmarks.run [--suite NAME] [--fast]``
"""
from __future__ import annotations

import argparse
import sys
import time
from dataclasses import dataclass, field


@dataclass
class Report:
    rows: list = field(default_factory=list)

    def add(self, name: str, us_per_call: float, derived: str = "") -> None:
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def bench_train_smoke(report) -> None:
    import jax
    from repro.launch.train import main as train_main
    t0 = time.perf_counter()
    out = train_main(["--arch", "smollm-135m", "--smoke", "--steps", "12",
                      "--batch", "4", "--seq", "64", "--ckpt-every", "0",
                      "--ckpt-dir", "/tmp/repro_bench_ckpt"])
    dt = time.perf_counter() - t0
    toks = 12 * 4 * 64
    report.add("train_smoke/smollm-135m", us_per_call=dt / 12 * 1e6,
               derived=f"tok_per_s={toks/dt:.0f}|"
                       f"final_loss={out['final_loss']:.3f}")


def bench_lint(report, fast: bool = False) -> None:
    """Hazard-lint every config (the CI lane `python -m repro.lint`
    drives the same code); nonzero findings land in the derived column
    rather than aborting the suite.  Ruff is optional tooling — absent
    in the pinned image — so its row degrades to a skip note."""
    import shutil
    import subprocess

    from repro.configs import list_archs
    from repro.lint import lint_one

    targets = (list_archs()[:3] if fast else list_archs()) + ["synth_1k"]
    for name in targets:
        res = lint_one(name)
        report.add(f"lint/{name}", us_per_call=res["wall_s"] * 1e6,
                   derived=f"ok={res['ok']}|errors={len(res['errors'])}"
                           f"|warnings={len(res['warnings'])}"
                           f"|checks={res['checks']}"
                           f"|analyze_ms={res['analyze_s'] * 1e3:.3f}")
    ruff = shutil.which("ruff")
    if ruff is None:
        report.add("lint/ruff", 0.0,
                   derived="skipped (ruff not installed; see ruff.toml)")
    else:
        t0 = time.perf_counter()
        proc = subprocess.run([ruff, "check", "src", "tests", "benchmarks"],
                              capture_output=True, text=True)
        report.add("lint/ruff",
                   us_per_call=(time.perf_counter() - t0) * 1e6,
                   derived=f"rc={proc.returncode}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=("all", "case_study", "polybench", "models",
                             "ablation_iaca", "ablation_scale", "roofline",
                             "train_smoke", "compile_time", "serve",
                             "lint"))
    ap.add_argument("--fast", action="store_true",
                    help="skip the slower model-zoo arms")
    args = ap.parse_args()

    report = Report()
    print("name,us_per_call,derived")

    want = (lambda s: args.suite in ("all", s))
    if want("case_study"):
        from .bench_case_study import run as r
        r(report)
    if want("polybench"):
        from .bench_kernels_polybench import run as r
        r(report)
    if want("models"):
        from .bench_models import run as r
        archs = (["smollm-135m", "jamba-v0.1-52b", "deepseek-v2-236b"]
                 if args.fast else None)
        r(report, archs=archs)
    if want("ablation_iaca"):
        from .bench_ablation_iaca import run as r
        r(report, factors=(16, 256) if args.fast else (4, 16, 64, 256))
    if want("ablation_scale"):
        from .bench_ablation_scale import run as r
        r(report, factors=(16, 256) if args.fast else (4, 16, 64, 256))
    if want("roofline"):
        from .roofline import run as r
        r(report)
    if want("train_smoke"):
        bench_train_smoke(report)
    if want("compile_time"):
        from .bench_compile_time import run as r
        r(report, fast=args.fast)
    if want("serve"):
        from .bench_serve import run as r
        r(report, fast=args.fast)
    if want("lint"):
        bench_lint(report, fast=args.fast)
    print(f"# {len(report.rows)} benchmark rows", file=sys.stderr)


if __name__ == "__main__":
    main()
