"""§Roofline table generator: reads the dry-run artifacts in
``experiments/dryrun/`` and renders per-(arch × shape × mesh) roofline
terms for EXPERIMENTS.md.

Terms (per the assignment):
  compute    = FLOPs / (chips · 197e12)       [analytic FLOPs: XLA's
               cost analysis counts the layer-scan while body once]
  memory     = HLO bytes / (chips · 819e9)    [scan-scaled]
  collective = collective bytes / (chips · 50e9)  [loop-scaled, per-device
               bytes already, so divided by link BW only]
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.estimator import HBM_BW, ICI_BW, PEAK_FLOPS

ARTIFACT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh: str | None = None, strategy: str = "hida"
               ) -> list[dict]:
    cells = []
    for p in sorted(ARTIFACT_DIR.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("strategy", "hida") != strategy:
            continue
        if mesh and r["mesh"] != mesh:
            continue
        cells.append(r)
    return cells


_MEM_CACHE: dict = {}


def _estimator_mem_bytes(arch: str, shape: str) -> float:
    """Per-device HBM traffic per step from the HIDA model (node bytes ×
    shard factors × layer repeats).  Used for the memory term because the
    compiled 'bytes accessed' counts the layer-scan body once and offers
    no per-computation split to scale it correctly."""
    key = (arch, shape)
    if key not in _MEM_CACHE:
        from repro.configs import SHAPES, get_config
        from repro.core import SINGLE_POD, build_lm_graph, optimize
        cfg = get_config(arch)
        sp = SHAPES[shape]
        g = build_lm_graph(cfg, sp)
        _, _, rep = optimize(g, SINGLE_POD,
                             training=sp.mode == "train")
        mult = 3.0 if sp.mode == "train" else 1.0   # fwd+bwd re-traffic
        _MEM_CACHE[key] = (rep.cost.hbm_bytes_per_device
                           * g.meta.repeat_factor * mult)
    return _MEM_CACHE[key]


def roofline_row(r: dict) -> dict | None:
    if r["status"] != "ok":
        return {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "status": r["status"], "reason": r.get("reason", "")}
    chips = r["chips"]
    loop = r.get("loop_trip", 1)
    flops = r.get("analytic_flops", 0.0)
    mem_bytes = _estimator_mem_bytes(r["arch"], r["shape"])
    coll = r["collectives"].get("scaled_total_bytes",
                                r["collectives"]["total_bytes"])
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = mem_bytes / HBM_BW           # already per-device
    collective_s = coll / ICI_BW            # per-device payload
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dom = max(terms, key=terms.get)
    step_s = max(terms.values())
    model_flops = r.get("model_flops_6nd", 0.0)
    mem = r["memory_analysis"]
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "status": "ok",
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dom,
        "roofline_frac": compute_s / step_s if step_s else 0.0,
        "model_flops": model_flops, "hlo_flops": flops,
        "useful_ratio": model_flops / flops if flops else 0.0,
        "bytes_per_dev": mem["argument_size_in_bytes"]
        + mem["temp_size_in_bytes"],
        "compile_s": r.get("compile_s", 0.0),
    }


def markdown_table(mesh: str = "16x16", strategy: str = "hida") -> str:
    rows = [roofline_row(r) for r in load_cells(mesh, strategy)]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| roofline frac | 6ND/HLO | GiB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r is None:
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"{r['status']}: {r.get('reason','')[:60]} | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['roofline_frac']:.2f} | "
            f"{r['useful_ratio']:.2f} | "
            f"{r['bytes_per_dev']/2**30:.1f} | {r['compile_s']:.0f} |")
    return "\n".join(lines)


def run(report) -> None:
    for r in load_cells():
        row = roofline_row(r)
        if row is None or row["status"] != "ok":
            continue
        report.add(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            us_per_call=max(row["compute_s"], row["memory_s"],
                            row["collective_s"]) * 1e6,
            derived=f"dom={row['dominant']}|frac={row['roofline_frac']:.2f}"
                    f"|useful={row['useful_ratio']:.2f}")


if __name__ == "__main__":
    print(markdown_table())
