"""Serving-path benchmark: continuous batching + the persistent plan
cache (the production serving deliverable).

Two arm families, one JSON (``BENCH_serve.json``):

* ``serve/<arch>`` — steady-state throughput of the continuous batcher
  vs. the lock-step static-wave baseline at the *same* hardware batch
  width, on a mixed-length request trace (the regime continuous
  batching exists for: short requests finish and their slots are
  refilled while long ones keep decoding).  One un-timed warmup pass
  absorbs jit compiles, so the numbers are what a long-lived endpoint
  serves at.  Reported: total and decode-only tok/s, p50/p99 request
  latency, slot occupancy, and the continuous/static ratio.
* ``plan_cache/<arch>`` — the compile-side tiers on every zoo config
  (full, non-smoke): cold DSE wall, cache-hit fetch time (fresh
  :class:`PlanCache` instance, so the disk tier + static re-verify are
  on the measured path), and warm re-DSE wall/QoR seeded from the
  cached assignment snapshot.

Absolute gates (checked in ``--compare`` mode, independent of the
baseline — these are the serving path's acceptance criteria, not
regression bounds):

* continuous ≥ static total tok/s on the mixed-length trace;
* cache-hit plan fetch < 5 ms;
* warm re-DSE wall < cold wall on every config;
* warm QoR never worse than cold.

Baseline-relative gates (vs. the committed ``BENCH_serve.json``):
continuous tok/s must not drop below ``1/threshold ×`` baseline, and
warm wall / fetch time must not grow past ``threshold ×``.

Regression gate (CI)::

    PYTHONPATH=src python -m benchmarks.bench_serve \
        --compare BENCH_serve.json [--threshold 2.0] [--fast]

In compare mode fresh results go to a scratch dir (unless
``REPRO_BENCH_OUT_DIR`` is set) so a failing run cannot overwrite the
baseline it is judged against.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path

from repro.configs import get_config, list_archs
from repro.configs.base import ShapeSpec
from repro.core import (SINGLE_POD, CachedPlan, PlanCache, PlanKey,
                        build_lm_graph, canonical_snapshot, optimize,
                        shape_bucket)

#: serving throughput arms (smoke configs — the arm measures scheduler
#: behaviour, not model FLOPs; MoE archs are static-only by design).
SERVE_ARMS = ("smollm-135m", "xlstm-125m")

#: the serving shape the plan-cache arms compile for.
DECODE_SEQ, DECODE_BATCH = 2048, 16

#: acceptance ceiling for a cache-hit plan fetch (disk tier + static
#: re-verification included).
FETCH_MS_GATE = 5.0


def _bench_serve_arm(arch: str, repeats: int = 3) -> dict:
    from repro.launch.serve import main as serve_main
    args = ["--arch", arch, "--smoke", "--slots", "4",
            "--requests", "24", "--prompt-len-range", "4", "48",
            "--gen-range", "32", "96", "--temperature", "0.0",
            "--seed", "0", "--static", "--no-plan"]
    # every pass carries --warmup: serve_main builds a fresh LM (and so
    # a fresh jit cache) per call, so an unwarmed pass would pay the
    # compiles inside its measured window.  The two paths run
    # back-to-back inside each pass, so a per-pass ratio is controlled
    # for machine-wide noise (CPU contention hits both paths of one
    # pass, not one path of one pass) — keep the best paired pass.
    runs = [serve_main(args + ["--warmup", "1"]) for i in range(repeats)]
    best = max(runs, key=lambda m: m["continuous_vs_static"])
    c, s = best["continuous"], best["static"]
    return {
        "tok_per_s": c["tok_per_s"],
        "decode_tok_per_s": c["decode_tok_per_s"],
        "static_tok_per_s": s["tok_per_s"],
        "ratio_vs_static": best["continuous_vs_static"],
        "latency_p50_s": c["latency_p50_s"],
        "latency_p99_s": c["latency_p99_s"],
        "occupancy": c["occupancy"],
        "requests": c["requests"],
        "generated": c["generated"],
    }


def _bench_plan_cache_arm(arch: str, cache_root: Path,
                          repeats: int = 2) -> dict:
    cfg = get_config(arch)
    bucket = shape_bucket("decode", DECODE_SEQ, DECODE_BATCH)
    shape = ShapeSpec(bucket, DECODE_SEQ, DECODE_BATCH, "decode")
    key = PlanKey.make(cfg, SINGLE_POD, bucket)

    # best-of-N on both walls: a single scheduler hiccup on either side
    # must not decide the warm-faster-than-cold gate.
    cold_wall = float("inf")
    for _ in range(repeats):
        g = build_lm_graph(cfg, shape)
        t0 = time.perf_counter()
        sched, plan, rep_cold = optimize(g, SINGLE_POD, training=False)
        cold_wall = min(cold_wall, time.perf_counter() - t0)

    cache = PlanCache(cache_root)
    cache.put(CachedPlan(key=key, plan=plan,
                         snapshot=canonical_snapshot(sched),
                         qor_total_s=rep_cold.cost.total_s,
                         stored_unix=time.time()))
    # fresh instance: the hit pays JSON parse + plan rebuild + static
    # re-verify, exactly what a restarted server pays.
    fresh = PlanCache(cache_root)
    t0 = time.perf_counter()
    got, vrep = fresh.fetch(key, SINGLE_POD)
    fetch_ms = (time.perf_counter() - t0) * 1e3
    assert got is not None and vrep.ok, f"{arch}: cache hit failed verify"

    warm_wall = float("inf")
    for _ in range(repeats):
        g2 = build_lm_graph(cfg, shape)
        t0 = time.perf_counter()
        _, _, rep_warm = optimize(g2, SINGLE_POD, training=False,
                                  warm_start=got.snapshot)
        warm_wall = min(warm_wall, time.perf_counter() - t0)

    return {
        "nodes": len(sched.nodes),
        "cold_wall_s": cold_wall,
        "warm_wall_s": warm_wall,
        "warm_speedup": cold_wall / warm_wall if warm_wall else float("inf"),
        "fetch_ms": fetch_ms,
        "cold_qor_s": rep_cold.cost.total_s,
        "warm_qor_s": rep_warm.cost.total_s,
        "warm_covered": rep_warm.parallelize.warm_covered,
        "warm_verify_ok": bool(rep_warm.verify.ok),
    }


def run(report, fast: bool = False) -> dict:
    results: dict[str, dict] = {}
    for arch in (SERVE_ARMS[:1] if fast else SERVE_ARMS):
        r = _bench_serve_arm(arch)
        results[f"serve/{arch}"] = r
        report.add(f"serve/{arch}", us_per_call=1e6 / r["tok_per_s"],
                   derived=f"tok_per_s={r['tok_per_s']:.0f}"
                           f"|static={r['static_tok_per_s']:.0f}"
                           f"|ratio={r['ratio_vs_static']:.2f}"
                           f"|p50_ms={r['latency_p50_s'] * 1e3:.0f}"
                           f"|p99_ms={r['latency_p99_s'] * 1e3:.0f}"
                           f"|occ={r['occupancy']:.2f}")
    archs = list_archs()
    if fast:
        archs = archs[:3]
    with tempfile.TemporaryDirectory(prefix="repro_plan_cache_") as td:
        for arch in archs:
            r = _bench_plan_cache_arm(arch, Path(td) / arch)
            results[f"plan_cache/{arch}"] = r
            report.add(f"plan_cache/{arch}",
                       us_per_call=r["warm_wall_s"] * 1e6,
                       derived=f"cold_ms={r['cold_wall_s'] * 1e3:.0f}"
                               f"|warm_ms={r['warm_wall_s'] * 1e3:.0f}"
                               f"|speedup={r['warm_speedup']:.1f}x"
                               f"|fetch_ms={r['fetch_ms']:.2f}"
                               f"|covered={r['warm_covered']}/{r['nodes']}")

    out_dir = Path(os.environ.get("REPRO_BENCH_OUT_DIR", "."))
    out = out_dir / "BENCH_serve.json"
    try:
        out.write_text(json.dumps(results, indent=2, sort_keys=True))
    except OSError as e:  # read-only CWD: keep the CSV rows, note the miss
        report.add("serve/json_write_failed", 0.0, derived=str(e))
    return results


def gate(results: dict, qor_tolerance: float = 1e-3) -> list[str]:
    """The absolute acceptance gates — hold against any baseline."""
    failures: list[str] = []
    for arm, r in sorted(results.items()):
        if arm.startswith("serve/"):
            if r["ratio_vs_static"] < 1.0:
                failures.append(
                    f"{arm}: continuous batching {r['tok_per_s']:.0f} tok/s "
                    f"< static baseline {r['static_tok_per_s']:.0f} tok/s "
                    f"({r['ratio_vs_static']:.2f}x)")
        elif arm.startswith("plan_cache/"):
            if r["fetch_ms"] >= FETCH_MS_GATE:
                failures.append(
                    f"{arm}: cache-hit fetch {r['fetch_ms']:.2f} ms "
                    f">= {FETCH_MS_GATE} ms budget")
            if r["warm_wall_s"] >= r["cold_wall_s"]:
                failures.append(
                    f"{arm}: warm re-DSE {r['warm_wall_s'] * 1e3:.0f} ms "
                    f"not faster than cold {r['cold_wall_s'] * 1e3:.0f} ms")
            if r["warm_qor_s"] > r["cold_qor_s"] * (1 + qor_tolerance):
                failures.append(
                    f"{arm}: warm QoR {r['warm_qor_s'] * 1e3:.4f} ms worse "
                    f"than cold {r['cold_qor_s'] * 1e3:.4f} ms")
            if not r["warm_verify_ok"]:
                failures.append(f"{arm}: warm-started plan failed the exit "
                                "verifier")
    return failures


def compare(results: dict, baseline: dict, threshold: float,
            allow_missing: bool = False) -> list[str]:
    """Baseline-relative regression checks + the absolute gates."""
    failures = gate(results)
    for arm in sorted(set(results) & set(baseline)):
        new, old = results[arm], baseline[arm]
        if arm.startswith("serve/"):
            ratio = (old["tok_per_s"] / new["tok_per_s"]
                     if new["tok_per_s"] else float("inf"))
            print(f"{arm}: {old['tok_per_s']:.0f} -> "
                  f"{new['tok_per_s']:.0f} tok/s, p99 "
                  f"{old['latency_p99_s'] * 1e3:.0f} -> "
                  f"{new['latency_p99_s'] * 1e3:.0f} ms")
            if ratio > threshold:
                failures.append(
                    f"{arm}: throughput dropped to {new['tok_per_s']:.0f} "
                    f"tok/s, {ratio:.2f}x below baseline "
                    f"{old['tok_per_s']:.0f} (threshold {threshold:.2f}x)")
        elif arm.startswith("plan_cache/"):
            print(f"{arm}: warm {old['warm_wall_s'] * 1e3:.0f} -> "
                  f"{new['warm_wall_s'] * 1e3:.0f} ms, fetch "
                  f"{old['fetch_ms']:.2f} -> {new['fetch_ms']:.2f} ms")
            w_ratio = (new["warm_wall_s"] / old["warm_wall_s"]
                       if old["warm_wall_s"] else float("inf"))
            # sub-50ms walls gate only on real growth, not timer noise
            if w_ratio > threshold \
                    and new["warm_wall_s"] - old["warm_wall_s"] > 0.05:
                failures.append(
                    f"{arm}: warm re-DSE wall "
                    f"{new['warm_wall_s'] * 1e3:.0f} ms is "
                    f"{w_ratio:.2f}x the baseline "
                    f"{old['warm_wall_s'] * 1e3:.0f} ms")
    missing = sorted(set(baseline) - set(results))
    if missing:
        if allow_missing:
            print(f"note: baseline arms not re-run: {missing}")
        else:
            failures.append(
                f"baseline arms not re-run: {missing} (drop --fast, or "
                f"pass --allow-missing-arms to gate on a subset)")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="serving-path benchmark / regression gate")
    ap.add_argument("--fast", action="store_true",
                    help="one serve arm, three plan-cache arms")
    ap.add_argument("--compare", metavar="BASELINE_JSON", default=None,
                    help="diff against a committed BENCH_serve.json and "
                         "exit nonzero on regression or gate failure")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="max allowed regression ratio vs baseline")
    ap.add_argument("--allow-missing-arms", action="store_true")
    args = ap.parse_args(argv)

    baseline = None
    if args.compare is not None:
        baseline = json.loads(Path(args.compare).read_text())
        if "REPRO_BENCH_OUT_DIR" not in os.environ:
            os.environ["REPRO_BENCH_OUT_DIR"] = tempfile.mkdtemp(
                prefix="repro_bench_")

    from .run import Report
    report = Report()
    print("name,us_per_call,derived")
    results = run(report, fast=args.fast)
    if baseline is None:
        failures = gate(results)
    else:
        failures = compare(results, baseline, args.threshold,
                           allow_missing=args.allow_missing_arms)
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    if not failures:
        print("serve gate: OK", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
