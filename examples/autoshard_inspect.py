"""Inspect HIDA-OPT pass by pass: the paper's pipeline made visible.

    PYTHONPATH=src python examples/autoshard_inspect.py \
        --arch deepseek-v3-671b --shape train_4k [--multi-pod] [--ablate]
"""
import argparse

from repro.configs import SHAPES, get_config, list_archs
from repro.core import (MULTI_POD, SINGLE_POD, build_lm_graph, optimize)


def show(arch, shape_name, mesh, ia=True, ca=True, label="IA+CA"):
    cfg = get_config(arch)
    g = build_lm_graph(cfg, SHAPES[shape_name])
    sched, plan, rep = optimize(g, mesh, ia=ia, ca=ca,
                                training=SHAPES[shape_name].mode == "train")
    print(f"\n==== {label}: {arch} x {shape_name} ====")
    print(f"[1-2] construct+fuse: {rep.fusion.pattern_fusions} pattern + "
          f"{rep.fusion.balance_fusions} balance fusions "
          f"-> {len(sched.nodes)} Structural nodes")
    print(f"[3]   multi-producer: {rep.multi_producer.duplicated} buffers "
          f"duplicated, {rep.multi_producer.copies} copies, "
          f"{rep.multi_producer.merged} producers merged")
    print(f"[4]   path balancing: {rep.balance.copy_nodes} skid buffers, "
          f"{rep.balance.soft_fifos} soft FIFOs "
          f"(max skew {rep.balance.max_skew})")
    print(f"[5]   IA+CA parallelization: {rep.parallelize.evaluated} "
          f"proposals, {rep.parallelize.rejected_constraint} rejected by "
          f"divisibility (CA), order={rep.parallelize.order[:4]}...")
    print(f"      rules: {dict(sorted(plan.rules.items()))}")
    print(f"      estimate: {rep.cost.total_s*1e3:.2f} ms/iter, "
          f"critical node {rep.cost.critical_s*1e3:.2f} ms, "
          f"dominant={rep.cost.dominant}, "
          f"hbm={rep.cost.hbm_bytes_per_device/2**30:.2f} GiB/dev")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v3-671b",
                    choices=list_archs())
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ablate", action="store_true",
                    help="also run the IA / CA / naive arms (Fig. 11)")
    args = ap.parse_args()
    mesh = MULTI_POD if args.multi_pod else SINGLE_POD

    base = show(args.arch, args.shape, mesh)
    if args.ablate:
        for label, ia, ca in (("IA-only", True, False),
                              ("CA-only", False, True),
                              ("naive", False, False)):
            rep = show(args.arch, args.shape, mesh, ia, ca, label)
            print(f"      vs IA+CA: "
                  f"{rep.cost.total_s/base.cost.total_s:.2f}x time, "
                  f"{rep.cost.hbm_bytes_per_device / max(base.cost.hbm_bytes_per_device,1):.2f}x HBM")


if __name__ == "__main__":
    main()
