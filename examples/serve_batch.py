"""Batched serving example (deliverable b): prefill + decode with KV /
SSM / xLSTM caches across architectures.

    PYTHONPATH=src python examples/serve_batch.py --arch xlstm-125m
"""
import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--batch",
                str(args.batch), "--prompt-len", "16", "--gen",
                str(args.gen), "--temperature", "0.8"])


if __name__ == "__main__":
    main()
