"""End-to-end training driver (deliverable b): trains smollm-135m-class
models with the full substrate — HIDA plan, sharded deterministic data,
AdamW + cosine schedule, async checkpointing with auto-resume, straggler
monitor.  The loss demonstrably decreases on the markov-flavoured
synthetic corpus.

Reduced config (CPU, ~2 min for 200 steps):
    PYTHONPATH=src python examples/train_e2e.py --steps 200

Full config (TPU pod):
    PYTHONPATH=src python examples/train_e2e.py --full --steps 500 \
        --batch 256 --seq 4096
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="full config (pod-scale); default is reduced")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
    if not args.full:
        argv.append("--smoke")
    out = train_main(argv)
    losses = out["losses"]
    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"[e2e] loss {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'did not decrease'})")


if __name__ == "__main__":
    main()
