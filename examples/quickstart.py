"""Quickstart: HIDA-OPT derives the sharding plan, then we train a few
steps — nobody writes a PartitionSpec by hand.

    PYTHONPATH=src python examples/quickstart.py [--arch smollm-135m]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs
from repro.core import SINGLE_POD, build_lm_graph, optimize
from repro.data import SyntheticCorpus
from repro.models import LM
from repro.optim import AdamW


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    # 1. HIDA-OPT: algorithmic description -> dataflow plan.
    full_cfg = get_config(args.arch)
    graph = build_lm_graph(full_cfg, SHAPES["train_4k"])
    sched, plan, report = optimize(graph, SINGLE_POD)
    print(f"== {args.arch}: HIDA-OPT on the 16x16 production mesh ==")
    print(f"   nodes={len(sched.nodes)} "
          f"fusions={report.fusion.pattern_fusions}p"
          f"+{report.fusion.balance_fusions}b "
          f"balance_copies={report.balance.copy_nodes} "
          f"soft_fifos={report.balance.soft_fifos}")
    print(f"   estimated step: {report.cost.total_s*1e3:.2f} ms/block-iter"
          f" dominant={report.cost.dominant}")
    print(f"   sharding rules: {dict(sorted(plan.rules.items()))}")

    # 2. Train the reduced config for a few steps on this host.
    cfg = get_config(args.arch, smoke=True)
    lm = LM(cfg, remat="none")
    params, _ = lm.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    corpus = SyntheticCorpus(cfg.vocab)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    print(f"== training the reduced config for {args.steps} steps ==")
    for i in range(args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in corpus.batch(i, 0, 4, 32).items()}
        if cfg.frontend == "audio_frames":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (4, 32, cfg.d_model), jnp.bfloat16)
        if cfg.frontend == "vision":
            batch["img_embeds"] = jax.random.normal(
                jax.random.PRNGKey(i), (4, cfg.n_img_tokens, cfg.d_model),
                jnp.bfloat16)
        params, opt_state, loss = step(params, opt_state, batch)
        print(f"   step {i}: loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
