"""Plan-layer contracts: unified coherent projection, the delta
re-projection API, and the EP-widening arms of ``optimize()``.

What is pinned here (see ``repro.core.plan``):

* **One projection routine** — ``build_plan``'s per-buffer scan,
  ``project_rules``'s full rebuild and ``ShardingPlan.apply_rule_change``'s
  delta path all project through the schedule's cached
  ``ScheduleTopology.axis_dims`` (first non-None loop dim *any* owner
  names per buffer axis).  The historical ``project_rules`` walked only
  the first owner's access map, silently replicating axes that owner did
  not name — the regression test below builds exactly that shape.
* **Delta == rebuild, bit-identically** — after a full ``optimize()``
  (whose EP widening uses ``apply_rule_change``), ``plan.to_json()``
  equals a from-scratch ``build_plan`` + ``project_rules`` rebuild on
  every registered config × applicable shape.
* **EP widening arms** — widened-over-data (deepseek-v3), the ``moe_tp``
  fallback (deepseek-v2: expert count divides ``data`` but not
  ``data × model``), and the no-widen small-MoE case (jamba); widening
  must leave non-expert buffer specs untouched.
* **Intensity-proportional parallel factors** — powers of two, capped,
  monotone in intensity (integer bit-length rounding, no float log2).
"""
from __future__ import annotations

import sys
from pathlib import Path

import pytest
from jax.sharding import PartitionSpec as P

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.core import (SINGLE_POD, AccessMap, Buffer, MemoryEffect, Node,
                        Op, Schedule, ShardingPlan, build_lm_graph,
                        build_plan, optimize, project_rules)
from repro.core.parallelize import parallel_factors


# -- the first-owner access-map hazard (regression) --------------------------

def _hazard_schedule() -> Schedule:
    """Producer's access map has ``None`` at an axis the consumer names:
    the coherent projection must still shard that axis from the rules."""
    sched = Schedule(name="hazard")
    sched.buffers["B"] = Buffer(name="B", shape=(64, 64), dims=("a", "b"))
    p = Node(name="P", args={"B": MemoryEffect.WRITE}, body=[
        Op(name="p0", kind="prod", ins=[], outs=["B"], loop_dims={"a": 64},
           access={"B": AccessMap.of(("a", 1), (None, 1))})])
    c = Node(name="C", args={"B": MemoryEffect.READ}, body=[
        Op(name="c0", kind="cons", ins=["B"], outs=[],
           loop_dims={"a": 64, "b": 64},
           access={"B": AccessMap.of(("a", 1), ("b", 1))})])
    p.axis_map = {"a": ("data",)}
    p.unroll = {"a": 16}
    c.axis_map = {"a": ("data",), "b": ("model",)}
    c.unroll = {"a": 16, "b": 16}
    sched.nodes = [p, c]
    return sched


def test_project_rules_scans_all_owners_per_axis():
    """The coherent projection shards axis 1 from the consumer's loop dim
    even though the *first* owner (the producer) has ``None`` there —
    previously ``project_rules`` stopped at the producer's access map and
    silently replicated the axis."""
    sched = _hazard_schedule()
    plan = build_plan(sched, SINGLE_POD, coherent=True)
    assert plan.rules == {"a": ("data",), "b": ("model",)}
    assert plan.buffer_specs["B"] == (("data",), ("model",))
    assert sched.buffers["B"].spec == (("data",), ("model",))
    # The cached topology records the coherent per-axis dims.
    assert sched.topology().axis_dims["B"] == ("a", "b")


def test_apply_rule_change_matches_full_rebuild():
    """Delta re-projection touches exactly the sites referencing the dim
    (plus role aliases) and lands bit-identical to a full rebuild."""
    sched = _hazard_schedule()
    plan = build_plan(sched, SINGLE_POD, coherent=True)
    plan.add_role_alias("role_b", "B")
    assert plan.buffer_specs["role_b"] == plan.buffer_specs["B"]

    changed = plan.apply_rule_change("b", ("model", "data"), sched)
    assert set(changed) == {"B", "role_b"}
    assert plan.buffer_specs["B"] == (("data",), ("model", "data"))
    assert plan.buffer_specs["role_b"] == plan.buffer_specs["B"]

    rebuilt = build_plan(sched, SINGLE_POD, coherent=True)
    rebuilt.add_role_alias("role_b", "B")
    rebuilt.rules["b"] = ("model", "data")
    project_rules(rebuilt, sched)
    assert plan.to_json() == rebuilt.to_json()

    # Deleting a rule (empty axes) un-shards the axis on the delta path.
    plan.apply_rule_change("b", (), sched)
    assert "b" not in plan.rules
    assert plan.buffer_specs["B"] == (("data",), ())
    assert plan.buffer_specs["role_b"] == (("data",), ())


# -- spec_for_dims site-override rank mismatches -----------------------------

def test_spec_for_dims_records_rank_mismatch():
    plan = ShardingPlan(mesh_spec=SINGLE_POD)
    plan.buffer_specs["qkv"] = (("data",), (), ("model",))
    plan.rules = {"batch": ("data",)}
    # Matching rank: the override applies, nothing is recorded.
    assert (plan.spec_for_dims(("batch", "seq", "heads"), site="qkv")
            == P("data", None, "model"))
    assert plan.spec_rank_mismatches == {}
    # Rank mismatch (role alias stripped from a different-rank site):
    # falls back to the rules and counts the dropped override.
    base_json = plan.to_json()
    assert plan.spec_for_dims(("batch", "d_model"), site="qkv") == P("data")
    assert plan.spec_rank_mismatches == {"qkv": 1}
    plan.spec_for_dims(("batch",), site="qkv")
    assert plan.spec_rank_mismatches == {"qkv": 2}
    # Unknown sites are not overrides and are not counted.
    plan.spec_for_dims(("batch",), site="nope")
    assert plan.spec_rank_mismatches == {"qkv": 2}
    # The diagnostic never leaks into the serialized artifact: the plan
    # stays pure data, independent of query history.
    assert plan.to_json() == base_json


# -- intensity-proportional parallel factors --------------------------------

@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "deepseek-v2-236b"])
@pytest.mark.parametrize("max_pf", [1, 4, 16, 256])
def test_parallel_factors_properties(arch, max_pf):
    """Every pf is a power of two, ≤ max_pf, and monotone in intensity."""
    from repro.core import (construct_functional, fuse_tasks,
                            lower_to_structural)
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    pf = parallel_factors(sched, max_pf=max_pf, ia=True)
    by_intensity = sorted(sched.nodes, key=lambda n: n.intensity())
    for n in sched.nodes:
        v = pf[n.name]
        assert v >= 1 and v <= max_pf
        assert v & (v - 1) == 0, f"{n.name}: pf {v} not a power of two"
    for lo, hi in zip(by_intensity, by_intensity[1:]):
        assert pf[lo.name] <= pf[hi.name]
    # The peak-intensity node always gets the full budget.
    assert pf[by_intensity[-1].name] == max_pf


# -- EP-widening arms of optimize() ------------------------------------------

def _optimized(arch):
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    return optimize(g, SINGLE_POD)


def _mesh_prod(axes):
    f = 1
    for a in axes:
        f *= SINGLE_POD.size(a)
    return f


def _expert_count(sched):
    b = next(b for b in sched.buffers.values()
             if b.is_weight and "experts" in b.dims)
    return b.shape[b.dims.index("experts")]


def _non_expert_specs_match_unwidened(sched, plan):
    """Re-projection after widening must leave every buffer whose access
    maps do not reference "experts" bit-identical to the unwidened plan."""
    topo = sched.topology()
    unwidened = build_plan(sched, SINGLE_POD, coherent=True, topology=topo)
    for bname, spec in unwidened.buffer_specs.items():
        if "experts" in topo.axis_dims[bname]:
            continue
        assert plan.buffer_specs[bname] == spec, bname


def test_ep_widening_over_data_deepseek_v3():
    """256 experts divide data×model: EP widens over the data axis."""
    sched, plan, _rep = _optimized("deepseek-v3-671b")
    axes = plan.rules["experts"]
    assert "data" in axes
    assert plan.meta["ep_widened"] == list(axes)
    assert "moe_tp" not in plan.meta
    assert _expert_count(sched) % _mesh_prod(axes) == 0
    _non_expert_specs_match_unwidened(sched, plan)


def test_ep_widening_moe_tp_fallback_deepseek_v2():
    """160 experts divide data (16) but not data×model (256): EP over data
    plus Megatron expert-TP over model."""
    sched, plan, _rep = _optimized("deepseek-v2-236b")
    assert plan.rules["experts"] == ("data",)
    assert plan.meta["moe_tp"] == "model"
    assert plan.meta["ep_widened"] == ["data", "+tp:model"]
    assert _expert_count(sched) % SINGLE_POD.size("data") == 0
    assert _expert_count(sched) % (SINGLE_POD.size("data")
                                   * SINGLE_POD.size("model")) != 0
    _non_expert_specs_match_unwidened(sched, plan)


def test_ep_no_widen_small_moe_jamba():
    """Small MoE under the HBM budget: the DSE's choice stands, no
    widening metadata, and the plan equals the plain coherent build."""
    sched, plan, _rep = _optimized("jamba-v0.1-52b")
    assert "ep_widened" not in plan.meta
    assert "moe_tp" not in plan.meta
    unwidened = build_plan(sched, SINGLE_POD, coherent=True)
    for bname, spec in unwidened.buffer_specs.items():
        assert plan.buffer_specs[bname] == spec, bname


# -- delta projection == from-scratch rebuild, every config × shape ----------

def _assert_delta_matches_rebuild(arch: str, shape: str) -> None:
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES[shape])
    if not ok:
        pytest.skip(why)
    g = build_lm_graph(cfg, SHAPES[shape])
    sched, plan, _rep = optimize(g, SINGLE_POD)

    rebuilt = build_plan(sched, SINGLE_POD, fsdp=plan.fsdp,
                         meta=dict(plan.meta), coherent=True)
    for bname in list(rebuilt.buffer_specs):
        if "__" in bname:
            rebuilt.add_role_alias(bname.split("__", 1)[1], bname)
    if "experts" in plan.rules:
        rebuilt.rules["experts"] = plan.rules["experts"]
    project_rules(rebuilt, sched)
    assert plan.to_json() == rebuilt.to_json()


_FAST_CELLS = [("deepseek-v3-671b", "train_4k"),
               ("deepseek-v2-236b", "train_4k"),
               ("smollm-360m", "prefill_32k")]


@pytest.mark.parametrize("arch,shape", _FAST_CELLS)
def test_delta_projection_bit_identical(arch, shape):
    _assert_delta_matches_rebuild(arch, shape)


@pytest.mark.slow
@pytest.mark.parametrize("shape", sorted(SHAPES))
@pytest.mark.parametrize("arch", list_archs())
def test_delta_projection_bit_identical_sweep(arch, shape):
    _assert_delta_matches_rebuild(arch, shape)
