"""Persistent plan cache + warm-started re-DSE.

Contracts:

1. **Round-trip** — a :class:`CachedPlan` survives JSON exactly (plan
   payload, canonical snapshot, QoR), and the envelope version gate
   rejects stale entries.
2. **Tiers** — memory hit needs no I/O, disk hit survives a process
   restart (fresh :class:`PlanCache` on the same root), and a hit is
   served in well under the 5 ms budget.
3. **Degradation** — corrupt files, version skew, and injected
   ``cache.load`` / ``cache.store`` faults degrade to a miss (load) or
   an unstored entry (store); :func:`fetch_or_optimize` then falls back
   to the DSE and never raises.
4. **Safety** — every cache-served plan passes the static verifier
   against the requesting mesh; a mesh-mismatched entry is rejected,
   not served.
5. **Warm start** — a donor snapshot covers the fresh schedule's nodes
   (canonical keys bridge the process-global name counter), warm wall
   is below cold wall, warm QoR is never worse, and the elastic
   topology rung (host-count change) replans warm with the new plan
   cached for next time.
"""
import json
import time

import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core import (MULTI_POD, SINGLE_POD, CachedPlan, PlanCache,
                        PlanKey, build_lm_graph, canonical_snapshot,
                        config_fingerprint, fetch_or_optimize, optimize,
                        shape_bucket, verify_static)
from repro.core.faults import inject_faults
from repro.core.ir import reset_fresh_names
from repro.core.plan_cache import CACHE_FORMAT_VERSION
from repro.distributed import mesh_for_hosts, replan_for_topology

ARCH = "smollm-135m"
BUCKET = shape_bucket("decode", 128, 4)
SHAPE = ShapeSpec(BUCKET, 128, 4, "decode")


@pytest.fixture(scope="module")
def cfg():
    return get_config(ARCH, smoke=True)


@pytest.fixture(scope="module")
def cold(cfg):
    """One cold optimize shared by the module's tests."""
    t0 = time.perf_counter()
    sched, plan, report = optimize(build_lm_graph(cfg, SHAPE), SINGLE_POD)
    wall = time.perf_counter() - t0
    return sched, plan, report, wall


def graph_factory(cfg):
    return lambda: build_lm_graph(cfg, SHAPE)


def make_entry(cfg, cold, mesh=SINGLE_POD) -> CachedPlan:
    sched, plan, report, _ = cold
    return CachedPlan(key=PlanKey.make(cfg, mesh, BUCKET), plan=plan,
                      snapshot=canonical_snapshot(sched),
                      qor_total_s=report.cost.total_s, stored_unix=1.0)


# -- identity -------------------------------------------------------------

def test_fingerprint_covers_every_field(cfg):
    other = get_config("smollm-360m", smoke=True)
    assert config_fingerprint(cfg) == config_fingerprint(cfg)
    assert config_fingerprint(cfg) != config_fingerprint(other)


def test_shape_bucket_quantizes():
    assert shape_bucket("decode", 100, 4) == shape_bucket("decode", 128, 4)
    assert shape_bucket("decode", 129, 4) == "decode_b4_s256"
    assert shape_bucket("decode", 128, 8) != shape_bucket("decode", 128, 4)
    assert shape_bucket("prefill", 128, 4) != shape_bucket("decode", 128, 4)


def test_plan_key_roundtrip(cfg):
    key = PlanKey.make(cfg, SINGLE_POD, BUCKET)
    assert PlanKey.from_dict(key.to_dict()) == key
    assert key.digest() == key.digest()
    assert key.digest() != PlanKey.make(cfg, MULTI_POD, BUCKET).digest()


# -- round-trip -----------------------------------------------------------

def test_entry_json_roundtrip(cfg, cold):
    entry = make_entry(cfg, cold)
    back = CachedPlan.from_json(entry.to_json())
    assert back.key == entry.key
    assert back.snapshot == entry.snapshot
    assert back.qor_total_s == entry.qor_total_s
    assert back.plan.to_json() == entry.plan.to_json()


def test_entry_version_gate(cfg, cold):
    blob = json.loads(make_entry(cfg, cold).to_json())
    blob["cache_version"] = CACHE_FORMAT_VERSION + 1
    with pytest.raises(ValueError, match="version"):
        CachedPlan.from_json(json.dumps(blob))


# -- tiers ----------------------------------------------------------------

def test_memory_then_disk_hit(cfg, cold, tmp_path):
    entry = make_entry(cfg, cold)
    cache = PlanCache(tmp_path)
    assert cache.put(entry)
    assert cache.get(entry.key) is entry          # memory tier
    assert cache.stats["hits_mem"] == 1

    fresh = PlanCache(tmp_path)                   # "restarted process"
    t0 = time.perf_counter()
    got, rep = fresh.fetch(entry.key, SINGLE_POD)
    fetch_s = time.perf_counter() - t0
    assert got is not None and rep.ok
    assert fresh.stats["hits_disk"] == 1
    assert got.plan.to_json() == entry.plan.to_json()
    assert fetch_s < 0.005, f"disk hit took {fetch_s * 1e3:.2f} ms"


def test_lru_eviction_keeps_disk(cfg, cold, tmp_path):
    cache = PlanCache(tmp_path, capacity=1)
    a = make_entry(cfg, cold, SINGLE_POD)
    b = make_entry(cfg, cold, MULTI_POD)
    cache.put(a)
    cache.put(b)                                  # evicts a from memory
    assert a.key not in cache._lru
    assert cache.get(a.key) is not None           # but disk still has it
    assert cache.stats["hits_disk"] == 1


# -- degradation ----------------------------------------------------------

def test_corrupt_file_is_a_miss(cfg, cold, tmp_path):
    entry = make_entry(cfg, cold)
    cache = PlanCache(tmp_path)
    cache.put(entry)
    path = cache._path(entry.key)
    path.write_text(path.read_text()[:40])        # truncate mid-JSON
    fresh = PlanCache(tmp_path)
    assert fresh.get(entry.key) is None
    assert fresh.stats["corrupt"] == 1 and fresh.stats["misses"] == 1


def test_wrong_key_in_file_is_a_miss(cfg, cold, tmp_path):
    entry = make_entry(cfg, cold, SINGLE_POD)
    other = make_entry(cfg, cold, MULTI_POD)
    cache = PlanCache(tmp_path)
    cache.put(entry)
    # overwrite entry's file with other's payload: digest/key mismatch
    cache._path(entry.key).write_text(other.to_json())
    fresh = PlanCache(tmp_path)
    assert fresh.get(entry.key) is None
    assert fresh.stats["corrupt"] == 1


def test_chaos_cache_sites_never_raise(cfg, cold, tmp_path):
    entry = make_entry(cfg, cold)
    cache = PlanCache(tmp_path)
    with inject_faults(seed=0, rate=1.0, sites=("cache.*",)) as inj:
        assert cache.put(entry) is False          # store degraded
        cache._lru.clear()
        assert cache.get(entry.key) is None       # load degraded
    assert cache.stats["store_errors"] == 1
    assert {r.site for r in inj.fired()} <= {"cache.load", "cache.store"}


def test_fetch_or_optimize_survives_chaos(cfg, tmp_path):
    cache = PlanCache(tmp_path)
    with inject_faults(seed=0, rate=1.0, sites=("cache.*",)):
        plan, source, report = fetch_or_optimize(
            cache, PlanKey.make(cfg, SINGLE_POD, BUCKET), SINGLE_POD,
            graph_factory(cfg))
    assert source == "cold" and report.verify.ok
    assert verify_static(plan, SINGLE_POD).ok


# -- safety ---------------------------------------------------------------

def test_mesh_mismatched_entry_rejected(cfg, cold, tmp_path):
    sched, plan, report, _ = cold                 # plan derived on SINGLE_POD
    bad = CachedPlan(key=PlanKey.make(cfg, MULTI_POD, BUCKET), plan=plan,
                     snapshot=canonical_snapshot(sched),
                     qor_total_s=report.cost.total_s)
    cache = PlanCache(tmp_path)
    cache.put(bad)
    got, rep = cache.fetch(bad.key, MULTI_POD)
    assert got is None and not rep.ok
    assert "mesh-mismatch" in rep.codes()
    assert cache.stats["rejected"] == 1


def test_cache_loaded_plans_verify(cfg, cold, tmp_path):
    cache = PlanCache(tmp_path)
    cache.put(make_entry(cfg, cold))
    fresh = PlanCache(tmp_path)
    got, rep = fresh.fetch(make_entry(cfg, cold).key, SINGLE_POD)
    assert got is not None and rep.ok and not rep.errors()


# -- warm start -----------------------------------------------------------

def test_hit_warm_cold_progression(cfg, cold, tmp_path):
    _, _, _, cold_wall = cold
    cache = PlanCache(tmp_path)
    key = PlanKey.make(cfg, SINGLE_POD, BUCKET)

    plan1, s1, rep1 = fetch_or_optimize(cache, key, SINGLE_POD,
                                        graph_factory(cfg))
    assert s1 == "cold" and rep1.verify.ok

    # same key again: pure hit, no DSE
    plan2, s2, rep2 = fetch_or_optimize(cache, key, SINGLE_POD,
                                        graph_factory(cfg))
    assert s2 == "hit" and rep2 is None
    assert plan2.to_json() == plan1.to_json()

    # different bucket, same config: warm re-DSE seeded by the donor
    key3 = PlanKey.make(cfg, SINGLE_POD, shape_bucket("decode", 256, 4))
    t0 = time.perf_counter()
    plan3, s3, rep3 = fetch_or_optimize(
        cache, key3, SINGLE_POD,
        lambda: build_lm_graph(cfg, ShapeSpec("d256", 256, 4, "decode")))
    warm_wall = time.perf_counter() - t0
    assert s3 == "warm" and rep3.verify.ok
    assert rep3.parallelize.warm_covered > 0
    assert warm_wall < cold_wall, (warm_wall, cold_wall)


def test_warm_qor_never_worse_and_deterministic(cfg, cold):
    sched, _, report, _ = cold
    snap = canonical_snapshot(sched)
    # pin the process-global fresh-name counter so the two runs produce
    # identically-named (not merely isomorphic) schedules
    reset_fresh_names()
    _, wplan1, wrep1 = optimize(build_lm_graph(cfg, SHAPE), SINGLE_POD,
                                warm_start=snap)
    reset_fresh_names()
    _, wplan2, wrep2 = optimize(build_lm_graph(cfg, SHAPE), SINGLE_POD,
                                warm_start=snap)
    assert wrep1.parallelize.warm and wrep1.parallelize.warm_covered > 0
    assert wrep1.cost.total_s <= report.cost.total_s * (1 + 1e-9)
    assert wplan1.to_json() == wplan2.to_json()   # deterministic
    assert not wrep1.degradations


def test_nearest_prefers_same_fingerprint(cfg, cold, tmp_path):
    other = get_config("smollm-360m", smoke=True)
    cache = PlanCache(tmp_path)
    # donor A: same config, different mesh;  donor B: different config,
    # same mesh+bucket.  A must win (fingerprint outranks mesh+bucket).
    cache.put(make_entry(cfg, cold, MULTI_POD))
    sched_b, plan_b, rep_b = optimize(
        build_lm_graph(other, SHAPE), SINGLE_POD)
    cache.put(CachedPlan(key=PlanKey.make(other, SINGLE_POD, BUCKET),
                         plan=plan_b, snapshot=canonical_snapshot(sched_b),
                         qor_total_s=rep_b.cost.total_s))
    donor = cache.nearest(PlanKey.make(cfg, SINGLE_POD, BUCKET))
    assert donor is not None
    assert donor.key.fingerprint == config_fingerprint(cfg)


def test_elastic_topology_rung(cfg, tmp_path):
    cache = PlanCache(tmp_path)
    gf = graph_factory(cfg)
    m16, m8 = mesh_for_hosts(16), mesh_for_hosts(8)
    assert m16 == SINGLE_POD
    _, s0, _ = fetch_or_optimize(cache, PlanKey.make(cfg, m16, BUCKET),
                                 m16, gf)
    assert s0 == "cold"
    plan8, s8, rep8 = replan_for_topology(cache, cfg, new_mesh=m8,
                                          bucket=BUCKET, graph_factory=gf)
    assert s8 == "warm" and rep8.verify.ok
    assert rep8.parallelize.warm_covered > 0
    assert verify_static(plan8, m8).ok
    # growing back is now a sub-ms hit, not a re-plan
    _, s16, rep16 = replan_for_topology(cache, cfg, new_mesh=m16,
                                        bucket=BUCKET, graph_factory=gf)
    assert s16 == "hit" and rep16 is None
