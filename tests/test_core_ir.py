"""Unit tests for HIDA-IR and the HIDA-OPT passes, including the paper's
own worked examples (Listing 1 / Table 4 connection maps, Fig. 7
multi-producer cases, Fig. 8 path balancing)."""
from fractions import Fraction

import pytest

from repro.core import (AccessMap, Buffer, Graph, MemoryEffect, Node, Op,
                        Schedule, SINGLE_POD, balance_paths,
                        construct_functional, eliminate_multi_producers,
                        estimate, fuse_tasks, lower_to_structural,
                        parallelize)
from repro.core.balance import path_skew
from repro.core.parallelize import analyze_connections, parallel_factors


# --------------------------------------------------------------------------
# Algorithm 1: Functional dataflow construction
# --------------------------------------------------------------------------

def _two_matmul_graph():
    g = Graph("g")
    g.tensor("x", (8, 8), dims=("i", "k"), is_input=True)
    g.tensor("w1", (8, 8), dims=("k", "j"), is_weight=True)
    g.tensor("w2", (8, 8), dims=("j", "l"), is_weight=True)
    g.tensor("t", (8, 8), dims=("i", "j"))
    g.tensor("y", (8, 8), dims=("i", "l"))
    g.op("matmul", ["x", "w1"], ["t"], {"i": 8, "k": 8, "j": 8}, flops=1024)
    g.op("matmul", ["t", "w2"], ["y"], {"i": 8, "j": 8, "l": 8}, flops=1024)
    g.outputs = ["y"]
    return g


def test_construct_wraps_dispatch_and_tasks():
    g = construct_functional(_two_matmul_graph())
    assert len(g.ops) == 1 and g.ops[0].kind == "dispatch"
    assert all(t.kind == "task" for t in g.ops[0].region)
    assert len(g.ops[0].region) == 2


def test_construct_single_op_not_dispatchable():
    g = Graph("g")
    g.tensor("x", (4,), is_input=True)
    g.tensor("y", (4,))
    g.op("elementwise", ["x"], ["y"], {"i": 4}, flops=4)
    construct_functional(g)
    assert g.ops[0].kind == "elementwise"  # untouched


# --------------------------------------------------------------------------
# Algorithm 2: task fusion
# --------------------------------------------------------------------------

def test_pattern_fusion_matmul_epilogue():
    g = Graph("g")
    g.tensor("x", (8, 8), is_input=True)
    g.tensor("w", (8, 8), is_weight=True)
    g.tensor("h", (8, 8))
    g.tensor("a", (8, 8))
    g.op("matmul", ["x", "w"], ["h"], {"i": 8, "j": 8, "k": 8}, flops=1024)
    g.op("activation", ["h"], ["a"], {"i": 8, "j": 8}, flops=64)
    g.outputs = ["a"]
    construct_functional(g)
    stats = fuse_tasks(g)
    assert stats.pattern_fusions == 1
    # Everything fused into one task → hierarchy canonicalised.
    sched = lower_to_structural(g)
    assert len(sched.nodes) == 1
    # h is now node-internal: not a schedule buffer.
    assert "h" not in sched.buffers


def test_balance_fusion_absorbs_light_tasks():
    g = Graph("g")
    g.tensor("x", (8,), is_input=True)
    prev = "x"
    for i in range(3):
        g.tensor(f"t{i}", (8,))
        g.op("scan", [prev], [f"t{i}"], {"i": 8},
             flops=(10_000 if i == 0 else 10))
        prev = f"t{i}"
    g.outputs = [prev]
    construct_functional(g)
    stats = fuse_tasks(g)
    assert stats.balance_fusions >= 1
    sched = lower_to_structural(g)
    assert len(sched.nodes) < 3


def test_fusion_never_creates_cycle():
    # a -> b -> c with a--c adjacency: fusing a+c around b is illegal.
    g = Graph("g")
    g.tensor("x", (8,), is_input=True)
    for name in ("ta", "tb", "tc"):
        g.tensor(name, (8,))
    g.op("matmul", ["x"], ["ta"], {"i": 8}, flops=100)
    g.op("scan", ["ta"], ["tb"], {"i": 8}, flops=100)
    g.op("elementwise", ["ta", "tb"], ["tc"], {"i": 8}, flops=8)
    g.outputs = ["tc"]
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    sched.topo_order()  # must not raise


# --------------------------------------------------------------------------
# Section 6.3: lowering + effects
# --------------------------------------------------------------------------

def test_lowering_effects_ro_rw():
    g = Graph("g")
    g.tensor("x", (8,), is_input=True)
    g.tensor("acc", (8,), is_input=True)
    g.tensor("y", (8,))
    g.op("matmul", ["x"], ["y"], {"i": 8}, flops=64)
    g.op("elementwise", ["y", "acc"], ["acc"], {"i": 8}, flops=8)
    g.outputs = ["acc"]
    construct_functional(g)
    sched = lower_to_structural(g)
    effects = {}
    for n in sched.nodes:
        effects.update(n.args)
    assert effects["x"] == MemoryEffect.READ
    assert effects["acc"] == MemoryEffect.READ_WRITE


# --------------------------------------------------------------------------
# Algorithm 3: multi-producer elimination (paper Fig. 7)
# --------------------------------------------------------------------------

def _mk_node(name, args, loop=None, flops=0):
    op = Op(name=f"{name}_op", kind="compute",
            ins=[a for a, e in args.items()
                 if e in (MemoryEffect.READ, MemoryEffect.READ_WRITE)],
            outs=[a for a, e in args.items()
                  if e in (MemoryEffect.WRITE, MemoryEffect.READ_WRITE)],
            loop_dims=loop or {}, flops=flops)
    return Node(name=name, args=dict(args), body=[op])


def test_internal_buffer_duplication_fig7a():
    # Node1 RW Buf2, Node2 writes Buf2, Node3 reads Buf2 — internal buffer.
    s = Schedule("s")
    s.buffers["buf1"] = Buffer("buf1", (16,))
    s.buffers["buf2"] = Buffer("buf2", (16,))
    s.buffers["out"] = Buffer("out", (16,))
    s.args = ["buf1"]
    n1 = _mk_node("n1", {"buf1": MemoryEffect.READ,
                         "buf2": MemoryEffect.READ_WRITE})
    n2 = _mk_node("n2", {"buf1": MemoryEffect.READ,
                         "buf2": MemoryEffect.WRITE})
    n3 = _mk_node("n3", {"buf2": MemoryEffect.READ,
                         "out": MemoryEffect.WRITE})
    s.nodes = [n1, n2, n3]
    stats = eliminate_multi_producers(s)
    assert stats.duplicated == 1
    # Exactly one producer per buffer now.
    for b in s.buffers:
        assert len(s.producers_of(b)) <= 1, b
    # n2 reads nothing from buf2 → no copy inserted; n3 re-pointed.
    assert stats.copies == 0
    assert "buf2" not in n3.args


def test_internal_duplication_inserts_copy_when_producer_reads():
    s = Schedule("s")
    s.buffers["buf"] = Buffer("buf", (16,))
    n1 = _mk_node("n1", {"buf": MemoryEffect.WRITE})
    n2 = _mk_node("n2", {"buf": MemoryEffect.READ_WRITE})
    s.nodes = [n1, n2]
    stats = eliminate_multi_producers(s)
    assert stats.duplicated == 1 and stats.copies == 1
    assert n2.body[0].kind == "copy"


def test_external_buffer_producers_merged_fig7c():
    s = Schedule("s")
    s.buffers["ext"] = Buffer("ext", (16,))
    s.args = ["ext"]
    n1 = _mk_node("n1", {"ext": MemoryEffect.WRITE})
    n2 = _mk_node("n2", {"ext": MemoryEffect.WRITE})
    s.nodes = [n1, n2]
    stats = eliminate_multi_producers(s)
    assert stats.merged == 2
    assert len(s.nodes) == 1
    assert len(s.producers_of("ext")) == 1


# --------------------------------------------------------------------------
# Section 6.4.2: data-path balancing (paper Fig. 8)
# --------------------------------------------------------------------------

def _shortcut_schedule(buf_bytes=16):
    # n0 -> n1 -> n2 and n0 -> n2 (shortcut, skew 1)
    s = Schedule("s")
    for b in ("b01", "b12", "b02", "out"):
        s.buffers[b] = Buffer(b, (buf_bytes // 2,), dtype="bf16",
                              dims=("i",))
    n0 = _mk_node("n0", {"b01": MemoryEffect.WRITE,
                         "b02": MemoryEffect.WRITE}, {"i": buf_bytes // 2})
    n1 = _mk_node("n1", {"b01": MemoryEffect.READ,
                         "b12": MemoryEffect.WRITE}, {"i": buf_bytes // 2})
    n2 = _mk_node("n2", {"b12": MemoryEffect.READ,
                         "b02": MemoryEffect.READ,
                         "out": MemoryEffect.WRITE}, {"i": buf_bytes // 2})
    s.nodes = [n0, n1, n2]
    return s


def test_path_skew_detects_shortcut():
    s = _shortcut_schedule()
    skews = path_skew(s)
    assert skews[("n0", "n2", "b02")] == 1
    assert skews[("n0", "n1", "b01")] == 0


def test_balance_duplicates_small_buffer():
    s = _shortcut_schedule()
    stats = balance_paths(s, onchip_budget_bytes=1 << 20)
    assert stats.copy_nodes == 1 and stats.soft_fifos == 0
    # After balancing every edge has skew 0 (paths equal length).
    assert all(k <= 0 for k in path_skew(s).values())


def test_balance_soft_fifo_for_large_buffer():
    s = _shortcut_schedule()
    stats = balance_paths(s, onchip_budget_bytes=1)
    assert stats.soft_fifos == 1
    assert s.buffers["b02"].stages == 2
    assert s.buffers["b02"].placement == "external"
    assert len(s.tokens) == 1 and s.tokens[0].src == "n0"


# --------------------------------------------------------------------------
# Section 6.5: the paper's Listing 1 / Table 4 example
# --------------------------------------------------------------------------

def _listing1_graph():
    """Node0 loads A[32,16]; Node1 loads B[16,16];
    Node2: C[i][j] += A[i*2][k] * B[k][j] (i,j,k = 16,16,16)."""
    g = Graph("listing1")
    g.tensor("A", (32, 16), "f32", ("a0", "a1"), is_input=True)
    g.tensor("B", (16, 16), "f32", ("b0", "b1"), is_input=True)
    g.tensor("C", (16, 16), "f32", ("c0", "c1"))
    g.tensor("Asrc", (32, 16), "f32", ("a0", "a1"), is_input=True)
    g.tensor("Bsrc", (16, 16), "f32", ("b0", "b1"), is_input=True)
    g.op("copy", ["Asrc"], ["A"], {"i": 32, "k": 16}, flops=512,
         name="node0",
         access={"Asrc": AccessMap.of(("i", 1), ("k", 1)),
                 "A": AccessMap.of(("i", 1), ("k", 1))})
    g.op("copy", ["Bsrc"], ["B"], {"k": 16, "j": 16}, flops=256,
         name="node1",
         access={"Bsrc": AccessMap.of(("k", 1), ("j", 1)),
                 "B": AccessMap.of(("k", 1), ("j", 1))})
    g.op("matmul", ["A", "B"], ["C"], {"i": 16, "j": 16, "k": 16},
         flops=4096, name="node2",
         access={"A": AccessMap.of(("i", 2), ("k", 1)),
                 "B": AccessMap.of(("k", 1), ("j", 1)),
                 "C": AccessMap.of(("i", 1), ("j", 1))})
    g.outputs = ["C"]
    return g


def test_listing1_connection_maps_match_table4():
    g = _listing1_graph()
    construct_functional(g)
    sched = lower_to_structural(g)
    conns = analyze_connections(sched)
    byname = {c.buffer: c for c in conns}
    a = byname["A"]
    # Axis 0: producer writes with loop i stride 1; consumer reads with
    # loop i stride 2 → S-to-T scaling 0.5 (paper Table 4).
    (sdim0, sstr0, ddim0, dstr0) = a.axes[0]
    assert (sdim0, ddim0) == ("i", "i")
    proj = a.project({"i": 4}, from_src=True)
    assert proj["i"] == Fraction(2)  # factor 4 × (1/2) = 2
    back = a.project({"i": 2}, from_src=False)
    assert back["i"] == Fraction(4)  # T-to-S scaling 2


def test_listing1_intensities_match_table5():
    g = _listing1_graph()
    construct_functional(g)
    sched = lower_to_structural(g)
    by = {n.name: n for n in sched.nodes}
    ints = sorted(n.intensity() for n in sched.nodes)
    assert ints == [256, 512, 4096]  # Node1, Node0, Node2 (paper Table 5)


def test_intensity_proportional_parallel_factors():
    g = _listing1_graph()
    construct_functional(g)
    sched = lower_to_structural(g)
    pf = parallel_factors(sched, max_pf=32, ia=True)
    vals = {n.name: pf[n.name] for n in sched.nodes}
    node2 = [n for n in sched.nodes if n.intensity() == 4096][0]
    node1 = [n for n in sched.nodes if n.intensity() == 256][0]
    assert vals[node2.name] == 32          # critical node: full factor
    assert vals[node1.name] < vals[node2.name]  # IA scales down
    pf_no_ia = parallel_factors(sched, max_pf=32, ia=False)
    assert all(v == 32 for v in pf_no_ia.values())  # naive: max everywhere


def test_parallelize_respects_divisibility_constraints():
    g = _listing1_graph()
    construct_functional(g)
    sched = lower_to_structural(g)
    res = parallelize(sched, SINGLE_POD, ia=True, ca=True, training=False)
    # Every connected pair must have mutually divisible factors on mapped
    # dims (the CA invariant).
    conns = analyze_connections(sched)
    for c in conns:
        src = sched.node(c.src)
        dst = sched.node(c.dst)
        proj = c.project(src.unroll, from_src=True)
        for d, constr in proj.items():
            uf = dst.unroll.get(d, 1)
            a = constr / uf
            b = Fraction(uf) / constr if constr else Fraction(1)
            assert a.denominator == 1 or b.denominator == 1


def test_estimate_produces_three_terms():
    g = _listing1_graph()
    construct_functional(g)
    sched = lower_to_structural(g)
    parallelize(sched, SINGLE_POD, training=False)
    cost = estimate(sched, SINGLE_POD, training=False)
    assert cost.total_s > 0
    assert cost.critical_s <= cost.total_s
    assert cost.dominant in ("compute", "memory", "collective")


# --------------------------------------------------------------------------
# Node.access_for: merged across body ops (first-owner hazard regression)
# --------------------------------------------------------------------------

def test_access_for_merges_across_body_ops():
    """Two body ops touching the same buffer with complementary maps: the
    merged map must expose *both* ops' dims, not just the first op's
    (returning the first body op's map wholesale silently replicated any
    axis only a later op indexes — the hazard class PR 3 fixed across
    nodes in project_rules, here within one node)."""
    op1 = Op(name="o1", kind="copy", ins=["b"], outs=[],
             loop_dims={"i": 8},
             access={"b": AccessMap.of(("i", 1), (None, 1))})
    op2 = Op(name="o2", kind="compute", ins=["b"], outs=[],
             loop_dims={"j": 8},
             access={"b": AccessMap.of((None, 1), ("j", 1))})
    n = Node(name="n", args={"b": MemoryEffect.READ}, body=[op1, op2])
    am = n.access_for("b")
    assert am.entries == (("i", Fraction(1)), ("j", Fraction(1)))


def test_access_for_conflicting_axis_earliest_op_wins():
    """When two body ops name *different* dims at the same axis the
    earliest body op wins — the deterministic conflict policy (matching
    the old behaviour whenever the first op's map was total)."""
    op1 = Op(name="o1", kind="compute", ins=["b"], outs=[],
             loop_dims={"i": 8},
             access={"b": AccessMap.of(("i", 2), (None, 1))})
    op2 = Op(name="o2", kind="compute", ins=["b"], outs=[],
             loop_dims={"k": 8, "j": 8},
             access={"b": AccessMap.of(("k", 1), ("j", 1))})
    n = Node(name="n", args={"b": MemoryEffect.READ}, body=[op1, op2])
    am = n.access_for("b")
    assert am.entries == (("i", Fraction(2)), ("j", Fraction(1)))
    # Single-map nodes return the map object itself (no copy).
    n_single = Node(name="m", args={"b": MemoryEffect.READ}, body=[op1])
    assert n_single.access_for("b") is op1.access["b"]
    assert n_single.access_for("missing") is None


# --------------------------------------------------------------------------
# topo_order_over: order-preserving de-quadratification
# --------------------------------------------------------------------------

def _reference_topo_order(nodes, edges, name=""):
    """The pre-optimization O(V²) implementation, kept verbatim as the
    order oracle."""
    succ = {n.name: set() for n in nodes}
    indeg = {n.name: 0 for n in nodes}
    for s, d, _ in edges:
        if d not in succ[s]:
            succ[s].add(d)
            indeg[d] += 1
    order = []
    ready = [n for n in nodes if indeg[n.name] == 0]
    while ready:
        n = ready.pop(0)
        order.append(n)
        for m in nodes:
            if m.name in succ[n.name]:
                indeg[m.name] -= 1
                if indeg[m.name] == 0:
                    ready.append(m)
    if len(order) != len(nodes):
        raise ValueError(f"schedule {name} has a dataflow cycle")
    return order


def test_topo_order_matches_reference_on_real_schedule():
    from repro.core.ir import topo_order_over
    from repro.configs import SHAPES, get_config
    from repro.core import build_lm_graph
    from repro.core.balance import balance_paths
    from repro.core.multi_producer import eliminate_multi_producers

    g = build_lm_graph(get_config("smollm-135m"), SHAPES["train_4k"])
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    eliminate_multi_producers(sched)
    balance_paths(sched)
    got = [n.name for n in topo_order_over(sched.nodes, sched.edges())]
    want = [n.name for n in _reference_topo_order(sched.nodes,
                                                  sched.edges())]
    assert got == want
    assert [n.name for n in sched.topo_order()] == want


def test_topo_order_matches_reference_on_diamond():
    from repro.core.ir import topo_order_over

    nodes = [Node(name=f"n{i}") for i in range(6)]
    # diamond + straggler with mixed insertion order
    edges = [("n0", "n2", "a"), ("n0", "n1", "b"), ("n1", "n3", "c"),
             ("n2", "n3", "d"), ("n3", "n4", "e"), ("n0", "n4", "f"),
             ("n5", "n1", "g")]
    got = [n.name for n in topo_order_over(nodes, edges)]
    want = [n.name for n in _reference_topo_order(nodes, edges)]
    assert got == want


def test_topo_order_scales_linearly_on_long_chain():
    """5k-node chain: the rewritten walk is O(V + E log E) and finishes
    in milliseconds; the former per-pop all-nodes rescan took several
    seconds at this size, so the generous 2 s bound is a real regression
    tripwire, not timing noise."""
    import time
    from repro.core.ir import topo_order_over

    n = 5000
    nodes = [Node(name=f"c{i}") for i in range(n)]
    edges = [(f"c{i}", f"c{i+1}", f"b{i}") for i in range(n - 1)]
    t0 = time.perf_counter()
    order = topo_order_over(nodes, edges)
    assert time.perf_counter() - t0 < 2.0
    assert [x.name for x in order] == [f"c{i}" for i in range(n)]


def test_topo_order_still_raises_on_cycle():
    from repro.core.ir import topo_order_over

    nodes = [Node(name="a"), Node(name="b")]
    edges = [("a", "b", "x"), ("b", "a", "y")]
    with pytest.raises(ValueError, match="cycle"):
        topo_order_over(nodes, edges, "cyc")
