"""End-to-end system tests (deliverable c): training improves the loss on
the synthetic corpus with the full substrate engaged, serving decodes
coherently, and the HIDA plan machinery round-trips."""
import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.core import SINGLE_POD, MULTI_POD, build_lm_graph, optimize


@pytest.mark.slow
def test_train_loss_decreases_end_to_end(tmp_path):
    from repro.launch.train import main as train_main
    out = train_main(["--arch", "smollm-135m", "--smoke", "--steps", "40",
                      "--batch", "4", "--seq", "32", "--lr", "3e-3",
                      "--ckpt-every", "0",
                      "--ckpt-dir", str(tmp_path)])
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_serve_generates_tokens():
    from repro.launch.serve import main as serve_main
    out = serve_main(["--arch", "smollm-135m", "--smoke", "--slots", "2",
                      "--requests", "2", "--prompt-len-range", "8", "8",
                      "--gen-range", "4", "4", "--no-plan"])
    c = out["continuous"]
    assert c["requests"] == 2 and c["generated"] == 2 * 4
    assert c["tok_per_s"] > 0


def test_plan_roundtrips_json():
    cfg = get_config("smollm-135m")
    g = build_lm_graph(cfg, SHAPES["train_4k"])
    _, plan, _ = optimize(g, SINGLE_POD)
    import json
    blob = json.loads(plan.to_json())
    assert blob["rules"]["batch"] == ["data"]
    assert blob["mesh"] == [["data", 16], ["model", 16]]


@pytest.mark.slow
def test_every_cell_has_plan():
    """HIDA-OPT must produce a plan for all 40 (arch x shape) cells on
    both meshes without raising (the dry-run compiles them; this guards
    the optimizer itself at test speed)."""
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            for mesh in (SINGLE_POD, MULTI_POD):
                g = build_lm_graph(cfg, shape)
                sched, plan, rep = optimize(
                    g, mesh, training=shape.mode == "train")
                assert plan.rules.get("batch") or shape.global_batch == 1, \
                    (arch, shape_name)
                assert rep.cost.total_s > 0


def test_long_500k_skips_marked():
    for arch in ("smollm-135m", "deepseek-v3-671b", "musicgen-large"):
        ok, why = shape_applicable(get_config(arch), SHAPES["long_500k"])
        assert not ok and "sub-quadratic" in why
    for arch in ("jamba-v0.1-52b", "xlstm-125m", "h2o-danube-3-4b"):
        ok, _ = shape_applicable(get_config(arch), SHAPES["long_500k"])
        assert ok
