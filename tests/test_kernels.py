"""Per-kernel correctness: interpret-mode pallas_call vs pure-jnp oracle,
swept over shapes / dtypes / block sizes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # hypothesis optional in this container
    HAVE_HYPOTHESIS = False

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm_chunk import ops as ml_ops
from repro.kernels.mlstm_chunk.ref import mlstm_ref
from repro.kernels.moe_gmm import ops as gmm_ops
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan.ref import ssd_scan_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# -- flash attention ---------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,KVH,Dh,causal,window,qb,kb", [
    (2, 128, 128, 4, 2, 32, True, None, 64, 64),
    (1, 256, 256, 3, 1, 16, True, 96, 64, 128),     # SWA + MHA-of-3
    (2, 128, 256, 4, 4, 64, False, None, 128, 128),  # cross-attn shape
    (1, 512, 512, 8, 2, 128, True, None, 128, 256),  # MXU-aligned
])
def test_flash_attention_kernel(dtype, B, Sq, Skv, H, KVH, Dh, causal,
                                window, qb, kb):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, Dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, KVH, Dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, KVH, Dh)), dtype)
    got = fa_ops.mha(q, k, v, causal=causal, window=window,
                     q_block=qb, kv_block=kb)
    G = H // KVH
    qr = q.reshape(B, Sq, KVH, G, Dh).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KVH, G, Sq, Dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KVH, Skv, Dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KVH, Skv, Dh)
    want = attention_ref(qr, kr, vr, causal=causal, window=window)
    want = want.reshape(B, KVH, G, Sq, Dh).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sq, H, Dh)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


# -- ssd scan -----------------------------------------------------------------

@pytest.mark.parametrize("B,S,Din,N,chunk,dblk", [
    (2, 64, 16, 4, 16, 8),
    (1, 128, 32, 8, 32, 32),
    (2, 96, 24, 16, 48, 12),
])
def test_ssd_scan_kernel(B, S, Din, N, chunk, dblk):
    x = jnp.asarray(RNG.normal(size=(B, S, Din)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, Din)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(Din, N)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    got = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, d_block=dblk)
    want = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# -- mlstm chunk ---------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Dh,chunk", [
    (2, 32, 2, 16, 8),
    (1, 64, 4, 32, 16),
    (2, 48, 1, 8, 48),     # single chunk == full parallel form
])
def test_mlstm_chunk_kernel(B, S, H, Dh, chunk):
    q = jnp.asarray(RNG.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, H, Dh)), jnp.float32)
    i_pre = jnp.asarray(RNG.normal(size=(B, S, H)), jnp.float32)
    f_pre = jnp.asarray(RNG.normal(size=(B, S, H)) + 2.0, jnp.float32)
    got = ml_ops.mlstm_chunk(q, k, v, i_pre, f_pre, chunk=chunk)

    def tok(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    want = mlstm_ref(tok(q), tok(k), tok(v),
                     i_pre.transpose(0, 2, 1).reshape(B * H, S),
                     f_pre.transpose(0, 2, 1).reshape(B * H, S))
    want = want.reshape(B, H, S, Dh).transpose(0, 2, 1, 3).reshape(
        B, S, H * Dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# -- moe gmm --------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F,cb,fb,db", [
    (4, 32, 64, 128, 16, 64, 32),
    (8, 64, 32, 64, 64, 64, 32),
])
def test_moe_gmm_kernel(dtype, E, C, D, F, cb, fb, db):
    x = jnp.asarray(RNG.normal(size=(E, C, D)), dtype)
    w = jnp.asarray(RNG.normal(size=(E, D, F)) * 0.1, dtype)
    gs = jnp.asarray(RNG.integers(0, C + 1, size=(E,)), jnp.int32)
    got = gmm_ops.moe_gmm(x, w, gs, c_block=cb, f_block=fb, d_block=db)
    want = moe_gmm_ref(x, w, gs)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


# -- rmsnorm --------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("R,D,rb", [(64, 128, 16), (32, 96, 32)])
def test_rmsnorm_kernel(dtype, R, D, rb):
    x = jnp.asarray(RNG.normal(size=(R, D)), dtype)
    s = jnp.asarray(RNG.normal(size=(D,)) + 1.0, jnp.float32)
    got = rms_ops.rmsnorm(x, s, row_block=rb)
    want = rmsnorm_ref(x, s)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


# -- property-based sweeps (hypothesis) -----------------------------------------

if HAVE_HYPOTHESIS:
    @given(
        b=st.integers(1, 3), nq=st.integers(1, 4), nk=st.integers(1, 4),
        kvh=st.sampled_from([1, 2]), g=st.sampled_from([1, 2, 3]),
        dh=st.sampled_from([8, 16]), causal=st.booleans(),
    )
    @settings(max_examples=15, deadline=None)
    def test_flash_attention_property(b, nq, nk, kvh, g, dh, causal):
        Sq, Skv = nq * 32, nk * 32
        if causal and Skv < Sq:
            Skv = Sq
        H = kvh * g
        rng = np.random.default_rng(b * 1000 + nq * 100 + nk)
        q = jnp.asarray(rng.normal(size=(b, Sq, H, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, Skv, kvh, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, Skv, kvh, dh)), jnp.float32)
        got = fa_ops.mha(q, k, v, causal=causal, q_block=32, kv_block=32)
        qr = q.reshape(b, Sq, kvh, g, dh).transpose(0, 2, 3, 1, 4) \
            .reshape(b * kvh, g, Sq, dh)
        kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, Skv, dh)
        vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, Skv, dh)
        want = attention_ref(qr, kr, vr, causal=causal).reshape(
            b, kvh, g, Sq, dh).transpose(0, 3, 1, 2, 4).reshape(
            b, Sq, H, dh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)

    @given(n=st.integers(1, 6), din=st.sampled_from([8, 16]),
           nstate=st.sampled_from([2, 4]))
    @settings(max_examples=10, deadline=None)
    def test_ssd_scan_property(n, din, nstate):
        B, S = 1, n * 16
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.normal(size=(B, S, din)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, din)),
                         jnp.float32)
        A = -jnp.asarray(rng.uniform(0.3, 2.0, size=(din, nstate)),
                         jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, nstate)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, nstate)), jnp.float32)
        got = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16, d_block=din)
        want = ssd_scan_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
