"""Substrate tests: data pipeline, optimizer, gradient compression,
checkpoint/restart, elastic resharding, straggler policy (deliverable c)."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ShardedLoader, SyntheticCorpus
from repro.distributed import (CheckpointManager, StragglerMonitor,
                               gather_full_tree, reshard_checkpoint)
from repro.optim import AdamW, cosine_schedule
from repro.optim.compression import (EFState, compress, decompress,
                                     ef_compress_tree, ef_decompress_tree,
                                     init_ef_state)


# -- data ---------------------------------------------------------------------

def test_corpus_deterministic_and_host_disjoint():
    c = SyntheticCorpus(vocab=1024, seed=7)
    a = c.batch(step=3, shard=0, batch=4, seq=16)
    b = c.batch(step=3, shard=0, batch=4, seq=16)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    other = c.batch(step=3, shard=1, batch=4, seq=16)
    assert not np.array_equal(a["tokens"], other["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_loader_shards_partition_global_batch():
    c = SyntheticCorpus(vocab=64, seed=1)
    loaders = [ShardedLoader(c, global_batch=8, seq=8, n_hosts=4, host_id=h)
               for h in range(4)]
    batches = [ld.batch_at(0) for ld in loaders]
    assert all(b["tokens"].shape == (2, 8) for b in batches)
    # Elastic re-partition keeps determinism per (step, shard)
    re = loaders[0].reshard(n_hosts=2, host_id=1)
    assert re.batch_at(5)["tokens"].shape == (4, 8)


def test_loader_prefetch_iterator():
    c = SyntheticCorpus(vocab=64)
    ld = ShardedLoader(c, global_batch=4, seq=8)
    it = iter(ld)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ld.batch_at(0)["tokens"])


# -- optimizer -------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([2.0, -3.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_adamw_abstract_init_matches_concrete():
    opt = AdamW(moment_dtype="bf16")
    params = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((3,))}
    abs_params = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    st_c = opt.init(params)
    st_a = opt.init(abs_params)
    for c, a in zip(jax.tree.leaves(st_c), jax.tree.leaves(st_a)):
        assert c.shape == a.shape and c.dtype == a.dtype


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup=10, total=100)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(100)) < 0.01


# -- gradient compression ----------------------------------------------------------

def test_ef_compression_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, scale, resid = compress(g, jnp.zeros_like(g))
    deq = decompress(q, scale)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) + 1e-6
    # residual holds exactly the rounding error
    np.testing.assert_allclose(np.asarray(deq + resid), np.asarray(g),
                               rtol=1e-6, atol=1e-6)


def test_ef_feedback_corrects_bias_over_steps():
    """With error feedback the *accumulated* compressed sum tracks the
    accumulated true sum far better than memoryless quantization."""
    rng = np.random.default_rng(1)
    gs = [jnp.asarray(rng.normal(size=(64,)) * 1e-3, jnp.float32)
          for _ in range(50)]
    state = init_ef_state(gs[0])
    acc_ef = np.zeros(64)
    acc_nofb = np.zeros(64)
    resid = jnp.zeros((64,))
    for g in gs:
        q, s, resid = compress(g, resid)
        acc_ef += np.asarray(decompress(q, s))
        q2, s2, _ = compress(g, jnp.zeros((64,)))
        acc_nofb += np.asarray(decompress(q2, s2))
    true = np.sum([np.asarray(g) for g in gs], axis=0)
    assert np.abs(acc_ef - true).max() < np.abs(acc_nofb - true).max() + 1e-9


def test_ef_tree_roundtrip():
    grads = {"a": jnp.ones((8,)), "b": jnp.full((4,), -2.0)}
    state = init_ef_state(grads)
    q, s, new_state = ef_compress_tree(grads, state)
    deq = ef_decompress_tree(q, s)
    for k in grads:
        np.testing.assert_allclose(np.asarray(deq[k]),
                                   np.asarray(grads[k]), rtol=0.02)


# -- checkpoint / restart ------------------------------------------------------------

def test_checkpoint_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"p": jnp.arange(12.0).reshape(3, 4),
            "opt": {"m": jnp.ones((3, 4)) * 0.5}}
    mgr.save(10, tree, blocking=True)
    assert mgr.latest_step() == 10
    restored = mgr.restore(10, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_commit_ignores_partial(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"p": jnp.zeros((2,))}
    mgr.save(5, tree, blocking=True)
    # Simulate a torn write: directory without COMMITTED marker.
    (tmp_path / "step_000009").mkdir()
    assert mgr.latest_step() == 5


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"p": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.steps() == [3, 4]


@pytest.mark.slow
def test_train_resume_bitwise(tmp_path):
    """Kill at step 6, restart, and verify the loss trajectory matches an
    uninterrupted run (checkpoint/restart fault tolerance)."""
    from repro.launch.train import main as train_main
    common = ["--arch", "smollm-135m", "--smoke", "--steps", "10",
              "--batch", "2", "--seq", "16", "--ckpt-every", "3"]
    ref = train_main(common + ["--ckpt-dir", str(tmp_path / "a")])
    out1 = train_main(common + ["--ckpt-dir", str(tmp_path / "b"),
                                "--simulate-preemption-at", "7"])
    assert out1.get("preempted_at") == 7
    out2 = train_main(common + ["--ckpt-dir", str(tmp_path / "b")])
    assert out2["resumed_from"] == 6
    np.testing.assert_allclose(out2["losses"][-1], ref["losses"][-1],
                               rtol=1e-4)


# -- elastic --------------------------------------------------------------------------

def test_elastic_reshard_checkpoint(tmp_path):
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr = CheckpointManager(tmp_path / "src", host_id=0, n_hosts=1)
    mgr.save(2, tree, blocking=True)
    reshard_checkpoint(tmp_path / "src", 2, tree, new_n_hosts=2,
                       dst_dir=tmp_path / "dst")
    for h in range(2):
        m2 = CheckpointManager(tmp_path / "dst", host_id=h, n_hosts=2)
        got = m2.restore(2, jax.tree.map(jnp.zeros_like, tree))
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))


# -- straggler -------------------------------------------------------------------------

def test_straggler_detection_and_rebalance():
    mon = StragglerMonitor(n_hosts=4, ema=0.5, threshold=1.4,
                           evict_after=5)
    actions = []
    for step in range(10):
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 3.0}   # host 3 is slow
        actions += mon.step(times)
    assert any(a["action"] == "rebalance" and a["host"] == 3
               for a in actions)
    assert any(a["action"] == "checkpoint_and_evict" and a["host"] == 3
               for a in actions)
    w = mon.shard_weights()
    assert w[3] < w[0]          # slow host gets a smaller shard


def test_straggler_recovery_clears_flag():
    mon = StragglerMonitor(n_hosts=2, ema=0.1, threshold=1.5)
    for _ in range(5):
        mon.step({0: 1.0, 1: 3.0})
    assert mon.stragglers() == [1]
    for _ in range(30):
        mon.step({0: 1.0, 1: 1.0})
    assert mon.stragglers() == []


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    """build_train_step(accum_steps=K) must produce (numerically) the
    same update as the full-batch step on a dense arch."""
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.core import MeshSpec, build_lm_graph, optimize
    from repro.launch.mesh import make_host_mesh, set_mesh
    from repro.launch.steps import build_train_step
    from repro.data import SyntheticCorpus

    cfg = get_config("smollm-135m", smoke=True)
    shape = ShapeSpec("t", 16, 4, "train")
    mspec = MeshSpec((("data", 1), ("model", 1)))
    g = build_lm_graph(cfg, shape)
    _, plan, _ = optimize(g, mspec, training=True)
    mesh = make_host_mesh((1, 1))
    corpus = SyntheticCorpus(cfg.vocab)
    batch = {k: jnp.asarray(v) for k, v in
             corpus.batch(0, 0, 4, 16).items()}

    outs = {}
    with set_mesh(mesh):
        for accum in (1, 2):
            step = build_train_step(cfg, shape, mesh, plan, remat="none",
                                    accum_steps=accum)
            from repro.models.lm import LM
            from repro.optim import AdamW
            lm = LM(cfg, plan=plan, mesh=mesh, remat="none")
            params, _ = lm.init(jax.random.PRNGKey(0))
            opt_state = AdamW(
                moment_dtype=cfg.opt_moment_dtype).init(params)
            p2, _, metrics = step.fn(params, opt_state, batch)
            outs[accum] = p2
    for a, b in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[2])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)
