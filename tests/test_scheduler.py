"""Continuous-batching scheduler invariants.

The load-bearing contract is **row independence**: a request's token
stream must be byte-identical whether it is decoded alone
(:func:`decode_offline` — scalar cache positions, batch 1, no padding,
no gating) or streamed through the batcher (vector positions, per-slot
scatter writes, admit/evict churn, arbitrary co-tenants).  Everything
the serving path does — slot reuse, shape-bucketed batched prefill,
active-slot gating, per-request RNG streams — is only legal because
this equality holds.

Also pinned here: EOS/budget eviction, slot reuse beyond the batch
width, determinism in the seed, per-request RNG stream independence,
the MoE refusal, and the serve driver's metrics plumbing.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs import get_config                              # noqa: E402
from repro.launch.scheduler import (ContinuousBatcher, Request,   # noqa: E402
                                    decode_offline, prefill_bucket,
                                    run_static)

S_MAX = 96


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m", smoke=True)
    from repro.models.lm import LM
    lm = LM(cfg, remat="none")
    params, _ = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _trace(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        pl = int(rng.integers(3, 14))
        gen = int(rng.integers(4, 12))
        temp = 0.0 if i % 2 else 0.7
        prompt = rng.integers(0, cfg.vocab, pl).astype(np.int32)
        out.append((prompt, gen, temp))
    return out


def _run(cfg, lm, params, trace, *, slots=3, seed=0, eos_id=None,
         max_steps=None):
    b = ContinuousBatcher(lm, params, slots=slots, s_max=S_MAX, seed=seed,
                          eos_id=eos_id)
    for prompt, gen, temp in trace:
        b.submit(prompt, gen, temperature=temp)
    rep = b.run(max_steps=max_steps)
    return rep


def test_prefill_bucket():
    assert prefill_bucket(1) == 16
    assert prefill_bucket(16) == 16
    assert prefill_bucket(17) == 32
    assert prefill_bucket(33, minimum=8) == 64


def test_streamed_tokens_match_offline(served):
    """The headline invariant: admit/evict streaming == per-request
    offline decode, token for token, greedy and sampled alike."""
    cfg, lm, params = served
    rep = _run(cfg, lm, params, _trace(cfg))
    assert len(rep.requests) == 6
    for r in rep.requests:
        assert r.finish == "length" and len(r.out) == r.max_new
        ref = decode_offline(lm, params, r, seed=0, s_max=S_MAX)
        assert r.out == ref, f"rid {r.rid}: {r.out} != {ref}"


def test_slot_reuse_and_occupancy(served):
    cfg, lm, params = served
    trace = _trace(cfg, n=7)
    rep = _run(cfg, lm, params, trace, slots=2)
    assert len(rep.requests) == 7          # 7 requests through 2 slots
    assert 0.0 < rep.occupancy <= 1.0
    assert rep.generated == sum(gen for _, gen, _ in trace)
    d = rep.to_dict()
    assert d["tok_per_s"] > 0 and d["latency_p99_s"] >= d["latency_p50_s"]


def test_eos_evicts_early(served):
    cfg, lm, params = served
    base = _run(cfg, lm, params, _trace(cfg))
    # pick a token the longest request actually emits mid-stream and
    # replay with it as EOS: the stream must cut exactly there.
    victim = max(base.requests, key=lambda r: len(r.out))
    eos = victim.out[1]
    rep = _run(cfg, lm, params, _trace(cfg), eos_id=eos)
    for r in rep.requests:
        ref = decode_offline(lm, params, r, seed=0, s_max=S_MAX,
                             eos_id=eos)
        assert r.out == ref
        if eos in r.out:
            assert r.out.index(eos) == len(r.out) - 1   # stops at EOS
            assert r.finish in ("eos", "length")


def test_budget_eviction_terminates(served):
    cfg, lm, params = served
    rep = _run(cfg, lm, params, _trace(cfg), max_steps=3)
    assert rep.steps <= 3
    assert any(r.finish == "budget" for r in rep.requests)


def test_deterministic_in_seed(served):
    cfg, lm, params = served
    a = _run(cfg, lm, params, _trace(cfg), seed=7)
    b = _run(cfg, lm, params, _trace(cfg), seed=7)
    assert [r.out for r in a.requests] == [r.out for r in b.requests]
    c = _run(cfg, lm, params, _trace(cfg), seed=8)
    sampled = [r for r in c.requests if r.temperature > 0]
    assert [r.out for r in sampled] != \
        [r.out for r in a.requests if r.temperature > 0]


def test_request_streams_independent(served):
    """Sampling draws are keyed per (request, position): the same
    request decodes identically with different co-tenants."""
    cfg, lm, params = served
    full = _run(cfg, lm, params, _trace(cfg))
    solo_trace = _trace(cfg)[:1]
    solo = _run(cfg, lm, params, solo_trace, slots=1)
    assert solo.requests[0].out == full.requests[0].out


def test_moe_configs_refused():
    cfg = get_config("jamba-v0.1-52b", smoke=True)
    from repro.models.lm import LM
    lm = LM(cfg, remat="none")
    with pytest.raises(ValueError, match="MoE|capacity"):
        ContinuousBatcher(lm, None, slots=2, s_max=S_MAX)


def test_static_baseline_counts_useful_tokens(served):
    cfg, lm, params = served
    trace = _trace(cfg)
    reqs = [Request(rid=i, prompt_len=len(p), max_new=g, prompt=p,
                    temperature=t, t_submit=0.0)
            for i, (p, g, t) in enumerate(trace)]
    rep = run_static(lm, params, reqs, seed=0, s_max=S_MAX, slots=3)
    assert rep.generated == sum(g for _, g, _ in trace)
    assert 0.0 < rep.occupancy <= 1.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["xlstm-125m", "musicgen-large",
                                  "llama-3.2-vision-11b"])
def test_streamed_tokens_match_offline_all_frontends(arch):
    """Same invariant across recurrent (xLSTM), audio-frame, and
    vision frontends — exercises frames/img_embeds routing through the
    bucketed group prefill and the gated decode."""
    cfg = get_config(arch, smoke=True)
    from repro.models.lm import LM
    lm = LM(cfg, remat="none")
    params, _ = lm.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(lm, params, slots=2, s_max=S_MAX, seed=3)
    rng = np.random.default_rng(1)
    for i in range(4):
        pl = int(rng.integers(3, 12))
        prompt = (None if cfg.frontend == "audio_frames"
                  else rng.integers(0, cfg.vocab, pl).astype(np.int32))
        b.submit(prompt, int(rng.integers(3, 8)), prompt_len=pl,
                 temperature=0.6 if i % 2 else 0.0)
    rep = b.run()
    for r in rep.requests:
        ref = decode_offline(lm, params, r, seed=3, s_max=S_MAX)
        assert r.out == ref, f"{arch} rid {r.rid}"


def test_serve_main_metrics(tmp_path):
    from repro.launch.serve import main
    m = main(["--arch", "smollm-135m", "--smoke", "--slots", "2",
              "--requests", "4", "--prompt-len-range", "3", "10",
              "--gen-range", "3", "6", "--static",
              "--plan-cache", str(tmp_path)])
    assert m["plan"]["source"] == "cold"
    assert m["continuous"]["tok_per_s"] > 0
    assert m["static"]["tok_per_s"] > 0
    assert m["continuous"]["requests"] == 4
    # second invocation: the persisted plan is a hit
    m2 = main(["--arch", "smollm-135m", "--smoke", "--slots", "2",
               "--requests", "4", "--prompt-len-range", "3", "10",
               "--gen-range", "3", "6",
               "--plan-cache", str(tmp_path)])
    assert m2["plan"]["source"] == "hit"
    assert m2["plan"]["fetch_ms"] < 50
