"""Beam-search DSE invariants (see ``repro.core.parallelize``).

The contracts under test:

* **Beam ≥ greedy, everywhere** — on every registered model config and
  every PolyBench graph, the beam search's final QoR is at least as good
  as the converged greedy coordinate descent it is seeded with.  This is
  structural (the greedy state is always in the beam and is restored when
  nothing beats it), so the assertion is exact, not approximate.
* **Beam subsumes the deprecated ``seed_uniform`` escape hatch** — the
  beam's uniform-family seeding plus refinement must match or beat the
  legacy path on the schedules it was added for (coordination lock-in).
* **propose/rollback is a true transaction** — after a rollback every
  piece of the estimator's internal cached state is bit-identical to what
  it was before the propose, not just the aggregate totals.
* **Graph-colored sweeps are plan-identical to serial sweeps** — the
  level-scheduled batch evaluation (serial or thread-pooled) commits the
  same plan as strictly in-order coordinate descent.
"""
from __future__ import annotations

import random
import sys
import warnings
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.common import POLYBENCH
from repro.configs import SHAPES, get_config, list_archs
from repro.core import (SINGLE_POD, build_lm_graph, construct_functional,
                        fuse_tasks, lower_to_structural, optimize)
from repro.core.balance import balance_paths
from repro.core.incremental import IncrementalEstimator
from repro.core.multi_producer import eliminate_multi_producers
from repro.core.parallelize import _proposals, parallelize


def _lowered_model(arch):
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    eliminate_multi_producers(sched)
    balance_paths(sched)
    return sched


def _lowered_pb(name):
    g = POLYBENCH[name]()
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    eliminate_multi_producers(sched)
    balance_paths(sched)
    return sched


def _plan_snapshot(sched):
    return {i: (sorted(n.unroll.items()),
                sorted((d, tuple(a)) for d, a in n.axis_map.items()))
            for i, n in enumerate(sched.nodes) if n.unroll or n.axis_map}


# -- beam QoR >= greedy QoR on every registered config ----------------------

@pytest.mark.parametrize("arch", list_archs())
def test_beam_qor_at_least_greedy_models(arch):
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    _sched, _plan, rep = optimize(g, SINGLE_POD)
    res = rep.parallelize
    assert res.greedy_total_s > 0
    assert rep.cost.total_s <= res.greedy_total_s


@pytest.mark.parametrize("name", sorted(POLYBENCH))
def test_beam_qor_at_least_greedy_polybench(name):
    g = POLYBENCH[name]()
    _sched, _plan, rep = optimize(g, SINGLE_POD, training=False)
    res = rep.parallelize
    assert rep.cost.total_s <= res.greedy_total_s


# -- beam subsumes the deprecated seed_uniform escape hatch ------------------

@pytest.mark.parametrize("arch", ["xlstm-125m", "smollm-135m",
                                  "smollm-360m"])
def test_beam_subsumes_seed_uniform(arch):
    """The configs the escape hatch existed for: coordination lock-in,
    where no single-node move can leave the all-unsharded basin.  The
    beam must match or beat the legacy result without the hatch."""
    beam = parallelize(_lowered_model(arch), SINGLE_POD, training=True)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = parallelize(_lowered_model(arch), SINGLE_POD,
                             training=True, beam_width=1, seed_uniform=True)
    assert beam.cost.total_s <= legacy.cost.total_s
    # And the beam must genuinely escape the greedy basin here.
    if arch in ("xlstm-125m", "smollm-135m"):
        assert beam.cost.total_s < beam.greedy_total_s


def test_seed_uniform_emits_deprecation_warning():
    sched = _lowered_pb("2mm")
    with pytest.warns(DeprecationWarning, match="seed_uniform"):
        parallelize(sched, SINGLE_POD, training=False, seed_uniform=False)


# -- propose/rollback leaves the estimator state bit-identical ---------------

def _full_state(est: IncrementalEstimator):
    """Every cached term plus the node objects' assignment state."""
    return (
        list(est._comp), list(est._mem), list(est._nbytes), list(est._red),
        list(est._sync), list(est._reshard), list(est._contrib),
        list(est._lat),
        [(dict(n.unroll), dict(n.axis_map)) for n in est._nodes],
    )


@pytest.mark.parametrize("arch,training", [
    ("stablelm-3b", True), ("jamba-v0.1-52b", False)])
def test_propose_rollback_state_bit_identical(arch, training):
    sched = _lowered_model(arch)
    est = IncrementalEstimator(sched, SINGLE_POD, training=training)
    rng = random.Random(99)
    per_node = {n.name: _proposals(n, SINGLE_POD, SINGLE_POD.chips)
                for n in sched.nodes}
    names = [n.name for n in sched.nodes if per_node[n.name]]
    for step in range(40):
        # Occasionally commit so rollbacks are exercised from many states.
        name = rng.choice(names)
        if rng.random() < 0.3:
            est.apply(name, rng.choice(per_node[name]))
        before = _full_state(est)
        est.propose(name, rng.choice(per_node[name]))
        est.rollback()
        assert _full_state(est) == before


# -- graph-colored sweeps == serial sweeps ----------------------------------

@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "xlstm-125m",
                                  "deepseek-v2-236b"])
def test_colored_sweep_matches_serial_models(arch):
    s_colored = _lowered_model(arch)
    r_colored = parallelize(s_colored, SINGLE_POD, training=True)
    s_serial = _lowered_model(arch)
    r_serial = parallelize(s_serial, SINGLE_POD, training=True,
                           colored_sweeps=False)
    s_threaded = _lowered_model(arch)
    r_threaded = parallelize(s_threaded, SINGLE_POD, training=True,
                             sweep_workers=4)
    assert _plan_snapshot(s_colored) == _plan_snapshot(s_serial)
    assert _plan_snapshot(s_colored) == _plan_snapshot(s_threaded)
    assert (r_colored.cost.total_s == r_serial.cost.total_s
            == r_threaded.cost.total_s)


@pytest.mark.parametrize("name", sorted(POLYBENCH))
def test_colored_sweep_matches_serial_polybench(name):
    s_colored = _lowered_pb(name)
    r_colored = parallelize(s_colored, SINGLE_POD, training=False)
    s_serial = _lowered_pb(name)
    r_serial = parallelize(s_serial, SINGLE_POD, training=False,
                           colored_sweeps=False)
    assert _plan_snapshot(s_colored) == _plan_snapshot(s_serial)
    assert r_colored.cost.total_s == r_serial.cost.total_s
