"""Model-zoo tests: per-arch smoke (deliverable f), decode-vs-parallel
consistency for every sequence-mixer family, and sub-block oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs
from repro.models import LM
from repro.models.ssm import selective_scan_assoc, selective_scan_seq
from repro.models.xlstm import _mlstm_parallel, MLSTMState

RNG = jax.random.PRNGKey(0)


def _batch_for(cfg, B, S, rng=RNG):
    batch = {"labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.d_model),
                                            jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    if cfg.frontend == "vision":
        batch["img_embeds"] = jax.random.normal(
            rng, (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    return batch


# --------------------------------------------------------------------------
# Per-arch smoke: one train step on a reduced config (deliverable f)
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    lm = LM(cfg, remat="none")
    params, dims = lm.init(RNG)
    batch = _batch_for(cfg, B=2, S=16)

    def step(p, b):
        loss, metrics = lm.loss_fn(p, b)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(step))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # grads finite and same structure
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    assert jax.tree.structure(grads) == jax.tree.structure(params)


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_decode_shapes(arch):
    cfg = get_config(arch, smoke=True)
    lm = LM(cfg, remat="none")
    params, _ = lm.init(RNG)
    B, S_max = 2, 8
    caches = lm.init_caches(B, S_max)
    batch = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(RNG, (B, 1, cfg.d_model),
                                            jnp.bfloat16)
    else:
        batch["tokens"] = jnp.zeros((B, 1), jnp.int32)
    if cfg.frontend == "vision":
        batch["img_embeds"] = jax.random.normal(
            RNG, (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    logits, new_caches = jax.jit(lm.decode_step)(params, batch, caches)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


# --------------------------------------------------------------------------
# Decode ≡ teacher-forced forward, per mixer family
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [
    "smollm-135m",        # GQA + RoPE
    "h2o-danube-3-4b",    # sliding window
    "stablelm-3b",        # MHA + partial rotary + LN
    "deepseek-v2-236b",   # MLA absorbed decode
    "jamba-v0.1-52b",     # Mamba state + attention interleave + MoE
    "xlstm-125m",         # mLSTM/sLSTM states
    "musicgen-large",     # audio frontend
])
@pytest.mark.slow
def test_decode_matches_parallel(arch):
    cfg = get_config(arch, smoke=True)
    lm = LM(cfg, remat="none")
    params, _ = lm.init(RNG)
    B, S = 2, 12
    batch = _batch_for(cfg, B, S)
    full = np.asarray(jax.jit(lm.logits_fn)(params, batch), np.float32)

    caches = lm.init_caches(B, S)
    step = jax.jit(lm.decode_step)
    outs = []
    for t in range(S):
        sb = {"pos": jnp.asarray(t, jnp.int32)}
        if cfg.frontend == "audio_frames":
            sb["frames"] = batch["frames"][:, t:t + 1]
        else:
            sb["tokens"] = batch["tokens"][:, t:t + 1]
        if cfg.frontend == "vision":
            sb["img_embeds"] = batch["img_embeds"]
        logits, caches = step(params, sb, caches)
        outs.append(np.asarray(logits[:, 0], np.float32))
    stepped = np.stack(outs, axis=1)
    # bf16 params + different reduction orders → loose numeric tolerance,
    # but structural bugs (position off-by-one) blow way past this.
    np.testing.assert_allclose(stepped, full, atol=0.25, rtol=0.1)
    agree = np.mean(stepped.argmax(-1) == full.argmax(-1))
    assert agree > 0.9


# --------------------------------------------------------------------------
# Sequence-mixer oracles
# --------------------------------------------------------------------------

def test_selective_scan_chunked_matches_seq():
    from repro.models.ssm import selective_scan_chunked
    rng = np.random.default_rng(5)
    B, S, D, N = 2, 96, 8, 4
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, D)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(D, N)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y1 = selective_scan_chunked(x, dt, A, Bm, Cm, chunk=16)
    y2, _ = selective_scan_seq(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_selective_scan_assoc_matches_seq():
    rng = np.random.default_rng(0)
    B, S, D, N = 2, 33, 8, 4
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, D)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(D, N)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y1 = selective_scan_assoc(x, dt, A, Bm, Cm)
    y2, _ = selective_scan_seq(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_parallel_matches_steps():
    rng = np.random.default_rng(1)
    B, S, H, Dh = 2, 9, 2, 4
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    i_pre = jnp.asarray(rng.normal(size=(B, S, H)), jnp.float32)
    f_pre = jnp.asarray(rng.normal(size=(B, S, H)) + 2.0, jnp.float32)
    y_par = np.asarray(_mlstm_parallel(q, k, v, i_pre, f_pre))

    # Step-by-step matrix-memory recurrence (the decode form).
    C = np.zeros((B, H, Dh, Dh), np.float32)
    n = np.zeros((B, H, Dh), np.float32)
    m = np.full((B, H), -np.inf, np.float32)
    ys = []
    qn, kn, vn = map(np.asarray, (q, k, v))
    for t in range(S):
        logf = np.asarray(jax.nn.log_sigmoid(f_pre[:, t]))
        it = np.asarray(i_pre[:, t])
        m_new = np.maximum(logf + m, it)
        fg = np.exp(logf + m - m_new)
        ig = np.exp(it - m_new)
        kt = kn[:, t] / np.sqrt(Dh)
        C = fg[..., None, None] * C + ig[..., None, None] * (
            kt[..., :, None] * vn[:, t][..., None, :])
        n = fg[..., None] * n + ig[..., None] * kt
        num = np.einsum("bhd,bhde->bhe", qn[:, t], C)
        den = np.maximum(np.abs(np.einsum("bhd,bhd->bh", qn[:, t], n)),
                         np.exp(-m_new))[..., None]
        ys.append(num / (den + 1e-6))
        m = m_new
    y_step = np.stack(ys, axis=1)
    np.testing.assert_allclose(y_par, y_step, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------

def test_moe_dispatch_indices_invariants():
    from repro.models.moe import dispatch_indices
    rng = np.random.default_rng(2)
    T, K, E, cap = 64, 2, 8, 24
    idx = jnp.asarray(rng.integers(0, E, size=(T, K)))
    eid, slot, keep = dispatch_indices(idx, E, cap)
    eid, slot, keep = map(np.asarray, (eid, slot, keep))
    assert (slot[keep] < cap).all()
    # No two kept assignments share (expert, slot).
    pairs = set()
    for e, s, k in zip(eid, slot, keep):
        if k:
            assert (e, s) not in pairs
            pairs.add((e, s))
    # Per-expert kept counts == min(assigned, capacity).
    for e in range(E):
        assigned = int((eid == e).sum())
        kept = int(((eid == e) & keep).sum())
        assert kept == min(assigned, cap)


@pytest.mark.slow
def test_moe_matches_dense_reference_when_no_drop():
    """With capacity ≥ T·K the sort-based dispatch must equal the
    brute-force dense (every-expert) weighted combination."""
    from repro.models.moe import moe_ffn, router_topk
    from repro.models.layers import ParamBuilder
    cfg = get_config("deepseek-v2-236b", smoke=True)
    cfg = type(cfg)(**{**cfg.__dict__,
                       "moe": type(cfg.moe)(
                           n_experts=4, top_k=2, n_shared=0, d_expert=16,
                           capacity_factor=8.0)})
    pb = ParamBuilder(RNG)
    from repro.models.moe import init_moe
    init_moe(pb, "m", cfg)
    p = pb.params["m"]
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    out, aux = moe_ffn(x, p, cfg, lambda t, d, s=None: t)
    assert float(aux.dropped_fraction) == 0.0

    xt = x.reshape(-1, cfg.d_model)
    gate, idx, _ = router_topk(xt, p["w_router"], cfg.moe)
    ref = np.zeros((xt.shape[0], cfg.d_model), np.float32)
    for e in range(cfg.moe.n_experts):
        h = np.einsum("td,dgf->tgf", np.asarray(xt, np.float32),
                      np.asarray(p["w_in"][e], np.float32))
        act = np.asarray(jax.nn.silu(h[..., 0, :])) * h[..., 1, :]
        oe = act @ np.asarray(p["w_out"][e], np.float32)
        w = np.zeros(xt.shape[0], np.float32)
        for kk in range(cfg.moe.top_k):
            w += np.where(np.asarray(idx[:, kk]) == e,
                          np.asarray(gate[:, kk], np.float32), 0)
        ref += w[:, None] * oe
    got = np.asarray(out.reshape(-1, cfg.d_model), np.float32)
    # bf16 expert compute vs f32 reference: tolerance scaled to the O(30)
    # output magnitude.
    np.testing.assert_allclose(got, ref, rtol=0.1, atol=0.25)


def test_cross_entropy_matches_naive():
    from repro.models.layers import cross_entropy
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(4, 8, 32)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 32, size=(4, 8)))
    got = float(cross_entropy(logits, labels, z_loss=0.0))
    p = jax.nn.log_softmax(logits, axis=-1)
    ref = -float(jnp.mean(jnp.take_along_axis(
        p, labels[..., None], axis=-1)))
    assert abs(got - ref) < 1e-5
