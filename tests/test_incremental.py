"""Incremental QoR engine: exact equivalence with the batch estimator, and
DSE determinism of the rewritten parallelizer.

The contract under test (see ``repro.core.incremental``):

* ``IncrementalEstimator`` is **bit-identical** to the batch
  ``estimate()`` — not approximately equal — on every model config and
  PolyBench graph, for any state reachable through propose / commit /
  rollback (the integer terms are delta-maintained exactly; every float
  reduction re-runs in batch order).
* The read-only ``score()`` path (what the DSE scans and the graph-colored
  sweeps rely on) returns exactly what propose → read → rollback would.
* ``parallelize()`` on top of it is deterministic: golden plan snapshots,
  originally captured from the pre-refactor batch-scored DSE and
  re-validated under the beam-search DSE (the beam reproduces the greedy
  plans where greedy was already optimal; ``smollm-360m`` and
  ``xlstm-125m`` pin plans only the beam's joint moves find).
"""
from __future__ import annotations

import random
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.common import POLYBENCH
from repro.configs import SHAPES, get_config, list_archs
from repro.core import (SINGLE_POD, build_lm_graph, construct_functional,
                        estimate, fuse_tasks, lower_to_structural, optimize)
from repro.core.balance import balance_paths
from repro.core.incremental import IncrementalEstimator
from repro.core.multi_producer import eliminate_multi_producers
from repro.core.parallelize import _proposals, parallelize


def _cost_tuple(cost):
    return (
        cost.total_s, cost.critical_s, cost.reshard_bytes, cost.sync_bytes,
        cost.hbm_bytes_per_device,
        [(name, c.compute_s, c.memory_s, c.collective_s)
         for name, c in cost.nodes.items()],
    )


def _assert_exact(est: IncrementalEstimator, sched, mesh, training):
    batch = estimate(sched, mesh, training=training)
    inc = est.schedule_cost()
    assert _cost_tuple(inc) == _cost_tuple(batch)
    assert est.total_s == batch.total_s
    assert est.critical_s == batch.critical_s
    assert est.hbm_bytes_per_device == batch.hbm_bytes_per_device


def _lowered(graph):
    construct_functional(graph)
    fuse_tasks(graph)
    sched = lower_to_structural(graph)
    eliminate_multi_producers(sched)
    balance_paths(sched)
    return sched


@pytest.mark.parametrize("arch", list_archs())
def test_incremental_matches_batch_on_optimized_model(arch):
    """After a full optimize() the engine's final cost is bit-identical to
    a fresh batch estimate of the chosen assignment."""
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    sched, _plan, rep = optimize(g, SINGLE_POD)
    batch = estimate(sched, SINGLE_POD, training=True)
    assert _cost_tuple(rep.cost) == _cost_tuple(batch)
    est = IncrementalEstimator(sched, SINGLE_POD, training=True)
    _assert_exact(est, sched, SINGLE_POD, training=True)


@pytest.mark.parametrize("name", sorted(POLYBENCH))
def test_incremental_matches_batch_on_polybench(name):
    g = POLYBENCH[name]()
    sched, _plan, rep = optimize(g, SINGLE_POD, training=False)
    batch = estimate(sched, SINGLE_POD, training=False)
    assert _cost_tuple(rep.cost) == _cost_tuple(batch)
    est = IncrementalEstimator(sched, SINGLE_POD, training=False)
    _assert_exact(est, sched, SINGLE_POD, training=False)


@pytest.mark.parametrize("arch,training", [
    ("smollm-135m", True), ("stablelm-3b", True),
    ("deepseek-v2-236b", True), ("jamba-v0.1-52b", False),
])
def test_propose_commit_rollback_sequences(arch, training):
    """Drive the engine through a long randomized propose/commit/rollback
    walk; the cached state must stay bit-identical to a batch re-estimate
    at every step, and rollback must restore the pre-proposal totals."""
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    sched = _lowered(g)
    est = IncrementalEstimator(sched, SINGLE_POD, training=training)
    _assert_exact(est, sched, SINGLE_POD, training)

    rng = random.Random(1234)
    per_node = {n.name: _proposals(n, SINGLE_POD, SINGLE_POD.chips)
                for n in sched.nodes}
    names = [n.name for n in sched.nodes if per_node[n.name]]
    for step in range(60):
        name = rng.choice(names)
        proposal = rng.choice(per_node[name])
        before = est.total_s
        scored = est.score(name, proposal)
        est.propose(name, proposal)
        # score() is bit-identical to propose + read, with no mutation.
        assert scored.total_s == est.total_s
        assert scored.hbm_bytes == est.hbm_bytes_per_device
        assert scored.node_compute_s == est.node_compute_s(name)
        assert scored.node_parallel_factor == est.node_parallel_factor(name)
        if rng.random() < 0.5:
            est.rollback()
            assert est.total_s == before
        else:
            est.commit()
        if step % 10 == 0:
            _assert_exact(est, sched, SINGLE_POD, training)
    _assert_exact(est, sched, SINGLE_POD, training)


def test_double_propose_rejected():
    g = POLYBENCH["2mm"]()
    sched = _lowered(g)
    est = IncrementalEstimator(sched, SINGLE_POD, training=False)
    node = sched.nodes[0]
    prop = _proposals(node, SINGLE_POD, SINGLE_POD.chips)[0]
    est.propose(node.name, prop)
    with pytest.raises(RuntimeError):
        est.propose(node.name, prop)
    est.rollback()
    with pytest.raises(RuntimeError):
        est.rollback()


def test_refresh_resyncs_after_external_mutation():
    """Mutating node state behind the engine's back then refresh()ing must
    land in the same state as building a fresh engine."""
    g = POLYBENCH["3mm"]()
    sched = _lowered(g)
    est = IncrementalEstimator(sched, SINGLE_POD, training=False)
    for n in sched.nodes:
        props = _proposals(n, SINGLE_POD, SINGLE_POD.chips)
        if props:
            n.axis_map = dict(props[-1])
            n.unroll = {d: 16 * len(a) for d, a in props[-1].items()}
    est.refresh()
    _assert_exact(est, sched, SINGLE_POD, training=False)


# -- DSE determinism: golden plan snapshots ---------------------------------
#
# Each entry: run key -> {node index: (sorted unroll items,
# sorted (dim, axes) items)}; nodes with an empty assignment are omitted.
# smollm-135m / stablelm-3b were captured from the batch-scored
# parallelizer immediately before the incremental rewrite and survive the
# beam-search DSE unchanged (the beam keeps the greedy plan when nothing
# beats it).  smollm-360m and xlstm-125m were captured from the beam DSE:
# both need a joint move (uniform seed / neighbourhood re-DSE) that the
# greedy coordinate descent cannot reach (same configs, SINGLE_POD,
# train_4k).

_B, _S = ("batch", 16), ("seq", 16)
_BD, _SM = ("batch", ("data",)), ("seq", ("model",))
_GOLDEN = {
    ("smollm-135m", True, True): {
        i: ([_B, _S], [_BD, _SM]) for i in range(6)},
    ("smollm-135m", True, False): {
        i: ([_B, _S], [_BD, _SM]) for i in range(6)},
    ("smollm-135m", False, True): {
        i: ([_B, _S], [_BD, _SM]) for i in range(6)},
    ("stablelm-3b", True, True): {
        0: ([_B, _S], [_BD, _SM]),
        1: ([_B, ("kv_heads", 16)], [_BD, ("kv_heads", ("model",))]),
        2: ([_B, _S], [_BD, _SM]),
        3: ([_B, ("d_model", 16)], [_BD, ("d_model", ("model",))]),
        4: ([_B, ("d_ff", 16)], [_BD, ("d_ff", ("model",))]),
        5: ([_B, ("d_model", 16)], [_BD, ("d_model", ("model",))]),
        6: ([_B, ("vocab", 16)], [_BD, ("vocab", ("model",))]),
    },
    # Beam-only plans: the KV-cache update picks SP over kv_seq to stay
    # axis-aligned with attention (a producer/consumer joint choice).
    ("smollm-360m", True, True): {
        0: ([_B, _S], [_BD, _SM]),
        1: ([_B, ("kv_seq", 16)], [_BD, ("kv_seq", ("model",))]),
        **{i: ([_B, _S], [_BD, _SM]) for i in range(2, 7)},
    },
    # Coordination lock-in: greedy leaves the mLSTM chain unsharded
    # (431ms); only a uniform joint move reaches the SP basin (20.4ms).
    ("xlstm-125m", True, True): {
        **{i: ([_B, _S], [_BD, _SM]) for i in range(10)},
        10: ([_B], [_BD]),
        11: ([_B], [_BD]),
        12: ([_B, ("vocab", 16)], [_BD, ("vocab", ("model",))]),
        13: ([_B], [_BD]),
    },
}

_GOLDEN_PB = {
    "2mm": {0: ([("i", 16), ("j", 16)],
                [("i", ("data",)), ("j", ("model",))]),
            1: ([("i", 16), ("l", 16)],
                [("i", ("data",)), ("l", ("model",))])},
    "correlation": {1: ([("l", 256)], [("l", ("data", "model"))])},
}


def _plan_snapshot(sched):
    out = {}
    for i, n in enumerate(sched.nodes):
        if n.unroll or n.axis_map:
            out[i] = (sorted(n.unroll.items()),
                      sorted((d, tuple(a)) for d, a in n.axis_map.items()))
    return out


@pytest.mark.parametrize("arch,ia,ca", sorted(_GOLDEN))
def test_parallelize_golden_plans_models(arch, ia, ca):
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    sched, _plan, _rep = optimize(g, SINGLE_POD, ia=ia, ca=ca)
    assert _plan_snapshot(sched) == _GOLDEN[(arch, ia, ca)]


@pytest.mark.parametrize("name", sorted(_GOLDEN_PB))
def test_parallelize_golden_plans_polybench(name):
    g = POLYBENCH[name]()
    sched, _plan, _rep = optimize(g, SINGLE_POD, training=False)
    assert _plan_snapshot(sched) == _GOLDEN_PB[name]


def test_parallelize_direct_matches_optimize_cost():
    """parallelize()'s incremental final cost equals a batch estimate when
    called standalone (not through optimize)."""
    g = build_lm_graph(get_config("smollm-360m"), SHAPES["train_4k"])
    sched = _lowered(g)
    res = parallelize(sched, SINGLE_POD, training=True)
    batch = estimate(sched, SINGLE_POD, training=True)
    assert _cost_tuple(res.cost) == _cost_tuple(batch)
