"""Never-fail ``optimize()``: fault-injection chaos sweep, verifier
units, and checkpoint-corruption round-trips.

Four contracts:

1. **Chaos sweep** — under deterministic fault injection at every
   registered site, ``optimize()`` (a) never raises, (b) always returns
   a verifier-clean plan, (c) never returns a plan worse than the best
   uniform assignment on the schedule it produced (the QoR floor), and
   (d) reports what it degraded.  A small seed×config subset runs in the
   fast lane; the full sweep is ``slow``.

2. **Zero-fault bit-identity** — entering the injection context with
   ``rate=0`` must not perturb the pipeline: final plans stay
   bit-identical to the pinned goldens (``tests/goldens/pre_dse``).

3. **Verifier units** — hand-corrupted plans trip the precise
   machine-readable code (wrong axis owner → ``spec-incoherent``,
   over-capacity rule → ``rule-capacity``, backwards stage map →
   ``stage-order``, explicit HBM budget → ``hbm-overflow``), and a
   clean ``optimize()`` product verifies with zero issues.

4. **Checkpoint corruption** — a bit-flipped committed shard fails CRC
   on ``restore``, ``restore_latest`` walks back to the previous
   committed step, a background-save failure re-raises on ``wait()``,
   and ``gather_full_tree`` refuses partial / shard-missing steps.
"""
import json

import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.core import (SINGLE_POD, best_uniform, build_lm_graph, optimize,
                        verify)
from repro.core.faults import (FaultInjector, InjectedFault, active_injector,
                               fault_point, inject_faults)
from repro.core.ir import reset_fresh_names
from repro.core.plan import _projected_spec
from repro.distributed.checkpoint import (CheckpointCorruptionError,
                                          CheckpointManager)
from repro.distributed.elastic import gather_full_tree
from repro.distributed.straggler import StragglerMonitor

from golden_utils import build_final_plan, golden_path

FAST_CHAOS = [("smollm-135m", 0), ("smollm-135m", 1),
              ("xlstm-125m", 2), ("stablelm-3b", 3)]
SLOW_CHAOS = [(a, s)
              for a in ("smollm-360m", "h2o-danube-3-4b",
                        "jamba-v0.1-52b", "musicgen-large")
              for s in range(3)]


# --------------------------------------------------------------------------
# Injector mechanics
# --------------------------------------------------------------------------

def test_fault_point_is_noop_outside_context():
    assert active_injector() is None
    fault_point("dse.node")      # must not raise


def test_injection_is_deterministic_per_seed():
    def run(seed):
        reset_fresh_names()
        g = build_lm_graph(get_config("smollm-135m"), SHAPES["train_4k"])
        with inject_faults(seed=seed, rate=0.08, corrupt_rate=0.05) as inj:
            optimize(g, SINGLE_POD)
        return [(r.site, r.kind) for r in inj.records]

    assert run(7) == run(7)
    assert run(7) != run(8)      # distinct seeds draw distinct traces


def test_site_filter_restricts_firing():
    inj = FaultInjector(seed=0, rate=1.0, sites=("dse.*",))
    with pytest.raises(InjectedFault):
        inj.fire("dse.node")
    inj2 = FaultInjector(seed=0, rate=1.0, sites=("plan.*",))
    inj2.fire("dse.node")        # not armed -> no raise
    assert not inj2.records


def test_nested_injection_contexts_refused():
    with inject_faults(seed=0, rate=0.0):
        with pytest.raises(RuntimeError):
            with inject_faults(seed=1, rate=0.0):
                pass


# --------------------------------------------------------------------------
# 1. Chaos sweep: optimize() never raises, always legal, QoR-floored
# --------------------------------------------------------------------------

def _chaos_run(arch, seed, sites=("*",)):
    # Vary the rate with the seed: high rates exercise the early
    # fallbacks (lowering dies → single-node schedule), low rates let
    # the pipeline run deep and fail late (beam / plan / verify rungs).
    rate = (0.08, 0.03, 0.015)[seed % 3]
    reset_fresh_names()
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    with inject_faults(seed=seed, rate=rate, corrupt_rate=0.05,
                       sites=sites) as inj:
        sched, plan, rep = optimize(g, SINGLE_POD)

    # (b) the returned plan is verifier-clean (optimize ran the verifier
    # itself; re-run independently to make sure the report is honest).
    assert rep.verify is not None and rep.verify.ok, rep.verify.summary()
    vrep = verify(sched, plan, SINGLE_POD)
    assert vrep.ok, vrep.summary()
    assert vrep.checks > 0

    # (d) raised faults always surface as degradations.
    if any(r.kind == "raise" for r in inj.records):
        assert rep.degradations

    # (c) QoR floor: never worse than the best uniform assignment on the
    # schedule optimize() actually returned.
    assert rep.cost is not None
    saved = {n.name: (dict(n.axis_map), dict(n.unroll))
             for n in sched.nodes}
    _, ucost = best_uniform(sched, SINGLE_POD)
    for n in sched.nodes:
        n.axis_map, n.unroll = saved[n.name]
    assert rep.cost.total_s <= ucost.total_s * (1 + 1e-9), \
        f"{rep.cost.total_s} worse than uniform floor {ucost.total_s}"
    return rep


@pytest.mark.parametrize("arch,seed", FAST_CHAOS)
def test_chaos_sweep_fast(arch, seed):
    _chaos_run(arch, seed)


@pytest.mark.slow
@pytest.mark.parametrize("arch,seed", SLOW_CHAOS)
def test_chaos_sweep_full(arch, seed):
    _chaos_run(arch, seed)


@pytest.mark.parametrize("seed", (0, 1))
def test_chaos_sweep_dse_and_plan_only(seed):
    """Restrict injection to the DSE and plan layers so the pre-DSE
    passes run clean: the late ladder rungs (beam snapshot restore, QoR
    floor, plan rebuild, exit verify) get a real multi-node schedule
    instead of the single-node lowering fallback."""
    rep = _chaos_run("smollm-135m", seed, sites=("dse.*", "plan.*"))
    assert not rep.degraded("construct") and not rep.degraded("lower")


# --------------------------------------------------------------------------
# 1b. Hierarchical DSE chaos lane: the dse.inner / dse.outer rungs
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", (0, 1, 2))
def test_chaos_sweep_hierarchical_dse_sites(seed):
    """Injection restricted to the two-level DSE's own sites: the
    pre-DSE passes run clean, and every exit is verifier-clean and
    QoR-floored (asserted inside ``_chaos_run``)."""
    rep = _chaos_run("xlstm-125m", seed, sites=("dse.inner", "dse.outer"))
    assert not rep.degraded("construct") and not rep.degraded("lower")


def test_inner_failure_degrades_only_hit_regions():
    """``seed=0, rate=0.5`` deterministically kills two of xlstm's four
    region inner searches.  The hit regions pin to their greedy entry;
    the others keep their full entry lists — an inner failure never
    degrades the whole schedule."""
    reset_fresh_names()
    g = build_lm_graph(get_config("xlstm-125m"), SHAPES["train_4k"])
    with inject_faults(seed=0, rate=0.5, sites=("dse.inner",)) as inj:
        sched, plan, rep = optimize(g, SINGLE_POD)
    res = rep.parallelize
    assert res.dse_mode == "hierarchical" and res.regions == 4
    assert len(inj.fired("dse.inner")) == 2
    hit = [s for s in res.region_summaries if s.degraded]
    clean = [s for s in res.region_summaries if not s.degraded]
    assert len(hit) == 2 and len(clean) == 2
    for s in hit:
        assert "InjectedFault" in s.degraded
        assert [e.origin for e in s.entries] == ["greedy"]
    # Containment: the un-hit regions still ran their full inner search.
    assert any(len(s.entries) > 1 for s in clean)
    # Each region failure surfaces as its own dse degradation.
    msgs = [d.error for d in rep.degradations if d.stage == "dse"]
    assert sum("inner DSE failed on region" in m for m in msgs) == 2
    assert rep.verify is not None and rep.verify.ok
    assert rep.cost.total_s <= res.greedy_total_s * (1 + 1e-9)


def test_all_inner_failures_still_optimize_via_outer():
    """``rate=1.0`` on ``dse.inner``: every region is pinned to its
    (synthesized) greedy entry, yet the outer level still composes and
    seeds the global uniform family — the result keeps the beam
    invariant and the uniform QoR floor."""
    reset_fresh_names()
    g = build_lm_graph(get_config("xlstm-125m"), SHAPES["train_4k"])
    with inject_faults(seed=0, rate=1.0, sites=("dse.inner",)) as inj:
        sched, plan, rep = optimize(g, SINGLE_POD)
    res = rep.parallelize
    assert res.dse_mode == "hierarchical"
    assert len(inj.fired("dse.inner")) == res.regions
    assert all(s.degraded for s in res.region_summaries)
    assert all([e.origin for e in s.entries] == ["greedy"]
               for s in res.region_summaries)
    assert rep.verify is not None and rep.verify.ok
    assert rep.cost.total_s <= res.greedy_total_s * (1 + 1e-9)
    saved = {n.name: (dict(n.axis_map), dict(n.unroll))
             for n in sched.nodes}
    _, ucost = best_uniform(sched, SINGLE_POD)
    for n in sched.nodes:
        n.axis_map, n.unroll = saved[n.name]
    assert rep.cost.total_s <= ucost.total_s * (1 + 1e-9)


def test_outer_failure_restores_pre_failure_snapshot():
    """``rate=1.0`` on ``dse.outer`` kills the composition level at
    entry: the inner summaries survive untouched, the beam-phase error
    boundary restores the best pre-failure snapshot, and the exit is
    verifier-clean."""
    reset_fresh_names()
    g = build_lm_graph(get_config("xlstm-125m"), SHAPES["train_4k"])
    with inject_faults(seed=0, rate=1.0, sites=("dse.outer",)) as inj:
        sched, plan, rep = optimize(g, SINGLE_POD)
    res = rep.parallelize
    assert inj.fired("dse.outer")
    assert res.dse_mode == "hierarchical"
    assert all(not s.degraded for s in res.region_summaries)
    assert any("beam phase failed" in d.error
               for d in rep.degradations if d.stage == "dse")
    assert rep.verify is not None and rep.verify.ok
    assert rep.cost.total_s <= res.greedy_total_s * (1 + 1e-9)


def test_budget_expiry_still_returns_clean_plan():
    """A one-microsecond budget forces the anytime path everywhere; the
    result must still be a complete, verifier-clean plan."""
    reset_fresh_names()
    g = build_lm_graph(get_config("smollm-135m"), SHAPES["train_4k"])
    sched, plan, rep = optimize(g, SINGLE_POD, budget_s=1e-6)
    assert rep.verify is not None and rep.verify.ok
    assert rep.cost is not None
    assert rep.degraded("dse")


# --------------------------------------------------------------------------
# 2. Zero-fault path is bit-identical to the goldens
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ("smollm-135m", "xlstm-125m"))
def test_zero_rate_injection_is_bit_identical(arch):
    golden = json.loads(golden_path(arch).read_text())["plan"]
    with inject_faults(seed=0, rate=0.0, corrupt_rate=0.0) as inj:
        plan = build_final_plan(arch)
    assert not inj.records
    assert json.loads(plan.to_json()) == golden


# --------------------------------------------------------------------------
# 3. Verifier units: hand-corrupted plans trip the precise code
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def optimized():
    reset_fresh_names()
    g = build_lm_graph(get_config("smollm-135m"), SHAPES["train_4k"])
    return optimize(g, SINGLE_POD)


def test_clean_product_verifies(optimized):
    sched, plan, rep = optimized
    vrep = verify(sched, plan, SINGLE_POD)
    assert vrep.ok and not vrep.issues, vrep.summary()
    assert vrep.checks > 0
    assert rep.verify_s >= 0


def test_wrong_axis_owner_trips_spec_incoherent(optimized):
    sched, plan, _ = optimized
    topo = sched.topology()
    bname = next(b for b in plan.buffer_specs
                 if b in sched.buffers and topo.owners(b))
    want = _projected_spec(plan.rules, topo.axis_dims[bname])
    spec = list(plan.buffer_specs[bname])
    spec[0] = ("model",) if tuple(want[0]) != ("model",) else ("data",)
    original = plan.buffer_specs[bname]
    plan.buffer_specs[bname] = tuple(spec)
    try:
        vrep = verify(sched, plan, SINGLE_POD, coherent=True)
        assert "spec-incoherent" in vrep.codes()
        assert not vrep.ok
    finally:
        plan.buffer_specs[bname] = original


def test_over_capacity_rule_trips_rule_capacity(optimized):
    sched, plan, _ = optimized
    plan.rules["__bogus_dim__"] = ("data", "data")
    try:
        vrep = verify(sched, plan, SINGLE_POD)
        assert "rule-capacity" in vrep.codes()
        assert not vrep.ok
    finally:
        del plan.rules["__bogus_dim__"]


def test_backwards_stage_map_trips_stage_order(optimized):
    sched, plan, _ = optimized
    src, dst, _b = next(iter(sched.topology().edges))
    s, d = sched.node(src), sched.node(dst)
    saved = (s.stage, d.stage)
    s.stage, d.stage = 5, 1
    try:
        vrep = verify(sched, plan, SINGLE_POD)
        assert "stage-order" in vrep.codes()
        assert not vrep.ok
    finally:
        s.stage, d.stage = saved


def test_cyclic_dataflow_trips_topology_cycle():
    from repro.core.ir import Buffer, MemoryEffect, Node, Schedule
    from repro.core.plan import replicated_plan

    sched = Schedule(name="cyclic")
    for b in ("b1", "b2"):
        sched.buffers[b] = Buffer(name=b, shape=(4, 4), dtype="float32")
    sched.nodes.append(Node(name="n1", args={"b2": MemoryEffect.READ,
                                             "b1": MemoryEffect.WRITE}))
    sched.nodes.append(Node(name="n2", args={"b1": MemoryEffect.READ,
                                             "b2": MemoryEffect.WRITE}))
    vrep = verify(sched, replicated_plan(SINGLE_POD), SINGLE_POD)
    assert "topology-cycle" in vrep.codes()
    assert not vrep.ok


def test_explicit_hbm_budget_makes_overflow_an_error(optimized):
    sched, plan, _ = optimized
    vrep = verify(sched, plan, SINGLE_POD, hbm_capacity_bytes=1)
    assert "hbm-overflow" in vrep.codes()
    assert not vrep.ok


def test_unknown_axis_in_spec_trips_axis_unknown(optimized):
    sched, plan, _ = optimized
    bname = next(b for b in plan.buffer_specs if b in sched.buffers)
    original = plan.buffer_specs[bname]
    plan.buffer_specs[bname] = (("warp",),) + tuple(original[1:])
    try:
        vrep = verify(sched, plan, SINGLE_POD, coherent=False)
        assert "axis-unknown" in vrep.codes()
    finally:
        plan.buffer_specs[bname] = original


# --------------------------------------------------------------------------
# 4. Checkpoint corruption + distributed guard rails
# --------------------------------------------------------------------------

def _tree(scale=1.0):
    return {"w": np.arange(32, dtype=np.float32).reshape(4, 8) * scale,
            "b": np.ones(8, np.float32) * scale}


def test_corrupt_shard_fails_crc_and_restore_latest_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, host_id=0, n_hosts=1)
    mgr.save(10, _tree(1.0), blocking=True)
    mgr.save(20, _tree(2.0), blocking=True)

    shard = tmp_path / "step_000020" / "shard_h000.npz"
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    shard.write_bytes(bytes(raw))

    with pytest.raises(CheckpointCorruptionError):
        mgr.restore(20, _tree())

    step, got = mgr.restore_latest(_tree())
    assert step == 10
    np.testing.assert_array_equal(got["w"], _tree(1.0)["w"])


def test_all_steps_corrupt_raises(tmp_path):
    mgr = CheckpointManager(tmp_path, host_id=0, n_hosts=1)
    mgr.save(10, _tree(), blocking=True)
    shard = tmp_path / "step_000010" / "shard_h000.npz"
    shard.write_bytes(b"garbage")
    with pytest.raises(CheckpointCorruptionError):
        mgr.restore_latest(_tree())


def test_background_save_error_reraised_on_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(tmp_path, host_id=0, n_hosts=1)

    def boom(*a, **k):
        raise OSError("disk on fire")

    monkeypatch.setattr("repro.distributed.checkpoint.np.savez", boom)
    mgr.save(10, _tree(), blocking=False)
    with pytest.raises(OSError, match="disk on fire"):
        mgr.wait()
    # the error is consumed: the next wait is clean
    mgr.wait()


def test_gather_full_tree_validates_commit_and_shards(tmp_path):
    for h in range(2):
        CheckpointManager(tmp_path, host_id=h, n_hosts=2).save(
            5, _tree(), blocking=True)

    d = tmp_path / "step_000005"
    (d / "shard_h001.npz").unlink()
    with pytest.raises(ValueError, match=r"hosts \[1\] are missing"):
        gather_full_tree(tmp_path, 5, _tree())

    (d / "COMMITTED").unlink()
    with pytest.raises(ValueError, match="not committed"):
        gather_full_tree(tmp_path, 5, _tree())


def test_shard_weights_cover_unseen_and_zero_hosts():
    mon = StragglerMonitor(n_hosts=4)
    mon.record({0: 1.0, 1: 2.0})
    w = mon.shard_weights()
    assert set(w) == {0, 1, 2, 3}
    assert abs(sum(w.values()) - 1.0) < 1e-12
    # unseen hosts run at fleet-median speed, not zero share
    assert w[2] == w[3] > 0
    assert w[0] > w[1]

    mon2 = StragglerMonitor(n_hosts=2, ema=0.0)
    mon2.record({0: 0.0, 1: 1.0})
    w2 = mon2.shard_weights()        # no ZeroDivisionError
    assert w2[0] > w2[1]
