"""Multi-device tests run in subprocesses (the suite itself must see one
device; XLA locks the device count at first jax import).

Covers: (a) a reduced-mesh dry-run — lower+compile the real train step on
a (4,2) mesh with a HIDA plan, collectives present; (b) the GPipe
pipeline runtime over a 4-way stage axis vs the sequential oracle;
(c) shard_map EP MoE vs the global oracle on a (2,2) mesh."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(n_devices: int, code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_dryrun_reduced_mesh_compiles():
    out = _run(8, """
        import jax
        from repro.configs import get_config
        from repro.configs.base import ShapeSpec
        from repro.core import MeshSpec, build_lm_graph, optimize
        from repro.launch.steps import build_train_step
        from repro.launch.hlo_analysis import collective_bytes
        from repro.launch.mesh import set_mesh

        cfg = get_config("smollm-135m")
        shape = ShapeSpec("t", 512, 16, "train")
        mspec = MeshSpec((("data", 4), ("model", 2)))
        g = build_lm_graph(cfg, shape)
        sched, plan, rep = optimize(g, mspec, training=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with set_mesh(mesh):
            step = build_train_step(cfg, shape, mesh, plan)
            compiled = step.fn.lower(*step.abstract_inputs).compile()
        stats = collective_bytes(compiled.as_text())
        assert stats.total_bytes > 0, "expected collectives on a 4x2 mesh"
        mem = compiled.memory_analysis()
        print("OK", stats.count_by_kind, mem.temp_size_in_bytes)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_gpipe_pipeline_matches_sequential():
    out = _run(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.pipeline import PipelineConfig, gpipe

        S, M, B, D = 4, 6, 2, 8
        mesh = jax.make_mesh((S,), ("pod",))
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
        mb = jnp.asarray(rng.normal(size=(M, B, D)), jnp.float32)

        def stage_fn(w, x, sid):
            return jnp.tanh(x @ w)

        run = gpipe(stage_fn, PipelineConfig(S, M), mesh, None, None)
        got = np.asarray(run(Ws, mb))

        ref = mb
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-5,
                                   atol=1e-5)
        print("OK pipeline")
    """)
    assert "OK pipeline" in out


@pytest.mark.slow
def test_ep_moe_matches_global():
    out = _run(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import set_mesh
        from repro.models.moe import moe_ffn, moe_ffn_ep
        from repro.models.layers import ParamBuilder
        from repro.models.moe import init_moe

        cfg = get_config("deepseek-v2-smoke" if False else
                         "deepseek-v2-236b", smoke=True)
        # dropless regime so local-vs-global capacity enforcement agrees
        object.__setattr__(cfg.moe, "capacity_factor", 8.0)
        pb = ParamBuilder(jax.random.PRNGKey(0))
        init_moe(pb, "m", cfg)
        p = pb.params["m"]
        B, S, D = 4, 8, cfg.d_model
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D),
                              jnp.float32).astype(jnp.bfloat16)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        ref, aux_ref = moe_ffn(x, p, cfg, lambda t, d, s=None: t)
        with set_mesh(mesh):
            got, aux = jax.jit(lambda x, p: moe_ffn_ep(
                x, p, cfg, ("data",), ("model",), (), mesh))(x, p)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            rtol=0.1, atol=0.25)
        print("OK ep moe", float(aux.dropped_fraction))
    """)
    assert "OK ep moe" in out
