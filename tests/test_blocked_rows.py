"""Property tests for the blocked closure rows (``repro.core.rewrite``).

The region reachability index stores closure rows as sparse maps of
64-bit word blocks (``{block_index: word}``).  Three independent
implementations of the same closure must agree bit-for-bit:

1. the **blocked** builder ``_closure_rows`` (production),
2. the **dense-int** builder ``_closure_rows_int`` (the previous
   representation, kept as the differential oracle),
3. a **from-scratch per-node DFS** written here, sharing no code with
   either.

On top of the pure-function sweep, a rewrite sweep fuses random legal
pairs of a random-DAG dispatch region with ``selfcheck=True`` (so the
session itself asserts maintained == fresh after every rewrite) and
cross-checks the *maintained* rows against the int oracle, then rolls
back and asserts the index fingerprint is restored bit-exactly.  A
dedicated test drives the rare vanished-edge path (a multi-produced
value) on a ≥1k-task region and checks the epoch-bumping rebuild.
"""
import random

import pytest

from repro.core.ir import Graph, Op, make_dispatch, make_task, \
    reset_fresh_names
from repro.core.rewrite import (GraphRewriteSession, _bits,
                                _build_region_index, _closure_rows,
                                _closure_rows_int, _row_bits, _row_bytes,
                                _row_count, _row_fold, _row_from_int,
                                _row_has, _row_intersects, _row_or, _row_set,
                                _row_to_int, default_region_bounds,
                                dse_regions, region_index_bytes,
                                region_index_fingerprint)

_WORD = (1 << 64) - 1


# --------------------------------------------------------------------------
# Row primitives vs. plain int-bitmask semantics
# --------------------------------------------------------------------------

def _random_mask(rng, nbits):
    return rng.getrandbits(nbits)


@pytest.mark.parametrize("seed", range(5))
def test_row_primitives_match_int_semantics(seed):
    rng = random.Random(seed)
    for nbits in (1, 17, 63, 64, 65, 128, 200, 400):
        a_i, b_i = _random_mask(rng, nbits), _random_mask(rng, nbits)
        a, b = _row_from_int(a_i), _row_from_int(b_i)
        # round trip + no zero blocks ever stored
        assert _row_to_int(a) == a_i and _row_to_int(b) == b_i
        assert all(w != 0 for w in a.values())
        assert _row_to_int(_row_or(a, b)) == a_i | b_i
        assert _row_count(a) == a_i.bit_count()
        assert _row_bytes(a) == 8 * len(a)
        assert _row_intersects(a, b) == bool(a_i & b_i)
        assert sorted(_row_bits(a)) == sorted(_bits(a_i))
        for p in (0, nbits // 2, nbits - 1):
            assert _row_has(a, p) == bool(a_i >> p & 1)
            assert _row_to_int(_row_set(dict(a), p)) == a_i | 1 << p


@pytest.mark.parametrize("seed", range(5))
def test_row_fold_matches_int_semantics(seed):
    rng = random.Random(100 + seed)
    for nbits in (2, 64, 65, 190):
        m = _random_mask(rng, nbits)
        add_i = _random_mask(rng, nbits)
        p1, p2 = rng.randrange(nbits), rng.randrange(nbits)
        row = _row_from_int(m)
        folded = _row_fold(row, p1, p2, _row_from_int(add_i))
        expect = (m & ~(1 << p1) & ~(1 << p2)) | add_i
        assert _row_to_int(folded) == expect
        assert all(w != 0 for w in folded.values())
        # fold allocates; the input row is treated as immutable
        assert _row_to_int(row) == m


# --------------------------------------------------------------------------
# Closure: blocked == dense-int == from-scratch DFS
# --------------------------------------------------------------------------

def _random_dag_masks(rng, n, p):
    succ = [0] * n
    pred = [0] * n
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < p:
                succ[i] |= 1 << j
                pred[j] |= 1 << i
    return succ, pred

def _dfs_reach(n, succ):
    """Independent oracle: plain per-node DFS over int adjacency."""
    out = []
    for i in range(n):
        seen = set()
        work = list(_bits(succ[i]))
        while work:
            j = work.pop()
            if j not in seen:
                seen.add(j)
                work.extend(_bits(succ[j]))
        seen.discard(i)
        out.append(seen)
    return out


def _check_closures(n, succ_i, pred_i):
    succ_b = [_row_from_int(m) for m in succ_i]
    pred_b = [_row_from_int(m) for m in pred_i]
    reach_b, rreach_b = _closure_rows(n, succ_b, pred_b)
    reach_i, rreach_i = _closure_rows_int(n, succ_i, pred_i)
    dfs = _dfs_reach(n, succ_i)
    for i in range(n):
        assert _row_to_int(reach_b[i]) == reach_i[i]
        assert _row_to_int(rreach_b[i]) == rreach_i[i]
        assert reach_i[i] == sum(1 << j for j in dfs[i])
    rr_dfs = [set() for _ in range(n)]
    for i in range(n):
        for j in dfs[i]:
            rr_dfs[j].add(i)
    for i in range(n):
        assert rreach_i[i] == sum(1 << j for j in rr_dfs[i])


@pytest.mark.parametrize("seed", range(4))
def test_closure_blocked_equals_int_equals_dfs(seed):
    rng = random.Random(1000 + seed)
    for n, p in ((1, 0.5), (5, 0.5), (63, 0.1), (64, 0.1), (65, 0.1),
                 (130, 0.05), (257, 0.02)):
        _check_closures(n, *_random_dag_masks(rng, n, p))


def test_closure_cycle_fallback_agrees():
    """Degenerate (cyclic) input takes the per-node DFS fallback in both
    builders; they must still agree — including across a block boundary."""
    rng = random.Random(7)
    n = 140
    succ, pred = _random_dag_masks(rng, n, 0.04)
    # a 3-cycle spanning blocks 0/1/2
    for i, j in ((10, 70), (70, 133), (133, 10)):
        succ[i] |= 1 << j
        pred[j] |= 1 << i
    succ_b = [_row_from_int(m) for m in succ]
    pred_b = [_row_from_int(m) for m in pred]
    reach_b, rreach_b = _closure_rows(n, succ_b, pred_b)
    reach_i, rreach_i = _closure_rows_int(n, succ, pred)
    for i in range(n):
        assert _row_to_int(reach_b[i]) == reach_i[i]
        assert _row_to_int(rreach_b[i]) == rreach_i[i]
    # the cycle members reach each other both ways
    assert reach_i[10] >> 70 & 1 and reach_i[133] >> 10 & 1


# --------------------------------------------------------------------------
# Maintained index vs. int oracle across a random fuse sweep
# --------------------------------------------------------------------------

def _leaf(name, ins, outs):
    return Op(name=name, kind="matmul", ins=ins, outs=outs,
              loop_dims={"i": 8}, flops=8)


def _dag_dispatch(rng, n, p):
    """A dispatch whose task DAG mirrors a random int DAG exactly: task
    ``i`` produces the unique value ``v{i}`` and reads one value per
    predecessor edge (plus the external ``x`` so rootless tasks stay
    legal)."""
    succ, pred = _random_dag_masks(rng, n, p)
    tasks = []
    for i in range(n):
        ins = [f"v{j}" for j in _bits(pred[i])] or ["x"]
        tasks.append(make_task([_leaf(f"t{i}", ins, [f"v{i}"])]))
    d = make_dispatch(tasks)
    return Graph("g", ops=[d]), d


def _maintained_matches_int_oracle(idx):
    """Flatten the live maintained rows into the dense bit-space and
    rebuild the closure with the int oracle; every live reach/rreach row
    must match bit-for-bit."""
    nbits = len(idx.by_bit)
    succ_i = [0] * nbits
    pred_i = [0] * nbits
    for tid, b in idx.bit.items():
        succ_i[b] = _row_to_int(idx.succ[tid])
        pred_i[b] = _row_to_int(idx.pred[tid])
    reach_i, rreach_i = _closure_rows_int(nbits, succ_i, pred_i)
    for tid, b in idx.bit.items():
        assert _row_to_int(idx.reach[tid]) == reach_i[b]
        assert _row_to_int(idx.rreach[tid]) == rreach_i[b]


@pytest.mark.parametrize("seed", range(3))
def test_fuse_sweep_maintained_rows_match_int_oracle(seed):
    reset_fresh_names()
    rng = random.Random(2000 + seed)
    g, d = _dag_dispatch(rng, 180, 0.04)
    rs = GraphRewriteSession(g, selfcheck=True)  # maintained == fresh per fuse
    idx = rs._ensure_region(d)
    before = region_index_fingerprint(idx)
    for _ in range(40):
        pairs = [(a, b) for a, b in rs.adjacent_pairs(d)
                 if not rs.creates_cycle(d, a, b)]
        if not pairs:
            break
        rs.fuse(d, *pairs[rng.randrange(len(pairs))])
        _maintained_matches_int_oracle(rs._ensure_region(d))
    assert region_index_fingerprint(rs._ensure_region(d)) != before
    rs.rollback()
    assert region_index_fingerprint(rs._ensure_region(d)) == before


# --------------------------------------------------------------------------
# Vanished-edge epoch rebuild at ≥1k tasks
# --------------------------------------------------------------------------

def test_vanished_edge_rebuilds_and_bumps_epoch_at_1k_tasks():
    """An edge into ``second`` through a value ``first`` also produces
    vanishes under fusion (needs a multi-produced value); the session
    must detect it, rebuild the index from scratch, and bump the epoch —
    with the region holding ≥1k tasks so the rebuild exercises real
    multi-block rows — and rollback must restore the old index object."""
    reset_fresh_names()
    p1 = make_task([_leaf("p1", ["x"], ["v"])])
    p2 = make_task([_leaf("p2", ["x"], ["v"])])      # multi-produced "v"
    c = make_task([_leaf("c", ["v"], ["w"])])
    chain = []
    for i in range(1001):
        ins = ["x"] if i == 0 else [f"c{i - 1}"]
        chain.append(make_task([_leaf(f"n{i}", ins, [f"c{i}"])]))
    d = make_dispatch([p1, p2, c] + chain)
    g = Graph("g", ops=[d])

    rs = GraphRewriteSession(g, selfcheck=True)
    idx0 = rs._ensure_region(d)
    assert len(idx0.by_bit) >= 1000
    before = region_index_fingerprint(idx0)
    assert rs.region_epoch(d) == 0

    merged = rs.fuse(d, p1, c)   # "v" becomes internal; edge p2→c vanishes
    idx1 = rs._ensure_region(d)
    assert idx1 is not idx0           # rebuilt, not maintained
    assert rs.region_epoch(d) == 1    # cached cycle verdicts invalidated
    assert region_index_bytes(idx1) > 0
    _maintained_matches_int_oracle(idx1)
    # ranks survive the rebuild: merged inherits first's, all unique
    assert idx1.rank[id(merged)] == 0
    live_ranks = sorted(idx1.rank.values())
    assert len(live_ranks) == len(set(live_ranks))

    rs.rollback()
    assert rs._ensure_region(d) is idx0
    assert region_index_fingerprint(rs._ensure_region(d)) == before
    assert rs.region_epoch(d) == 0


# --------------------------------------------------------------------------
# Scale-aware region bounds: both regimes
# --------------------------------------------------------------------------

def test_default_region_bounds_small_regime_is_historical():
    for n in (1, 16, 43, 100, 256):
        assert default_region_bounds(n) == (3, 16)


def test_default_region_bounds_scaled_regime():
    prev_mx = 16
    for n in (257, 500, 1000, 5000, 10000):
        mn, mx = default_region_bounds(n)
        assert mn >= 3 and mx > 16
        assert mx >= prev_mx          # monotone in n
        assert mn <= mx
        assert mx * mx >= n - 1       # ~sqrt(n) cap actually scales
        prev_mx = mx


def test_dse_regions_defaults_bit_identical_below_threshold():
    """For every ≤256-node schedule the scale-aware defaults must be a
    no-op: the partition equals an explicit (3, 16) call."""
    from golden_utils import build_pre_dse_schedule

    sched = build_pre_dse_schedule("smollm-135m")
    assert len(sched.nodes) <= 256
    default = dse_regions(sched)
    explicit = dse_regions(sched, min_nodes=3, max_nodes=16)
    assert [r.nodes for r in default] == [r.nodes for r in explicit]
    assert [r.boundary for r in default] == [r.boundary for r in explicit]
