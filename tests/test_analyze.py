"""Static hazard analyzer tests (:mod:`repro.core.analyze`).

A hand-built hazard corpus — under-depth reconvergent diamond, shallow
soft FIFO, token/dataflow cycles, disagreeing sharded writers, lost
read-modify-write updates, unordered multi-writers, stale role aliases,
corrupted session indexes — where each rule trips exactly its hazard
code, plus a clean sweep asserting zero findings across the whole model
zoo and the 1k-node synthetic, the ``balance.py`` shared-soft-FIFO
regression the analyzer surfaced, and the ``analyze.rules`` chaos lane.
"""
import sys

import pytest

from repro.core import (AccessMap, Buffer, MemoryEffect, Node, Op,
                        Schedule, SINGLE_POD, ShardingPlan,
                        balance_paths, build_lm_graph, optimize)
from repro.core.ir import TokenEdge
from repro.core.analyze import (AnalyzeReport, analyze, analyze_plan,
                                register_rule, registered_rules)

# ``repro.core`` re-exports the ``analyze`` *function*, which shadows the
# submodule attribute — fetch the module itself for monkeypatching.
analyze_mod = sys.modules["repro.core.analyze"]
from repro.core.balance import path_skew
from repro.core.faults import inject_faults
from repro.configs import get_config, list_archs
from repro.configs.base import SHAPES


def _mk_node(name, args, loop=None, access=None, unroll=None):
    op = Op(name=f"{name}_op", kind="compute",
            ins=[a for a, e in args.items()
                 if e in (MemoryEffect.READ, MemoryEffect.READ_WRITE)],
            outs=[a for a, e in args.items()
                  if e in (MemoryEffect.WRITE, MemoryEffect.READ_WRITE)],
            loop_dims=loop or {}, access=access or {})
    n = Node(name=name, args=dict(args), body=[op])
    if unroll:
        n.unroll.update(unroll)
    return n


def _deep_diamond():
    """n0 -> n1 -> n2 -> n3 chain plus an n0 -> n3 shortcut through
    ``b03`` (skew 2: needs stages >= 3 to avoid stalling)."""
    s = Schedule("diamond")
    for b in ("b01", "b12", "b23", "b03", "out"):
        s.buffers[b] = Buffer(b, (8,), dims=("i",))
    W, R = MemoryEffect.WRITE, MemoryEffect.READ
    s.nodes = [
        _mk_node("n0", {"b01": W, "b03": W}, {"i": 8}),
        _mk_node("n1", {"b01": R, "b12": W}, {"i": 8}),
        _mk_node("n2", {"b12": R, "b23": W}, {"i": 8}),
        _mk_node("n3", {"b23": R, "b03": R, "out": W}, {"i": 8}),
    ]
    return s


# --------------------------------------------------------------------------
# Family 1: deadlock / FIFO depth
# --------------------------------------------------------------------------

def test_underdepth_onchip_diamond_is_reconvergent_deadlock():
    s = _deep_diamond()
    rep = analyze(s, rules=["deadlock.depth"])
    assert rep.codes() == {"reconvergent-deadlock"}
    (issue,) = rep.errors()
    assert issue.site == "b03" and "skips 2" in issue.message


def test_underdepth_external_fifo_is_fifo_underdepth():
    s = _deep_diamond()
    s.buffers["b03"].placement = "external"
    rep = analyze(s, rules=["deadlock.depth"])
    assert rep.codes() == {"fifo-underdepth"}


def test_sufficient_fifo_without_token_is_warning_only():
    s = _deep_diamond()
    s.buffers["b03"].placement = "external"
    s.buffers["b03"].stages = 3
    rep = analyze(s, rules=["deadlock.depth"])
    assert rep.ok  # warnings don't fail the lint
    assert rep.codes() == {"token-missing"}
    s.tokens.append(TokenEdge("n0", "n3"))
    assert analyze(s, rules=["deadlock.depth"]).issues == []


def test_balanced_schedule_is_clean():
    s = _deep_diamond()
    balance_paths(s, onchip_budget_bytes=0)  # force the soft-FIFO path
    assert all(k <= 0 for k in path_skew(s).values()) \
        or s.buffers["b03"].stages >= 3
    rep = analyze(s)
    assert rep.ok and not rep.issues
    assert rep.checks > 0


def test_token_cycle_detected():
    s = _deep_diamond()
    s.tokens.append(TokenEdge("n3", "n0"))  # closes the chain backwards
    rep = analyze(s, rules=["deadlock.cycle"])
    assert rep.codes() == {"token-cycle"}


def test_dataflow_cycle_detected_and_depth_rule_stays_silent():
    s = Schedule("cyc")
    s.buffers["b1"] = Buffer("b1", (8,), dims=("i",))
    s.buffers["b2"] = Buffer("b2", (8,), dims=("i",))
    W, R = MemoryEffect.WRITE, MemoryEffect.READ
    s.nodes = [_mk_node("na", {"b2": R, "b1": W}, {"i": 8}),
               _mk_node("nb", {"b1": R, "b2": W}, {"i": 8})]
    rep = analyze(s)  # all rules: none may crash on a cyclic schedule
    assert rep.codes() == {"deadlock-cycle"}
    assert "analyze-internal" not in rep.codes()


def test_token_dangling_detected():
    s = _deep_diamond()
    s.tokens.append(TokenEdge("ghost", "n0"))
    rep = analyze(s, rules=["deadlock.cycle"])
    assert rep.codes() == {"token-dangling"}


# --------------------------------------------------------------------------
# Family 2: shard races
# --------------------------------------------------------------------------

def test_shard_race_on_disagreeing_writer_dims():
    s = Schedule("race")
    s.buffers["buf"] = Buffer("buf", (8,), dims=("i",))
    s.buffers["t"] = Buffer("t", (8,), dims=("i",))
    s.buffers["out"] = Buffer("out", (8,), dims=("i",))
    W, R = MemoryEffect.WRITE, MemoryEffect.READ
    # w1 and w2 both write buf axis 0, but index it by different loop
    # dims — instance k of each owns overlapping slices.  The t edge
    # orders them so order.writers stays quiet and only the race trips.
    w1 = _mk_node("w1", {"buf": W, "t": W}, {"i": 8},
                  access={"buf": AccessMap.of(("i", 1))})
    w2 = _mk_node("w2", {"t": R, "buf": W, "out": W}, {"j": 8},
                  access={"buf": AccessMap.of(("j", 1))})
    s.nodes = [w1, w2]
    rep = analyze(s, rules=["race.shard"])
    assert rep.codes() == {"shard-race"}
    (issue,) = rep.errors()
    assert issue.site == "buf" and "'i'" in issue.message \
        and "'j'" in issue.message
    assert analyze(s, rules=["order.writers"]).issues == []


def test_agreeing_writers_are_not_a_race():
    s = Schedule("ok")
    s.buffers["buf"] = Buffer("buf", (8,), dims=("i",))
    s.buffers["t"] = Buffer("t", (8,), dims=("i",))
    W, R = MemoryEffect.WRITE, MemoryEffect.READ
    s.nodes = [
        _mk_node("w1", {"buf": W, "t": W}, {"i": 8},
                 access={"buf": AccessMap.of(("i", 1))}),
        _mk_node("w2", {"t": R, "buf": W}, {"i": 8},
                 access={"buf": AccessMap.of(("i", 1))}),
    ]
    assert analyze(s, rules=["race.shard"]).issues == []


def test_rw_lost_update_on_unindexed_unroll_dim():
    s = Schedule("rw")
    s.buffers["acc"] = Buffer("acc", (8,), dims=("i",))
    n = _mk_node("n", {"acc": MemoryEffect.READ_WRITE},
                 {"i": 8, "k": 4},
                 access={"acc": AccessMap.of(("i", 1))},
                 unroll={"k": 4})
    s.nodes = [n]
    rep = analyze(s, rules=["race.shard"])
    assert rep.codes() == {"rw-lost-update"}
    # Unrolling over the dim the map *does* index is fine.
    n.unroll = {"i": 4}
    assert analyze(s, rules=["race.shard"]).issues == []


# --------------------------------------------------------------------------
# Family 3: write ordering + role aliases
# --------------------------------------------------------------------------

def test_unordered_writers_flagged_then_cleared_by_token():
    s = Schedule("wo")
    s.buffers["buf"] = Buffer("buf", (8,), dims=("i",))
    W = MemoryEffect.WRITE
    am = {"buf": AccessMap.of(("i", 1))}  # agree → no shard-race noise
    s.nodes = [_mk_node("w1", {"buf": W}, {"i": 8}, access=am),
               _mk_node("w2", {"buf": W}, {"i": 8}, access=am)]
    rep = analyze(s, rules=["order.writers"])
    assert rep.codes() == {"write-order"}
    s.tokens.append(TokenEdge("w1", "w2"))  # now happens-before ordered
    assert analyze(s, rules=["order.writers"]).issues == []


def _plan(**kw):
    return ShardingPlan(mesh_spec=SINGLE_POD, **kw)


def test_alias_rules_clean_chain_missing_drift():
    spec = (("data",),)
    clean = _plan(buffer_specs={"src": spec, "alias": spec},
                  role_sources={"alias": "src"})
    assert analyze_plan(clean, SINGLE_POD).issues == []

    chained = _plan(buffer_specs={"src": spec, "a": spec, "b": spec},
                    role_sources={"a": "b", "b": "src"})
    rep = analyze_plan(chained, SINGLE_POD)
    assert rep.codes() == {"alias-chain"}
    assert rep.errors()[0].site == "a"

    missing = _plan(role_sources={"x": "nosuch"})
    assert analyze_plan(missing, SINGLE_POD).codes() == {"alias-missing"}

    drifted = _plan(buffer_specs={"src": spec, "alias": ((),)},
                    role_sources={"alias": "src"})
    assert analyze_plan(drifted, SINGLE_POD).codes() == {"alias-drift"}


def test_plan_cache_fetch_rejects_hazardous_entry():
    from repro.core.plan_cache import CachedPlan, PlanCache, PlanKey
    cache = PlanCache(None)  # memory tier only
    key = PlanKey("fp0", tuple(SINGLE_POD.axes), "decode_s64_b4")
    spec = (("data",),)
    plan = _plan(buffer_specs={"src": spec, "mid": spec, "alias": spec},
                 role_sources={"alias": "mid"})
    cache.put(CachedPlan(key, plan, snapshot={}, qor_total_s=1.0))
    entry, _ = cache.fetch(key, SINGLE_POD)
    assert entry is not None  # clean plan flows through

    # The memory tier hands out live objects — rot the alias in place
    # into a chain, the hazard verify_static does NOT see (all specs
    # still mirror, so the alias-incoherent check passes) but whose
    # one-hop apply_rule_change refresh goes stale on the next change.
    plan.role_sources["mid"] = "src"
    entry, rep = cache.fetch(key, SINGLE_POD)
    assert entry is None and rep is not None and rep.ok
    assert cache.stats["hazard_rejected"] == 1
    assert key not in cache._lru  # dropped, not re-tried every request


# --------------------------------------------------------------------------
# Family 4: session invariants
# --------------------------------------------------------------------------

def test_invariant_topology_stale_on_corrupted_index():
    s = _deep_diamond()
    topo = s.topology()
    assert analyze(s, rules=["invariant.index"]).issues == []
    # Simulate an index-maintenance bug: the producer list rots while
    # the structure signature still matches.
    topo.producers["out"].append(s.nodes[0])
    rep = analyze(s, rules=["invariant.index"])
    assert rep.codes() == {"topology-stale"}


def test_invariant_order_and_depth_memo_stale():
    s = _deep_diamond()
    topo = s.topology()
    topo.topo_order(s.nodes, s.name)
    topo.depth_of(s.nodes, s.name)
    topo._order_memo = list(reversed(topo._order_memo))
    rep = analyze(s, rules=["invariant.index"])
    assert "order-stale" in rep.codes()
    topo._order_memo = None
    topo._depth_memo = dict(topo._depth_memo, n3=99)
    rep = analyze(s, rules=["invariant.index"])
    assert rep.codes() == {"depth-stale"}


def test_invariant_node_cache_stale_on_inplace_replacement():
    s = _deep_diamond()
    s.node("n0")  # build the name->node cache
    s.nodes[0] = _mk_node("n0", dict(s.nodes[0].args), {"i": 8})
    rep = analyze(s, rules=["invariant.index"])
    assert "node-cache-stale" in rep.codes()


def test_invariant_deep_check_cap_is_recorded_not_silent(monkeypatch):
    s = _deep_diamond()
    s.topology()
    monkeypatch.setattr(analyze_mod, "DEEP_CHECK_NODE_CAP", 1)
    rep = analyze(s, rules=["invariant.index"])
    assert rep.issues == []
    assert rep.stats["invariant_deep_skipped"] == len(s.nodes)


# --------------------------------------------------------------------------
# Registry + driver contract
# --------------------------------------------------------------------------

def test_registry_rejects_duplicates_and_unknown_selection():
    with pytest.raises(ValueError, match="already registered"):
        register_rule("deadlock.depth", family="deadlock")(lambda ctx: None)
    with pytest.raises(ValueError, match="unknown analysis rule"):
        analyze(_deep_diamond(), rules=["no.such.rule"])


def test_analyze_plan_runs_only_plan_only_rules():
    rep = analyze_plan(_plan(), SINGLE_POD)
    assert rep.rules_run == ["order.alias"]
    # Schedule-free analyze over *all* rules skips the non-plan_only
    # ones and records how many, rather than crashing on sched=None.
    rep = analyze(None, _plan(), SINGLE_POD)
    assert rep.rules_run == ["order.alias"]
    assert rep.stats["rules_skipped_no_schedule"] == \
        len(registered_rules()) - 1


def test_crashing_rule_becomes_internal_issue_not_exception():
    @register_rule("test.crash", family="invariant")
    def _boom(ctx):
        raise RuntimeError("kaboom")
    try:
        rep = analyze(_deep_diamond(), rules=["test.crash"])
        assert rep.crashed_rules() == ["test.crash"]
        assert rep.rules_run == []
        assert not rep.ok and "kaboom" in rep.errors()[0].message
    finally:
        del analyze_mod._RULES["test.crash"]


def test_empty_report_is_ok_and_summary_renders():
    rep = AnalyzeReport()
    assert rep.ok and "clean" in rep.summary()
    bad = analyze(_deep_diamond())
    assert "hazard" in bad.summary()


# --------------------------------------------------------------------------
# balance.py regression: shared soft-FIFO buffer keeps the max depth
# --------------------------------------------------------------------------

def _shared_fifo_schedule():
    """One buffer feeding two consumers at different depths.  The deep
    consumer sorts first in ``balance_paths``'s lexicographic edge walk,
    so before the fix the later skew-1 edge shrank the FIFO from 3 to 2
    stages — exactly the under-depth hazard ``deadlock.depth`` flags."""
    s = Schedule("shared")
    for b in ("buf", "b1", "b2", "b3", "o1", "o2"):
        s.buffers[b] = Buffer(b, (8,), dims=("i",))
    W, R = MemoryEffect.WRITE, MemoryEffect.READ
    s.nodes = [
        _mk_node("n0", {"buf": W, "b1": W}, {"i": 8}),
        _mk_node("m1", {"b1": R, "b2": W}, {"i": 8}),
        _mk_node("m2", {"b2": R, "b3": W}, {"i": 8}),
        _mk_node("a_deep", {"buf": R, "b3": R, "o1": W}, {"i": 8}),
        _mk_node("b_shallow", {"buf": R, "b2": R, "o2": W}, {"i": 8}),
    ]
    return s


def test_balance_shared_soft_fifo_keeps_max_stage_requirement():
    s = _shared_fifo_schedule()
    skews = path_skew(s)
    assert skews[("n0", "a_deep", "buf")] == 2
    assert skews[("n0", "b_shallow", "buf")] == 1
    balance_paths(s, onchip_budget_bytes=0)  # both edges go soft-FIFO
    # Regression: the skew-1 edge must not shrink stages below the
    # skew-2 edge's requirement of 3.
    assert s.buffers["buf"].stages == 3
    assert s.buffers["buf"].placement == "external"
    assert {(t.src, t.dst) for t in s.tokens} >= {
        ("n0", "a_deep"), ("n0", "b_shallow")}
    rep = analyze(s)
    assert rep.ok and not rep.issues


# --------------------------------------------------------------------------
# Chaos lane: analyze.rules faults degrade, never raise
# --------------------------------------------------------------------------

def test_analyze_fault_site_crashes_rules_into_report():
    s = _deep_diamond()
    balance_paths(s, onchip_budget_bytes=0)
    with inject_faults(seed=0, rate=1.0, sites=("analyze.rules",)):
        rep = analyze(s)
    assert rep.rules_run == []
    assert set(rep.crashed_rules()) == set(registered_rules())


def test_optimize_survives_analyze_faults_with_degradation():
    g = build_lm_graph(get_config("smollm-135m", smoke=True),
                       SHAPES["train_4k"])
    with inject_faults(seed=3, rate=1.0, sites=("analyze.rules",)):
        sched, plan, rep = optimize(g, SINGLE_POD)
    assert rep.verify is not None and rep.verify.ok
    assert any(d.stage == "analyze" for d in rep.degradations)
    assert rep.analyze is not None and rep.analyze.crashed_rules()


# --------------------------------------------------------------------------
# Clean sweep: zero findings across the zoo + the 1k-node synthetic
# --------------------------------------------------------------------------

def _assert_clean_exit(graph):
    sched, plan, rep = optimize(graph, SINGLE_POD)
    assert rep.analyze is not None, "optimize() must attach the lint"
    assert rep.analyze.ok, rep.analyze.summary()
    assert rep.analyze.issues == [], rep.analyze.summary()
    assert set(rep.analyze.rules_run) == set(registered_rules())
    assert rep.analyze.checks > 0
    assert not any(d.stage == "analyze" for d in rep.degradations)
    return rep


def test_optimize_exit_analysis_clean_and_fast():
    g = build_lm_graph(get_config("smollm-135m", smoke=True),
                       SHAPES["train_4k"])
    rep = _assert_clean_exit(g)
    assert rep.analyze_s < 0.01  # ISSUE budget: < 10 ms per config


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_clean_sweep_zoo(arch):
    g = build_lm_graph(get_config(arch, smoke=True), SHAPES["train_4k"])
    rep = _assert_clean_exit(g)
    assert rep.analyze_s < 0.01, f"{arch}: analyze took {rep.analyze_s}s"


@pytest.mark.slow
def test_clean_sweep_synth_1k():
    from repro.core.generate import get_synth
    _assert_clean_exit(get_synth("synth_1k"))
