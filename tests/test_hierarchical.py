"""Hierarchy-equivalence properties of the two-level DSE
(``repro.core.parallelize`` with ``dse_mode="hierarchical"``, the paper
Section 4 decomposition: per-region inner beams composed by an
inter-region outer beam).

The flat whole-schedule beam is kept behind ``dse_mode="flat"`` as the
differential-testing oracle.  Contracts:

* **Hierarchical ≤ flat, everywhere** — on every registered model config
  the two-level DSE's final QoR is at least as good as the flat beam's.
  The dominance is structural (the outer level seeds with the same
  uniform global family and the converged greedy state the flat beam
  seeds with, and the final keep-best compares against both), so the
  assertion is exact.
* **Single-region schedules take the flat path bit-identically** — when
  :func:`~repro.core.rewrite.dse_regions` leaves the schedule whole
  (every PolyBench graph), ``dse_mode="hierarchical"`` is
  indistinguishable from ``dse_mode="flat"``: same plan, same cost,
  ``dse_mode == "flat"`` reported.
* **Determinism** — two hierarchical runs on identical schedules commit
  bit-identical plans and summaries (timings aside), and threaded
  scoring (``sweep_workers``) changes nothing.
* **Summary interface** — :class:`RegionSummary` round-trips exactly
  through JSON, and the boundary-connection signature is stable under
  renaming every node in the schedule (no names leak into the
  inner→outer interface).
* **Region-aware QoR floor** — ``best_uniform(regions=...)`` is never
  worse than the whole-schedule floor.
* **Anytime budget split** — an expired / near-expired deadline still
  yields a complete assignment no worse than converged greedy, with
  ``budget_expired`` reported.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.common import POLYBENCH
from repro.configs import SHAPES, get_config, list_archs
from repro.core import (SINGLE_POD, best_uniform, build_lm_graph,
                        construct_functional, fuse_tasks,
                        lower_to_structural)
from repro.core.balance import balance_paths
from repro.core.ir import reset_fresh_names
from repro.core.multi_producer import eliminate_multi_producers
from repro.core.parallelize import RegionEntry, RegionSummary, parallelize
from repro.core.rewrite import dse_regions

ARCHS = list_archs()
#: configs cheap enough for the fast lane (mirrors tests/test_rewrite.py)
FAST_ARCHS = ("smollm-135m", "xlstm-125m", "stablelm-3b")


def _arch_params(archs):
    return [pytest.param(a, marks=() if a in FAST_ARCHS
                         else (pytest.mark.slow,)) for a in archs]


def _lowered_model(arch):
    reset_fresh_names()
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    eliminate_multi_producers(sched)
    balance_paths(sched)
    return sched


def _lowered_pb(name):
    reset_fresh_names()
    g = POLYBENCH[name]()
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    eliminate_multi_producers(sched)
    balance_paths(sched)
    return sched


def _plan_snapshot(sched):
    """Name-independent assignment snapshot (keyed by topo-list index)."""
    return {i: (sorted(n.unroll.items()),
                sorted((d, tuple(a)) for d, a in n.axis_map.items()))
            for i, n in enumerate(sched.nodes) if n.unroll or n.axis_map}


def _summary_sig(summ: RegionSummary):
    """Everything in a summary except wall-clock timing."""
    d = summ.to_dict()
    d.pop("inner_s")
    return d


# --------------------------------------------------------------------------
# Hierarchical QoR <= flat QoR on every registered config
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_hier_qor_never_worse_than_flat(arch):
    s_hier = _lowered_model(arch)
    r_hier = parallelize(s_hier, SINGLE_POD, training=True)
    s_flat = _lowered_model(arch)
    r_flat = parallelize(s_flat, SINGLE_POD, training=True, dse_mode="flat")

    assert r_flat.dse_mode == "flat" and not r_flat.region_summaries
    assert r_hier.cost.total_s <= r_flat.cost.total_s, \
        f"hierarchical {r_hier.cost.total_s} worse than flat " \
        f"{r_flat.cost.total_s} on {arch}"
    # Both modes keep the classic beam invariant vs. converged greedy.
    assert r_hier.cost.total_s <= r_hier.greedy_total_s

    if r_hier.dse_mode == "hierarchical":
        assert r_hier.regions >= 2
        assert len(r_hier.region_summaries) == r_hier.regions
        assert r_hier.inner_dse_s > 0 and r_hier.outer_dse_s > 0
        # Regions tile the schedule exactly once.
        names = [n.name for n in s_hier.nodes]
        covered = [nm for s in r_hier.region_summaries for nm in s.nodes]
        assert sorted(covered) == sorted(names)
        for summ in r_hier.region_summaries:
            assert summ.entries, f"region {summ.index} has no entries"
            # Best entry first; the converged-greedy entry always present.
            best = min(e.key() for e in summ.entries)
            assert summ.entries[0].key() == best
            g = summ.entries[summ.greedy_index()]
            assert g.origin == "greedy" and g.delta_s == 0.0
            for e in summ.entries:
                assert set(e.assignment) <= set(summ.nodes)
                assert e.delta_s == e.total_s - g.total_s


# --------------------------------------------------------------------------
# Single-region schedules: hierarchical == flat, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(POLYBENCH))
def test_single_region_bit_identical_to_flat(name):
    s_hier = _lowered_pb(name)
    assert len(dse_regions(s_hier)) == 1, \
        f"PolyBench {name} unexpectedly partitioned"
    r_hier = parallelize(s_hier, SINGLE_POD, training=False)
    s_flat = _lowered_pb(name)
    r_flat = parallelize(s_flat, SINGLE_POD, training=False,
                         dse_mode="flat")

    # The partitioner left the schedule whole, so the hierarchical mode
    # must have taken the flat path — and report that honestly.
    assert r_hier.dse_mode == "flat"
    assert r_hier.regions == 1 and not r_hier.region_summaries
    assert r_hier.inner_dse_s == 0.0 and r_hier.outer_dse_s == 0.0
    assert _plan_snapshot(s_hier) == _plan_snapshot(s_flat)
    assert r_hier.cost.total_s == r_flat.cost.total_s


# --------------------------------------------------------------------------
# Determinism: repeated runs and threaded scoring are bit-identical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ("xlstm-125m", "stablelm-3b"))
def test_hierarchical_runs_are_deterministic(arch):
    s1 = _lowered_model(arch)
    r1 = parallelize(s1, SINGLE_POD, training=True)
    s2 = _lowered_model(arch)
    r2 = parallelize(s2, SINGLE_POD, training=True)
    assert _plan_snapshot(s1) == _plan_snapshot(s2)
    assert r1.cost.total_s == r2.cost.total_s
    assert ([_summary_sig(s) for s in r1.region_summaries]
            == [_summary_sig(s) for s in r2.region_summaries])


@pytest.mark.parametrize("arch", ("xlstm-125m",))
def test_hierarchical_threaded_sweeps_match_serial(arch):
    s_serial = _lowered_model(arch)
    r_serial = parallelize(s_serial, SINGLE_POD, training=True)
    s_thread = _lowered_model(arch)
    r_thread = parallelize(s_thread, SINGLE_POD, training=True,
                           sweep_workers=4)
    assert _plan_snapshot(s_serial) == _plan_snapshot(s_thread)
    assert r_serial.cost.total_s == r_thread.cost.total_s
    assert ([_summary_sig(s) for s in r_serial.region_summaries]
            == [_summary_sig(s) for s in r_thread.region_summaries])


# --------------------------------------------------------------------------
# RegionSummary: exact JSON round-trip
# --------------------------------------------------------------------------

def test_region_summary_json_round_trip():
    sched = _lowered_model("xlstm-125m")
    res = parallelize(sched, SINGLE_POD, training=True)
    assert res.region_summaries
    for summ in res.region_summaries:
        wire = json.loads(json.dumps(summ.to_dict()))
        back = RegionSummary.from_dict(wire)
        assert back.to_dict() == summ.to_dict()
        assert back.nodes == summ.nodes
        assert back.boundary_sig == summ.boundary_sig
        assert [e.key() for e in back.entries] \
            == [e.key() for e in summ.entries]
        assert back.entries[back.greedy_index()].assignment \
            == summ.entries[summ.greedy_index()].assignment


def test_region_entry_round_trip_preserves_assignment_types():
    e = RegionEntry(
        assignment={"n0": ({"d0": ("data",), "d1": ("model", "data")},
                           {"d0": 4, "d1": 2})},
        total_s=1.5, delta_s=-0.25, hbm_bytes=1024,
        region_hbm_bytes=256, origin="search")
    back = RegionEntry.from_dict(json.loads(json.dumps(e.to_dict())))
    assert back == e
    am, ur = back.assignment["n0"]
    assert all(isinstance(axes, tuple) for axes in am.values())
    assert all(isinstance(f, int) for f in ur.values())


# --------------------------------------------------------------------------
# Boundary signatures: stable under renaming every node
# --------------------------------------------------------------------------

def test_boundary_signature_stable_under_renaming():
    """The partition walk and the boundary signature depend only on edge
    structure, program order, and buffer geometry — never on node names.
    Rename every node (inverting their lexicographic order) and both
    must come out bit-identical."""
    s_base = _lowered_model("xlstm-125m")
    s_renamed = _lowered_model("xlstm-125m")
    n = len(s_renamed.nodes)
    for i, node in enumerate(s_renamed.nodes):
        node.name = f"zz_{n - i:04d}"
    s_renamed._topology = None  # force a topology rebuild on new names

    regs_base = dse_regions(s_base)
    regs_ren = dse_regions(s_renamed)
    assert len(regs_base) == len(regs_ren) >= 2
    pos_b = {nd.name: i for i, nd in enumerate(s_base.nodes)}
    pos_r = {nd.name: i for i, nd in enumerate(s_renamed.nodes)}
    for rb, rr in zip(regs_base, regs_ren):
        # Same slice of the (renaming-stable) topological order...
        assert sorted(pos_b[nm] for nm in rb.nodes) \
            == sorted(pos_r[nm] for nm in rr.nodes)
        assert len(rb.boundary) == len(rr.boundary)

    r_base = parallelize(s_base, SINGLE_POD, training=True)
    r_ren = parallelize(s_renamed, SINGLE_POD, training=True)
    assert r_base.dse_mode == r_ren.dse_mode == "hierarchical"
    # ...and bit-identical name-free boundary signatures per region.
    assert [s.boundary_sig for s in r_base.region_summaries] \
        == [s.boundary_sig for s in r_ren.region_summaries]
    assert r_base.cost.total_s == r_ren.cost.total_s


# --------------------------------------------------------------------------
# Region-aware QoR floor
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", _arch_params(
    ("xlstm-125m", "stablelm-3b", "jamba-v0.1-52b")))
def test_region_aware_floor_never_worse_than_whole_schedule(arch):
    s1 = _lowered_model(arch)
    _, c_whole = best_uniform(s1, SINGLE_POD)
    s2 = _lowered_model(arch)
    regs = dse_regions(s2)
    assert len(regs) >= 2
    assign, c_region = best_uniform(s2, SINGLE_POD, regions=regs)
    assert c_region.total_s <= c_whole.total_s * (1 + 1e-12)
    # The returned assignment is still a whole-schedule family member.
    assert isinstance(assign, dict)


def test_region_aware_floor_single_region_is_identity():
    s1 = _lowered_pb("atax")
    _, c_plain = best_uniform(s1, SINGLE_POD, training=False)
    s2 = _lowered_pb("atax")
    _, c_regs = best_uniform(s2, SINGLE_POD, training=False,
                             regions=dse_regions(s2))
    assert c_regs.total_s == c_plain.total_s
    assert _plan_snapshot(s1) == _plan_snapshot(s2)


# --------------------------------------------------------------------------
# Anytime budget split across the two levels
# --------------------------------------------------------------------------

def test_expired_deadline_still_returns_complete_assignment():
    """A deadline that expired before the DSE started: the greedy pass
    always completes (a full assignment must exist), both levels go
    anytime immediately, and the result is never worse than greedy."""
    sched = _lowered_model("xlstm-125m")
    res = parallelize(sched, SINGLE_POD, training=True,
                      deadline=time.perf_counter())
    assert res.budget_expired
    assert res.cost is not None
    assert res.cost.total_s <= res.greedy_total_s
    # Every region still produced at least its greedy entry.
    if res.dse_mode == "hierarchical":
        for summ in res.region_summaries:
            assert summ.entries


def test_near_expiry_deadline_is_anytime_not_an_error():
    """A deadline mid-way through the inner level: whatever slice of the
    search completes, the committed plan is best-so-far (<= greedy) and
    the expiry is reported instead of raised."""
    sched = _lowered_model("stablelm-3b")
    res = parallelize(sched, SINGLE_POD, training=True,
                      deadline=time.perf_counter() + 0.02)
    assert res.cost is not None
    assert res.cost.total_s <= res.greedy_total_s
    snap = _plan_snapshot(sched)
    assert snap  # a real assignment was committed in place
