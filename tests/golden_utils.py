"""Golden capture/compare helpers for the pre-DSE pass pipeline.

The transactional-rewrite refactor (``repro.core.rewrite``) is
correctness-gated the same way PR 3 gated ``apply_rule_change``: the
refactored passes must produce **bit-identical** output to the
pre-refactor pipeline on every config.  The goldens pinned here were
captured from ``main`` immediately *before* the passes were ported onto
``RewriteSession`` — each file holds, per config (``train_4k`` on the
SINGLE_POD mesh, ``training=True``, the paper-table configuration):

* ``schedule`` — ``Schedule.to_json()`` right after data-path balancing
  (construct → fuse → lower → multi-producer elimination → balance),
  i.e. the exact structure the DSE receives;
* ``plan`` — ``ShardingPlan.to_json()`` of a full ``optimize()`` run
  (the DSE itself is untouched by the refactor, so any plan drift means
  a pre-DSE pass changed behaviour).

Generated names embed the global fresh-name counter, so every build
resets it first (:func:`repro.core.ir.reset_fresh_names`) — capture and
comparison are reproducible bit-for-bit in any process.

Regenerate (only when a pass change is *intentional*)::

    PYTHONPATH=src python tests/golden_utils.py
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config, list_archs
from repro.core import (SINGLE_POD, build_lm_graph, construct_functional,
                        fuse_tasks, lower_to_structural, optimize)
from repro.core.balance import balance_paths
from repro.core.ir import reset_fresh_names
from repro.core.multi_producer import eliminate_multi_producers

GOLDEN_DIR = Path(__file__).resolve().parent / "goldens" / "pre_dse"
SHAPE = "train_4k"


def build_pre_dse_schedule(arch: str):
    """Deterministically run the pre-DSE pipeline for ``arch``: fresh
    name counter, then construct → fuse → lower → multi-producer →
    balance.  Returns the post-balance :class:`~repro.core.ir.Schedule`."""
    reset_fresh_names()
    g = build_lm_graph(get_config(arch), SHAPES[SHAPE])
    construct_functional(g)
    fuse_tasks(g)
    sched = lower_to_structural(g)
    eliminate_multi_producers(sched)
    balance_paths(sched)
    return sched


def build_final_plan(arch: str):
    """Deterministically run the full ``optimize()`` pipeline for
    ``arch`` and return the final :class:`~repro.core.plan.ShardingPlan`."""
    reset_fresh_names()
    g = build_lm_graph(get_config(arch), SHAPES[SHAPE])
    _sched, plan, _rep = optimize(g, SINGLE_POD, training=True)
    return plan


def golden_path(arch: str) -> Path:
    return GOLDEN_DIR / f"{arch}.json"


def capture(archs=None) -> None:
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    for arch in archs or list_archs():
        sched = build_pre_dse_schedule(arch)
        plan = build_final_plan(arch)
        golden_path(arch).write_text(json.dumps(
            {"shape": SHAPE, "mesh": "SINGLE_POD",
             "schedule": sched.to_dict(),
             "plan": json.loads(plan.to_json())}, indent=1))
        print(f"captured {golden_path(arch)}")


if __name__ == "__main__":
    capture()
