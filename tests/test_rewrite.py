"""Tests for the transactional rewrite layer (``repro.core.rewrite``).

Four contracts, mirroring how PR 3 gated ``apply_rule_change``:

1. **Golden invariance** — the refactored pre-DSE pipeline (construct →
   fuse → lower → multi-producer → balance, all on ``RewriteSession``)
   produces *bit-identical* post-balance schedules, and the full
   ``optimize()`` produces bit-identical final plans, on every config in
   ``repro.configs`` vs. goldens captured from the pre-refactor pipeline
   (``tests/goldens/pre_dse``; regenerate with
   ``PYTHONPATH=src python tests/golden_utils.py`` only when a pass
   change is intentional).

2. **Incremental == from-scratch** — with ``selfcheck=True`` every pass
   asserts, after *every individual rewrite* in its worklist trace, that
   the session's Δ-maintained topology equals a fresh
   ``GraphTopology.build()`` / ``ScheduleTopology.build()``.

3. **Rollback** — aborting a session restores the IR *and* the cached
   topology object bit-exactly, no matter what prefix of rewrites ran.

4. **Primitive semantics** — direct unit coverage of the multi-producer
   arms, the session primitives, and the stage-assignment applier.
"""
import json

import pytest

from repro.configs import list_archs
from repro.core import construct_functional
from repro.core.fusion import fuse_tasks
from repro.core.ir import (Buffer, Graph, MemoryEffect, Node, Op, Schedule,
                           ScheduleTopology, make_dispatch, make_task,
                           reset_fresh_names)
from repro.core.multi_producer import eliminate_multi_producers
from repro.core.pipeline import apply_stages, assign_stages, compute_stages
from repro.core.rewrite import (GraphRewriteSession, RewriteError,
                                ScheduleRewriteSession,
                                graph_topology_fingerprint,
                                schedule_topology_fingerprint)

from golden_utils import (build_final_plan, build_pre_dse_schedule,
                          golden_path)

ARCHS = list_archs()
#: configs cheap enough for the fast lane (every config runs pre-merge)
FAST_ARCHS = ("smollm-135m", "xlstm-125m", "stablelm-3b")
SLOW_ARCHS = tuple(a for a in ARCHS if a not in FAST_ARCHS)
#: configs for the per-rewrite selfcheck sweeps (O(n) assert per rewrite)
PROPERTY_ARCHS = ("smollm-135m", "xlstm-125m", "jamba-v0.1-52b",
                  "musicgen-large")


def _golden(arch):
    return json.loads(golden_path(arch).read_text())


# --------------------------------------------------------------------------
# 1. Golden invariance: schedules and plans bit-identical to pre-refactor
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_pre_dse_schedule_matches_golden_fast(arch):
    assert build_pre_dse_schedule(arch).to_dict() == _golden(arch)["schedule"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", SLOW_ARCHS)
def test_pre_dse_schedule_matches_golden_full(arch):
    assert build_pre_dse_schedule(arch).to_dict() == _golden(arch)["schedule"]


@pytest.mark.parametrize("arch", FAST_ARCHS)
def test_final_plan_matches_golden_fast(arch):
    assert json.loads(build_final_plan(arch).to_json()) \
        == _golden(arch)["plan"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", SLOW_ARCHS)
def test_final_plan_matches_golden_full(arch):
    assert json.loads(build_final_plan(arch).to_json()) \
        == _golden(arch)["plan"]


# --------------------------------------------------------------------------
# 2. Property sweep: Δ-maintained topology == from-scratch after ANY prefix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", PROPERTY_ARCHS)
def test_selfcheck_sweep_over_pass_traces(arch):
    """Run the real pass pipeline with per-rewrite selfchecks: after every
    wrap / fuse / rename / insert / retire in the worklist traces, the
    maintained topology — including the per-dispatch reachability index
    (direct edges, transitive closure, inverse closure, rank order) —
    must equal a fresh build / from-scratch DFS closure (the asserts live
    inside the session).  Also checks the pipeline output is unchanged by
    selfcheck mode itself."""
    from repro.configs import SHAPES, get_config
    from repro.core import build_lm_graph
    from repro.core.balance import balance_paths
    from repro.core.lower import lower_to_structural

    reset_fresh_names()
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    construct_functional(g, selfcheck=True)
    fuse_tasks(g, selfcheck=True)
    sched = lower_to_structural(g, selfcheck=True)
    eliminate_multi_producers(sched, selfcheck=True)
    balance_paths(sched, selfcheck=True)
    assert sched.to_dict() == _golden(arch)["schedule"]
    # Post-commit cache is warm and equal to a from-scratch build.
    assert schedule_topology_fingerprint(sched.topology()) \
        == schedule_topology_fingerprint(ScheduleTopology.build(sched))


# --------------------------------------------------------------------------
# 3. Rollback restores IR + topology exactly
# --------------------------------------------------------------------------

def _toy_schedule():
    s = Schedule("toy")
    for b, shape in (("a", (8,)), ("b", (8,)), ("c", (8,)), ("out", (8,))):
        s.buffers[b] = Buffer(b, shape, dims=("i",))
    s.args = ["a"]

    def op(name, ins, outs):
        return Op(name=name + "_op", kind="compute", ins=ins, outs=outs,
                  loop_dims={"i": 8}, flops=8)

    s.nodes = [
        Node(name="n0", args={"a": MemoryEffect.READ,
                              "b": MemoryEffect.WRITE},
             body=[op("n0", ["a"], ["b"])]),
        Node(name="n1", args={"b": MemoryEffect.READ,
                              "c": MemoryEffect.WRITE},
             body=[op("n1", ["b"], ["c"])]),
        Node(name="n2", args={"b": MemoryEffect.READ,
                              "c": MemoryEffect.READ,
                              "out": MemoryEffect.WRITE},
             body=[op("n2", ["b", "c"], ["out"])]),
    ]
    s.outputs = ["out"]
    return s


def test_schedule_rollback_restores_everything():
    s = _toy_schedule()
    base_topo = s.topology()
    before = s.to_json()
    before_fp = schedule_topology_fingerprint(base_topo)

    rs = ScheduleRewriteSession(s, selfcheck=True)
    # A representative mix of every primitive class.
    rs.add_buffer(Buffer("b_dup", (8,), dims=("i",)))
    rs.replace_uses("b", "b_dup", rs.users_in_program_order("b"))
    rs.insert_copy(s.node("n1"), s.buffers["b_dup"], "b", "b_dup")
    cp = Node(name="cp", args={"c": MemoryEffect.READ,
                               "out": MemoryEffect.READ_WRITE},
              body=[Op(name="cp_op", kind="copy", ins=["c"], outs=["out"],
                       loop_dims={"i": 8})])
    rs.add_node(cp, index=2)
    rs.set_arg(s.node("n2"), "a", MemoryEffect.READ)
    rs.drop_arg(s.node("n2"), "a")
    rs.set_buffer_attrs("c", stages=5, placement="external")
    rs.add_token("n1", "n2")
    rs.set_stage(s.node("n0"), 3)
    rs.retire_node(cp)
    rs.rename_buffer("c", "c2")
    assert s.to_json() != before  # genuinely mutated
    rs.rollback()

    assert s.to_json() == before
    assert s.topology() is base_topo
    assert schedule_topology_fingerprint(s.topology()) == before_fp


def test_schedule_commit_installs_warm_topology():
    s = _toy_schedule()
    with ScheduleRewriteSession(s) as rs:
        rs.add_buffer(Buffer("b2", (8,), dims=("i",)))
        rs.replace_uses("b", "b2", [s.node("n2")])
    # Committed topology is the cache (no rebuild on next access) and
    # equals a from-scratch build.
    cached = s._topology
    assert cached is not None
    assert s.topology() is cached
    assert schedule_topology_fingerprint(cached) \
        == schedule_topology_fingerprint(ScheduleTopology.build(s))
    assert [n.name for n in s.topology().consumers["b2"]] == ["n2"]
    assert [n.name for n in s.topology().consumers["b"]] == ["n1"]


def test_schedule_session_context_manager_rolls_back_on_error():
    s = _toy_schedule()
    before = s.to_json()
    with pytest.raises(RuntimeError, match="boom"):
        with ScheduleRewriteSession(s) as rs:
            rs.add_buffer(Buffer("tmp", (8,), dims=("i",)))
            rs.rename_buffer("b", "renamed")
            raise RuntimeError("boom")
    assert s.to_json() == before


def test_closed_session_raises():
    s = _toy_schedule()
    rs = ScheduleRewriteSession(s)
    rs.commit()
    with pytest.raises(RewriteError):
        rs.add_buffer(Buffer("x", (8,), dims=("i",)))
    with pytest.raises(RewriteError):
        rs.commit()


def test_duplicate_buffer_and_unknown_node_raise():
    s = _toy_schedule()
    rs = ScheduleRewriteSession(s)
    with pytest.raises(RewriteError):
        rs.add_buffer(Buffer("a", (8,), dims=("i",)))
    with pytest.raises(RewriteError):
        rs.retire_node(Node(name="ghost"))
    rs.rollback()


def _fused_graph(arch="smollm-135m"):
    from repro.configs import SHAPES, get_config
    from repro.core import build_lm_graph

    reset_fresh_names()
    g = build_lm_graph(get_config(arch), SHAPES["train_4k"])
    construct_functional(g)
    return g


def test_graph_rollback_restores_structure_and_topology():
    g = _fused_graph()
    base_topo = g.topology()
    before_sig = g.structure_signature()
    before_fp = graph_topology_fingerprint(base_topo, g)

    rs = GraphRewriteSession(g, selfcheck=True)
    d = next(op for op in g.walk() if op.kind == "dispatch")
    a, b = d.region[0], d.region[1]
    merged = rs.fuse(d, a, b)
    head, tail = rs.split(d, merged, 1)
    rs.fuse(d, head, tail)
    assert g.structure_signature() != before_sig
    rs.rollback()

    assert g.structure_signature() == before_sig
    assert g.topology() is base_topo
    assert graph_topology_fingerprint(g.topology(), g) == before_fp


def test_graph_split_is_inverse_of_fuse():
    g = _fused_graph()
    with GraphRewriteSession(g, selfcheck=True) as rs:
        d = next(op for op in g.walk() if op.kind == "dispatch")
        a, b = d.region[0], d.region[1]
        a_children = [id(c) for c in a.region]
        b_children = [id(c) for c in b.region]
        merged = rs.fuse(d, a, b)
        head, tail = rs.split(d, merged, len(a_children))
        # The split halves own exactly the original child op objects.
        assert [id(c) for c in head.region] == a_children
        assert [id(c) for c in tail.region] == b_children
    # committed without error; topology cache equals fresh build
    from repro.core.ir import GraphTopology
    assert graph_topology_fingerprint(g.topology(), g) \
        == graph_topology_fingerprint(GraphTopology.build(g), g)


def test_graph_split_bad_index_raises():
    g = _fused_graph()
    rs = GraphRewriteSession(g)
    d = next(op for op in g.walk() if op.kind == "dispatch")
    merged = rs.fuse(d, d.region[0], d.region[1])
    with pytest.raises(RewriteError):
        rs.split(d, merged, 0)
    with pytest.raises(RewriteError):
        rs.split(d, merged, len(merged.region))
    rs.rollback()


def test_graph_rollback_after_fuse_plus_canonicalize():
    """Regression: canonicalize rebinds region lists; its undo must
    restore the *same* list objects so fuse undos logged earlier still
    land in the live tree, and rolling back the whole session restores
    the pre-session structure exactly."""
    from repro.core.fusion import simplify_hierarchy

    g = _fused_graph()
    before_sig = g.structure_signature()
    rs = GraphRewriteSession(g)
    d = next(op for op in g.walk() if op.kind == "dispatch")
    rs.fuse(d, d.region[0], d.region[1])
    rs.canonicalize(simplify_hierarchy)
    assert g.structure_signature() != before_sig
    rs.rollback()
    assert g.structure_signature() == before_sig


def test_canonicalize_exception_mid_apply_rolls_back():
    """A callback raising mid-canonicalize (after it already mutated the
    tree in place) must still restore the pre-session structure."""
    from repro.core.fusion import simplify_hierarchy

    g = _fused_graph()
    before_sig = g.structure_signature()
    calls = []

    def poisoned(op):
        out = simplify_hierarchy(op)
        calls.append(op.name)
        if len(calls) >= 1:
            raise RuntimeError("mid-canonicalize")
        return out

    with pytest.raises(RuntimeError, match="mid-canonicalize"):
        with GraphRewriteSession(g) as rs:
            rs.canonicalize(poisoned)
    assert g.structure_signature() == before_sig


def test_rename_buffer_migrates_value_bytes():
    s = _toy_schedule()
    s.value_bytes = {"a": 1, "b": 2, "c": 3, "out": 4}
    with ScheduleRewriteSession(s) as rs:
        rs.rename_buffer("b", "b_renamed")
    assert s.value_bytes == {"a": 1, "b_renamed": 2, "c": 3, "out": 4}
    rs2 = ScheduleRewriteSession(s)
    rs2.rename_buffer("b_renamed", "bb")
    rs2.rollback()
    assert s.value_bytes == {"a": 1, "b_renamed": 2, "c": 3, "out": 4}


def test_graph_rollback_drops_stale_rollup_memos():
    """Regression: a rollup memo recomputed *mid-session* (after
    `_invalidate_ancestors` popped it) reflects the mutated tree; it must
    not survive rollback into the restored one."""
    def leaf(name, kind, ins, outs):
        return Op(name=name, kind=kind, ins=ins, outs=outs,
                  loop_dims={"i": 8}, flops=8)

    a = make_task([leaf("a", "matmul", ["x"], ["ta"])])
    b = make_task([leaf("b", "matmul", ["x"], ["tb"])])
    c = make_task([leaf("c", "elementwise", ["ta", "tb"], ["tc"])])
    d = make_dispatch([a, b, c])
    g = Graph("g", ops=[d])

    rs = GraphRewriteSession(g)
    rs.fuse(d, a, c)
    # Mid-session ancestor query: caches {'x','tb'} against the fused
    # tree (ta became internal to merged, tb now crosses into it).
    assert set(rs.consumes(d)) == {"x", "tb"}
    rs.rollback()
    # The restored tree's true live-ins are just {'x'} — the stale memo
    # must be gone, not served from the reinstated base topology.
    assert set(g.topology().consumes(d)) == {"x"}
    assert g.topology().intensity(d) == d.intensity()


def _fusable_pair(rs, d):
    """First adjacent, non-cycle-creating pair — what a legal worklist
    step would fuse."""
    for a, b in rs.adjacent_pairs(d):
        if not rs.creates_cycle(d, a, b):
            return a, b
    raise RuntimeError(f"no fusable pair in {d.name}")


def test_reach_index_exact_rollback_on_midpass_exception():
    """The reachability index is restored bit-exactly by rollback: every
    fuse logs the previous row values, and undoing the rewrites in
    reverse leaves the per-dispatch index (succ/pred, closure, inverse
    closure, ranks, bit assignments) equal to its pre-mutation state —
    no matter how deep into the worklist the pass died."""
    from repro.core.rewrite import region_index_fingerprint

    g = _fused_graph("xlstm-125m")
    rs = GraphRewriteSession(g, selfcheck=True)
    d = next(op for op in g.walk() if op.kind == "dispatch")
    idx = rs._ensure_region(d)
    before = region_index_fingerprint(idx)
    for _ in range(3):
        a, b = _fusable_pair(rs, d)
        rs.fuse(d, a, b)
    assert region_index_fingerprint(idx) != before    # genuinely mutated
    rs.rollback()
    assert region_index_fingerprint(idx) == before


def test_reach_index_exact_rollback_via_context_manager():
    """Same contract when a pass dies mid-worklist inside ``with``."""
    from repro.core.rewrite import region_index_fingerprint

    g = _fused_graph("smollm-135m")
    before_sig = g.structure_signature()
    captured = {}

    class Boom(Exception):
        pass

    with pytest.raises(Boom):
        with GraphRewriteSession(g, selfcheck=True) as rs:
            d = next(op for op in g.walk() if op.kind == "dispatch")
            captured["idx"] = rs._ensure_region(d)
            captured["before"] = region_index_fingerprint(captured["idx"])
            rs.fuse(d, *_fusable_pair(rs, d))
            raise Boom()
    assert g.structure_signature() == before_sig
    assert region_index_fingerprint(captured["idx"]) == captured["before"]


def test_region_queries_raise_after_canonicalize():
    """The maintained region indices no longer describe the tree after a
    wholesale canonicalize; querying them must fail loudly, not answer
    from stale structure."""
    from repro.core.fusion import simplify_hierarchy

    g = _fused_graph()
    rs = GraphRewriteSession(g)
    d = next(op for op in g.walk() if op.kind == "dispatch")
    a, b = d.region[0], d.region[1]
    rs.canonicalize(simplify_hierarchy)
    with pytest.raises(RewriteError):
        rs.adjacent(d, a, b)
    rs.rollback()


def test_balance_tie_break_deterministic_across_runs():
    """The balance phase's pair heap breaks combined-intensity ties by
    the session's program-order ranks — explicitly, not by whatever
    order an enumeration produced.  Repeated-layer LMs have many exact
    intensity ties, so two runs agreeing bit-for-bit (on top of the
    pinned goldens) is the determinism evidence for the heap rewrite."""
    first = build_pre_dse_schedule("stablelm-3b").to_json()
    second = build_pre_dse_schedule("stablelm-3b").to_json()
    assert first == second
    plan_a = build_final_plan("smollm-135m").to_json()
    plan_b = build_final_plan("smollm-135m").to_json()
    assert plan_a == plan_b


def test_fusion_exception_leaves_graph_untouched():
    """A pass aborting mid-worklist must not leave the graph half-fused."""
    g = _fused_graph()
    before_sig = g.structure_signature()

    class Boom(Exception):
        pass

    with pytest.raises(Boom):
        with GraphRewriteSession(g) as rs:
            d = next(op for op in g.walk() if op.kind == "dispatch")
            rs.fuse(d, d.region[0], d.region[1])
            raise Boom()
    assert g.structure_signature() == before_sig


# --------------------------------------------------------------------------
# 4a. Multi-producer elimination arms (direct unit coverage)
# --------------------------------------------------------------------------

def _mp_schedule(effects_list, external=()):
    """Schedule with nodes n0..nK over a single shared buffer ``buf``."""
    s = Schedule("mp")
    s.buffers["buf"] = Buffer("buf", (16,), dims=("i",))
    s.buffers["out"] = Buffer("out", (16,), dims=("i",))
    s.args = list(external)
    for i, eff in enumerate(effects_list):
        ins = ["buf"] if eff in (MemoryEffect.READ,
                                 MemoryEffect.READ_WRITE) else []
        outs = ["buf"] if eff in (MemoryEffect.WRITE,
                                  MemoryEffect.READ_WRITE) else []
        s.nodes.append(Node(
            name=f"n{i}", args={"buf": eff},
            body=[Op(name=f"n{i}_op", kind="compute", ins=ins, outs=outs,
                     loop_dims={"i": 16}, flops=16)]))
    return s


def test_mp_internal_chained_duplication_three_producers():
    """Three internal-buffer producers → two chained duplicates, each
    producer owning exactly one copy; the RW producer gets a copy op."""
    s = _mp_schedule([MemoryEffect.WRITE, MemoryEffect.READ_WRITE,
                      MemoryEffect.WRITE, MemoryEffect.READ])
    stats = eliminate_multi_producers(s)
    assert stats.duplicated == 2
    assert stats.copies == 1  # only n1 read the previous contents
    # Every buffer single-producer now.
    for b in s.buffers:
        assert len(s.producers_of(b)) <= 1, b
    # Chain: n0 writes buf; n1 owns dup0 (with copy buf->dup0 prepended);
    # n2 owns dup1; the trailing reader n3 follows the last duplicate.
    n1, n2, n3 = s.node("n1"), s.node("n2"), s.node("n3")
    assert n1.body[0].kind == "copy"
    assert n1.body[0].ins == ["buf"]
    dup0 = n1.body[0].outs[0]
    assert dup0.startswith("buf_dup")
    assert n1.args[dup0] == MemoryEffect.READ_WRITE
    dup1 = next(b for b in n2.writes())
    assert dup1 != dup0 and dup1.startswith("buf_dup")
    assert list(n3.reads()) == [dup1]
    # Duplicates inherit the base buffer's attributes.
    assert s.buffers[dup0].shape == s.buffers["buf"].shape
    assert s.buffers[dup0].dims == s.buffers["buf"].dims


def test_mp_internal_duplication_no_copy_for_blind_writer():
    s = _mp_schedule([MemoryEffect.WRITE, MemoryEffect.WRITE,
                      MemoryEffect.READ])
    stats = eliminate_multi_producers(s)
    assert stats.duplicated == 1 and stats.copies == 0
    # n1 (blind write) owns the duplicate without a copy op.
    assert all(o.kind != "copy" for o in s.node("n1").body)


def test_mp_external_merge_effect_policy():
    """External-buffer producers fuse into one node; conflicting effects
    merge to RW, bodies concatenate in program order."""
    s = _mp_schedule([MemoryEffect.WRITE, MemoryEffect.READ_WRITE],
                     external=("buf",))
    stats = eliminate_multi_producers(s)
    assert stats.merged == 2 and stats.duplicated == 0
    assert len(s.nodes) == 1
    merged = s.nodes[0]
    assert merged.name.startswith("merged_node")
    # wo (n0) + rw (n1) -> rw
    assert merged.args["buf"] == MemoryEffect.READ_WRITE
    assert [o.name for o in merged.body] == ["n0_op", "n1_op"]
    assert len(s.producers_of("buf")) == 1


def test_mp_is_transactional():
    """If elimination dies mid-pass the schedule must be untouched."""
    s = _mp_schedule([MemoryEffect.WRITE, MemoryEffect.WRITE,
                      MemoryEffect.READ])
    # Poison: pre-create the exact buffer name the pass's first
    # duplication will generate, so rs.add_buffer raises RewriteError
    # mid-pass (after the producer scan already started).
    reset_fresh_names(0)
    s.buffers["buf_dup_0"] = Buffer("buf_dup_0", (16,), dims=("i",))
    before = s.to_json()
    with pytest.raises(RewriteError):
        eliminate_multi_producers(s)
    assert s.to_json() == before


# --------------------------------------------------------------------------
# 4b. Stage assignment: pure analysis + transactional applier
# --------------------------------------------------------------------------

def test_compute_stages_is_pure():
    s = _toy_schedule()
    before = s.to_json()
    mapping = compute_stages(s, 2)
    assert s.to_json() == before            # no hidden side effect
    assert set(mapping) == {"n0", "n1", "n2"}
    assert mapping["n0"] == 0


def test_apply_stages_writes_mapping():
    s = _toy_schedule()
    mapping = compute_stages(s, 2)
    apply_stages(s, mapping)
    for n in s.nodes:
        assert n.stage == mapping[n.name]


def test_assign_stages_matches_compute_plus_apply():
    s1, s2 = _toy_schedule(), _toy_schedule()
    out = assign_stages(s1, 2)
    assert out == compute_stages(s2, 2)
    apply_stages(s2, out)
    assert s1.to_json() == s2.to_json()


def test_apply_stages_all_or_nothing():
    s = _toy_schedule()
    with pytest.raises(KeyError):
        apply_stages(s, {"n0": 1, "ghost": 2, "n2": 3})
    # Nothing half-applied: every node still at its initial stage.
    assert [n.stage for n in s.nodes] == [0, 0, 0]


# --------------------------------------------------------------------------
# 5. Bench gate: fuse_s regressions fail --compare on their own
# --------------------------------------------------------------------------

def test_compile_time_gate_fails_on_fuse_regression():
    """The --compare gate must catch a fusion-pass slide (back toward the
    O(n²·DFS) balance phase) even when it hides under the pre-DSE and
    wall-time noise guards."""
    from benchmarks.bench_compile_time import FUSE_MIN_DELTA_S, compare

    base = {"arm": {"wall_s": 1.0, "total_s": 1.0,
                    "pre_dse_s": 0.030, "fuse_s": 0.020}}
    crept = {"arm": {"wall_s": 1.0, "total_s": 1.0,
                     "pre_dse_s": 0.070, "fuse_s": 0.060}}
    failures = compare(crept, base, threshold=2.0, min_delta_s=0.25)
    assert any("fusion pass time" in f for f in failures), failures
    # Millisecond jitter below the absolute guard never gates.
    jitter = {"arm": {"wall_s": 1.0, "total_s": 1.0,
                      "pre_dse_s": 0.031,
                      "fuse_s": 0.020 + FUSE_MIN_DELTA_S * 0.9}}
    assert compare(jitter, base, threshold=2.0, min_delta_s=0.25) == []


# --------------------------------------------------------------------------
# 6. Vanished-edge fallback: reachability can shrink; worklists must reseed
# --------------------------------------------------------------------------

def _multi_produced_graph():
    """Region where value ``v`` has two producers (X and F): fusing F+S
    makes v internal to the merged task, so the X→S edge *vanishes* —
    the one fuse shape that removes reachability instead of contracting
    it.  Pre-fuse, (A, B) is blocked by the path A→X→S→B; post-fuse it
    is legal."""
    from repro.core import build_lm_graph  # noqa: F401  (path setup)
    from repro.core.ir import Graph

    g = Graph("multi_v")
    g.tensor("x", (8,), dims=("i",), is_input=True)
    for name in ("a1", "v", "s1", "b1", "c1"):
        g.tensor(name, (8,), dims=("i",))
    g.op("scan", ["x"], ["a1"], {"i": 8}, flops=1, name="A")
    g.op("scan", ["a1"], ["v"], {"i": 8}, flops=50, name="X")
    g.op("scan", ["x"], ["v"], {"i": 8}, flops=5, name="F")
    g.op("scan", ["v"], ["s1"], {"i": 8}, flops=5, name="S")
    g.op("scan", ["a1", "s1"], ["b1"], {"i": 8}, flops=8, name="B")
    g.op("scan", ["x"], ["c1"], {"i": 8}, flops=2000, name="C")
    g.outputs = ["b1", "c1"]
    return g


def test_vanished_edge_fuse_bumps_epoch_and_unblocks_pair():
    g = _multi_produced_graph()
    construct_functional(g)
    rs = GraphRewriteSession(g, selfcheck=True)
    d = next(op for op in g.walk() if op.kind == "dispatch")
    task_of = {t.region[0].name: t for t in d.region}
    a, b = task_of["A"], task_of["B"]
    f, s = task_of["F"], task_of["S"]
    assert rs.creates_cycle(d, a, b)          # blocked via A→X→S→B
    epoch = rs.region_epoch(d)
    rs.fuse(d, f, s)                          # v becomes internal: X→S gone
    assert rs.region_epoch(d) == epoch + 1    # reachability shrank
    assert not rs.creates_cycle(d, a, b)      # (A, B) is legal now
    rs.rollback()
    assert rs.region_epoch(d) == epoch        # rollback restores the index


def test_balance_reseeds_after_vanished_edge_matches_enumeration():
    """The heap discards cycle-creating pairs permanently (sound under
    pure contraction); after a vanished-edge fuse it must reseed, or the
    unblocked (A, B) pair would never be fused — diverging from the old
    per-step all-pairs enumeration.  Compare the full fusion output
    against the enumeration oracle on the one graph shape that triggers
    the fallback."""
    import repro.core.fusion as fusion
    from repro.core.lower import lower_to_structural

    def oracle_balance(d, stats, rs, max_tasks=None):
        # The pre-heap implementation, kept verbatim as the oracle.
        while len(d.region) > 1:
            crit = max(rs.intensity(t) for t in d.region)
            pairs = [(a, b) for i, a in enumerate(d.region)
                     for b in d.region[i + 1:]
                     if rs.adjacent(d, a, b)
                     and not rs.creates_cycle(d, a, b)]
            forced = max_tasks is not None and len(d.region) > max_tasks
            if not forced:
                pairs = [(a, b) for a, b in pairs
                         if min(rs.intensity(a), rs.intensity(b))
                         <= fusion.LIGHT_FRACTION * crit]
            if not pairs:
                break
            a, b = min(pairs, key=lambda p: rs.intensity(p[0])
                       + rs.intensity(p[1]))
            if rs.intensity(a) + rs.intensity(b) > crit and not forced:
                break
            merged = rs.fuse(d, a, b)
            stats.balance_fusions += 1
            stats.log.append(f"balance: {a.name}+{b.name}->{merged.name}")

    def build(balance_fn):
        saved = fusion._balance_phase
        fusion._balance_phase = balance_fn
        try:
            reset_fresh_names()
            g = _multi_produced_graph()
            construct_functional(g)
            stats = fusion.fuse_tasks(g, selfcheck=True)
            return lower_to_structural(g).to_json(), stats
        finally:
            fusion._balance_phase = saved

    want, want_stats = build(oracle_balance)
    got, got_stats = build(fusion._balance_phase)
    assert got == want
    assert got_stats.log == want_stats.log
    # The scenario really exercised the unblocking: A and B ended fused.
    assert any("balance:" in line for line in got_stats.log)
