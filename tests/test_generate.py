"""Synthetic scale-stress suite (``repro.core.generate``) + scale gates.

Three layers of coverage:

1. **Generator contract** — seeded determinism (bit-identical structure
   across builds), spec sensitivity, op-count targeting, and registry
   wiring (``repro.configs`` re-exports the synth ladder next to the
   real archs).
2. **Tier-1 smoke** — full ``optimize()`` on ``synth_1k`` must come out
   verifier-clean with a live index-footprint report (fast lane).
3. **Scale acceptance (slow lane)** — ``synth_5k`` holds the PR gate:
   verifier-clean in < 20 s wall (best of two runs, so one scheduler
   hiccup cannot flake the lane) with < 2 MB peak closure-index memory;
   ``synth_10k`` is the headroom arm (no wall bound, memory gate only).

The floor-rung estimator-context regression test lives here too: with
the shared ``EstimateContext`` hoisted out of ``best_uniform``'s family
scan, the whole scan must build exactly one context regardless of how
many family members × regions it scores.
"""
import time

import pytest

from repro.configs import SYNTH_CONFIGS, get_synth, list_synths
from repro.core.estimator import EstimateContext, MeshSpec
from repro.core.generate import SynthSpec, build_synth_graph
from repro.core.optimize import optimize

MESH = MeshSpec((("data", 16), ("model", 16)))

# --------------------------------------------------------------------------
# Generator contract
# --------------------------------------------------------------------------

def test_registry_names_and_reexport():
    assert list_synths() == ["synth_1k", "synth_5k", "synth_10k"]
    with pytest.raises(KeyError):
        get_synth("synth_999")
    for name, spec in SYNTH_CONFIGS.items():
        assert spec.name == name


def test_build_is_deterministic_bit_identical():
    spec = SYNTH_CONFIGS["synth_1k"]
    a = build_synth_graph(spec)
    b = build_synth_graph(spec)
    assert a.structure_signature() == b.structure_signature()
    assert ([(o.name, o.kind, tuple(o.ins), tuple(o.outs), o.flops)
             for o in a.walk()]
            == [(o.name, o.kind, tuple(o.ins), tuple(o.outs), o.flops)
                for o in b.walk()])


def test_build_depends_only_on_spec():
    spec = SYNTH_CONFIGS["synth_1k"]
    reseeded = SynthSpec(**{**spec.__dict__, "seed": spec.seed + 1})
    assert (build_synth_graph(reseeded).structure_signature()
            != build_synth_graph(spec).structure_signature())


@pytest.mark.parametrize("name", ["synth_1k", "synth_5k", "synth_10k"])
def test_op_count_lands_near_target(name):
    spec = SYNTH_CONFIGS[name]
    g = get_synth(name)
    n = sum(1 for _ in g.walk())
    assert abs(n - spec.n_ops) <= 0.15 * spec.n_ops


def test_group_size_bounds_cross_links():
    """group_size genuinely changes the wiring: removing the bound adds
    cross-links (the transitively-composing shape the bound exists to
    prevent), so the structures must differ."""
    spec = SYNTH_CONFIGS["synth_1k"]
    unbounded = SynthSpec(**{**spec.__dict__, "group_size": 0})
    assert (build_synth_graph(unbounded).structure_signature()
            != build_synth_graph(spec).structure_signature())


# --------------------------------------------------------------------------
# Tier-1 smoke: synth_1k end to end
# --------------------------------------------------------------------------

def test_synth_1k_optimize_smoke():
    sched, plan, rep = optimize(get_synth("synth_1k"), MESH)
    assert not rep.verify.issues
    assert len(sched.nodes) > 500
    assert rep.regions > 1                  # partitioned, not flat-beamed
    assert rep.index_bytes > 0              # footprint report is live
    assert rep.fusion.index_peak_bytes > 0
    assert rep.fusion.index_peak_bytes < 2 * 1024 * 1024


# --------------------------------------------------------------------------
# Scale acceptance (slow lane)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_synth_5k_under_20s_and_2mb():
    best = float("inf")
    for _ in range(2):                      # best-of-2: absorb one hiccup
        t0 = time.perf_counter()
        sched, plan, rep = optimize(get_synth("synth_5k"), MESH)
        best = min(best, time.perf_counter() - t0)
        assert not rep.verify.issues
        assert rep.fusion.index_peak_bytes < 2 * 1024 * 1024
        if best < 20.0:
            break
    assert best < 20.0, f"synth_5k optimize() took {best:.2f}s (gate: 20s)"


@pytest.mark.slow
def test_synth_10k_verifier_clean_memory_bounded():
    sched, plan, rep = optimize(get_synth("synth_10k"), MESH)
    assert not rep.verify.issues
    assert len(sched.nodes) > 5000
    assert rep.fusion.index_peak_bytes < 2 * 1024 * 1024


# --------------------------------------------------------------------------
# best_uniform builds exactly one EstimateContext
# --------------------------------------------------------------------------

def test_best_uniform_builds_one_estimate_context(monkeypatch):
    """The family scan and every per-region retry reuse one hoisted
    context: structure is assignment-independent, so rebuilding it per
    estimate() call was O(members × edges) for nothing.  Count real
    constructions to pin the hoist."""
    import importlib

    from repro.core.lower import lower_to_structural
    from repro.core.parallelize import best_uniform
    from repro.core.rewrite import dse_regions
    est_mod = importlib.import_module("repro.core.estimator")
    par_mod = importlib.import_module("repro.core.parallelize")

    g = get_synth("synth_1k")
    from repro.core.fusion import fuse_tasks
    fuse_tasks(g)
    sched = lower_to_structural(g)
    regions = dse_regions(sched)

    calls = []
    real_init = EstimateContext.__init__

    def counting_init(self, s):
        calls.append(s)
        real_init(self, s)

    monkeypatch.setattr(est_mod.EstimateContext, "__init__", counting_init)
    assert par_mod.EstimateContext is est_mod.EstimateContext
    t0 = time.perf_counter()
    assignment, cost = best_uniform(sched, MESH, regions=regions)
    dt = time.perf_counter() - t0
    assert cost.total_s > 0
    assert len(calls) == 1, (f"best_uniform built {len(calls)} "
                             "EstimateContexts; the hoist guarantees 1")
    # Timing regression: the floor rung stays interactive on 1k+-node
    # schedules.  Pre-hoist, every estimate() call rebuilt the context —
    # an O(nodes) topology revalidation *per buffer* — putting this same
    # call in the minutes; the bound is loose against CI noise but tight
    # against any reintroduced per-call rebuild.
    assert dt < 15.0, f"best_uniform took {dt:.2f}s on synth_1k"
