"""Elastic scaling: re-stitch checkpoints across mesh/host changes.

A job restarted on a different topology (16→8 hosts after failures, or
grown back to 16) calls ``reshard_checkpoint``: every host loads the union
of the old shards it needs and slices out its new shard.  Because the
data loader is keyed by ``(step, shard)`` (see repro.data), the input
stream re-partitions consistently too — no sample is lost or duplicated.

For the single-process container the "hosts" are simulated shard files;
the stitching logic is identical to the multi-host case.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import numpy as np

from .checkpoint import CheckpointManager, _flatten


def gather_full_tree(directory: str | Path, step: int, like: Any) -> Any:
    """Load + concatenate every host shard of a checkpoint along the
    leading (data-sharded) axis when host shards differ, or verify
    replicas agree.

    Validates the step before stitching: the directory must carry the
    ``COMMITTED`` marker, and every host shard the manifest promises
    (``n_hosts``) must be present — a silently-missing shard would
    otherwise stitch a smaller, wrong tree."""
    import ml_dtypes
    directory = Path(directory)
    d = directory / f"step_{step:06d}"
    if not (d / "COMMITTED").exists():
        raise ValueError(
            f"checkpoint step {step} at {d} is not committed "
            "(missing COMMITTED marker); refusing to stitch a "
            "partial write")
    manifest = json.loads((d / "manifest.json").read_text())
    bf16 = set(manifest.get("bf16_keys", ()))
    shards = sorted(d.glob("shard_h*.npz"))
    n_hosts = int(manifest.get("n_hosts", len(shards)))
    have = {int(s.name[len("shard_h"):-len(".npz")]) for s in shards}
    missing = sorted(set(range(n_hosts)) - have)
    if missing:
        raise ValueError(
            f"checkpoint step {step} at {d}: manifest promises "
            f"{n_hosts} host shards but hosts {missing} are missing "
            f"(found {sorted(have)})")
    datas = [np.load(s) for s in shards]
    named, treedef = _flatten(like)
    leaves = []
    for key, ref in named:
        parts = [dt[key].view(ml_dtypes.bfloat16) if key in bf16
                 else dt[key] for dt in datas]
        if all(p.shape == parts[0].shape for p in parts) and len(parts) > 1:
            same = all(np.array_equal(parts[0], p) for p in parts[1:])
            arr = parts[0] if same else np.concatenate(parts, axis=0)
        else:
            arr = (parts[0] if len(parts) == 1
                   else np.concatenate(parts, axis=0))
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves)


def reshard_checkpoint(src_dir: str | Path, step: int, like: Any,
                       new_n_hosts: int, dst_dir: str | Path) -> None:
    """Rewrite a committed checkpoint for a different host count.  Host
    shards are assumed replicated (params/opt under FSDP are saved
    replicated per host after an all-gather, or identical per host) —
    each new host gets a full copy, sliced lazily at restore by the new
    mesh's shardings."""
    full = gather_full_tree(src_dir, step, like)
    for h in range(new_n_hosts):
        mgr = CheckpointManager(dst_dir, host_id=h, n_hosts=new_n_hosts)
        mgr.save(step, full, blocking=True)


def mesh_for_hosts(n_hosts: int, base: "MeshSpec" = None) -> "MeshSpec":
    """The serving/compile mesh after an elastic rescale: the data axis
    scales with the surviving host count, the model axis is untouched
    (re-sharding weights across a *different model parallelism* is a
    checkpoint rewrite, not an elastic event)."""
    from ..core.estimator import SINGLE_POD, MeshSpec
    base = base if base is not None else SINGLE_POD
    axes = tuple((a, n_hosts if a in ("data", "pod") and i == 0 else s)
                 for i, (a, s) in enumerate(base.axes))
    return MeshSpec(axes)


def replan_for_topology(cache, cfg, *, new_mesh, bucket: str,
                        graph_factory, optimize_kwargs: dict | None = None):
    """Re-plan after a host-count change — warm, not cold.

    An elastic rescale (16→8 hosts after failures, back to 16 on
    recovery) changes the mesh, so the old :class:`~repro.core.PlanKey`
    misses.  Routing the miss through
    :func:`~repro.core.fetch_or_optimize` means the cache's
    :meth:`~repro.core.PlanCache.nearest` finds the *same-fingerprint*
    entry from the previous topology (same config outranks same mesh in
    donor scoring) and seeds the DSE from its assignment — the restarted
    job pays a warm re-DSE, a fraction of the cold wall, and the new
    plan is cached so the *next* rescale back to this topology is a
    sub-ms hit.  Returns ``(plan, source, report)`` exactly like
    :func:`~repro.core.fetch_or_optimize`."""
    from ..core.plan_cache import PlanKey, fetch_or_optimize
    key = PlanKey.make(cfg, new_mesh, bucket)
    return fetch_or_optimize(cache, key, new_mesh, graph_factory,
                             optimize_kwargs=optimize_kwargs)


def scale_batch_schedule(global_batch: int, old_hosts: int,
                         new_hosts: int) -> dict:
    """Keep the *global* batch invariant across rescales (per-host batch
    changes); returns the new loader partition."""
    if global_batch % new_hosts:
        raise ValueError(f"global_batch {global_batch} not divisible by "
                         f"{new_hosts} hosts")
    return {"n_hosts": new_hosts,
            "local_batch": global_batch // new_hosts,
            "note": f"rescaled from {old_hosts} hosts; global batch and "
                    f"data stream unchanged"}
