"""Fault-tolerant sharded checkpointing.

Layout (one directory per step):

    ckpt_dir/
      step_000120/
        manifest.json          # pytree structure, shapes, dtypes, mesh
        shard_h000.npz         # this host's param/opt shards
        COMMITTED              # written last — atomic commit marker

Writes go to ``step_XXXX.tmp`` and are renamed only after every shard +
manifest lands, so a preemption mid-write can never corrupt the latest
checkpoint; ``latest_step`` ignores uncommitted directories.  Saving is
asynchronous (background thread) — the train loop donates nothing and
keeps stepping while the previous state is serialised.  A failure inside
the background write is captured and re-raised on the next ``wait()`` /
``save()`` instead of dying silently on a daemon thread.

Commit markers guard against *partial* writes; silent bit-rot after
commit (a bad disk, a truncated object-store download) is caught by a
per-shard CRC32 recorded in the manifest and verified on ``restore``.
``restore_latest`` walks back to the newest step that verifies, so one
corrupt checkpoint costs re-training from the previous one — not the
job.

Elastic restore: arrays are stored logically-whole per host shard with
their global offsets; ``repro.distributed.elastic`` re-stitches them for
a different mesh/host count.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import time
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

logger = logging.getLogger(__name__)


class CheckpointCorruptionError(RuntimeError):
    """A committed checkpoint failed CRC verification on restore."""


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    # jax.tree.flatten_with_path only exists on newer JAX; the tree_util
    # spelling works on every version this repo supports.
    flatten_with_path = getattr(jax.tree, "flatten_with_path", None) \
        or jax.tree_util.tree_flatten_with_path
    leaves, treedef = flatten_with_path(tree)
    named = [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path), leaf) for path, leaf in leaves]
    return named, treedef


@dataclass
class CheckpointManager:
    directory: str | Path
    host_id: int = 0
    n_hosts: int = 1
    keep: int = 3
    _thread: Optional[threading.Thread] = field(default=None, repr=False)
    _error: Optional[BaseException] = field(default=None, repr=False)

    def __post_init__(self):
        self.directory = Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        # Pull device shards to host memory synchronously (cheap copy),
        # serialise + fsync in the background.  bfloat16 has no native
        # numpy storage — persist as uint16 bits + a dtype tag.
        named, _ = _flatten(tree)
        host_named = []
        bf16_keys = []
        for k, v in named:
            arr = np.asarray(v)
            if arr.dtype.name == "bfloat16":
                arr = arr.view(np.uint16)
                bf16_keys.append(k)
            host_named.append((k, arr))
        self.wait()

        def write():
            tmp = self.directory / f"step_{step:06d}.tmp"
            final = self.directory / f"step_{step:06d}"
            tmp.mkdir(parents=True, exist_ok=True)
            shard_name = f"shard_h{self.host_id:03d}.npz"
            np.savez(tmp / shard_name, **dict(host_named))
            crc32 = {shard_name: zlib.crc32((tmp / shard_name).read_bytes())}
            if (final / "manifest.json").exists():
                # Another host committed this step first: carry its shard
                # CRCs forward so ours don't clobber them.
                prev = json.loads((final / "manifest.json").read_text())
                crc32 = {**prev.get("crc32", {}), **crc32}
            manifest = {
                "step": step,
                "n_hosts": self.n_hosts,
                "keys": [k for k, _ in host_named],
                "shapes": {k: list(v.shape) for k, v in host_named},
                "dtypes": {k: str(v.dtype) for k, v in host_named},
                "bf16_keys": bf16_keys,
                "crc32": crc32,
                "time": time.time(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "COMMITTED").touch()
            if final.exists():
                # Another host already committed this step: merge our
                # shard + manifest into the shared directory.
                for f in tmp.iterdir():
                    os.replace(f, final / f.name)
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                os.replace(tmp, final)
            self._gc()

        def guarded_write():
            try:
                write()
            except BaseException as e:   # surfaced on wait()/next save()
                self._error = e

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=guarded_write,
                                            daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join the in-flight background save.  A failure captured on the
        writer thread is re-raised *here* (and from the next ``save()``,
        which waits first) — an async save error must not be silent."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:06d}",
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in sorted(self.directory.glob("step_*")):
            if p.suffix == ".tmp" or not (p / "COMMITTED").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure of ``like`` (shapes must match).

        The host shard's CRC32 is verified against the manifest before
        deserialising; a mismatch raises
        :class:`CheckpointCorruptionError` (post-commit bit-rot — the
        atomic-commit marker cannot catch it)."""
        import ml_dtypes
        d = self.directory / f"step_{step:06d}"
        manifest = json.loads((d / "manifest.json").read_text())
        bf16 = set(manifest.get("bf16_keys", ()))
        shard_name = f"shard_h{self.host_id:03d}.npz"
        expect = manifest.get("crc32", {}).get(shard_name)
        if expect is not None:
            got = zlib.crc32((d / shard_name).read_bytes())
            if got != expect:
                raise CheckpointCorruptionError(
                    f"step {step}: {shard_name} crc32 {got:#010x} != "
                    f"manifest {expect:#010x} (corrupt shard)")
        data = np.load(d / shard_name)
        named, treedef = _flatten(like)
        leaves = []
        for key, ref in named:
            arr = data[key]
            if key in bf16:
                arr = arr.view(ml_dtypes.bfloat16)
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {ref.shape}; "
                    "use repro.distributed.elastic.reshard_checkpoint")
            leaves.append(jax.device_put(arr).astype(ref.dtype) if hasattr(
                ref, "dtype") else arr)
        return jax.tree.unflatten(treedef, leaves)

    def restore_latest(self, like: Any) -> tuple[Optional[int], Any]:
        """Restore the newest committed step that *verifies*.  A step
        failing CRC (or deserialisation) is skipped with a warning and
        the previous committed step is tried — one corrupt checkpoint
        costs re-training from the prior one, not the job.  Raises only
        when every committed step fails."""
        steps = self.steps()
        if not steps:
            return None, like
        last_err: Optional[BaseException] = None
        for step in reversed(steps):
            try:
                return step, self.restore(step, like)
            except (CheckpointCorruptionError, OSError,
                    ValueError, KeyError) as e:
                logger.warning(
                    "checkpoint step %d failed to restore (%s); falling "
                    "back to previous committed step", step, e)
                last_err = e
        raise CheckpointCorruptionError(
            f"no committed step in {self.directory} restored cleanly "
            f"(tried {steps[::-1]})") from last_err
