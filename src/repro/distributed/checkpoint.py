"""Fault-tolerant sharded checkpointing.

Layout (one directory per step):

    ckpt_dir/
      step_000120/
        manifest.json          # pytree structure, shapes, dtypes, mesh
        shard_h000.npz         # this host's param/opt shards
        COMMITTED              # written last — atomic commit marker

Writes go to ``step_XXXX.tmp`` and are renamed only after every shard +
manifest lands, so a preemption mid-write can never corrupt the latest
checkpoint; ``latest_step`` ignores uncommitted directories.  Saving is
asynchronous (background thread) — the train loop donates nothing and
keeps stepping while the previous state is serialised.

Elastic restore: arrays are stored logically-whole per host shard with
their global offsets; ``repro.distributed.elastic`` re-stitches them for
a different mesh/host count.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    # jax.tree.flatten_with_path only exists on newer JAX; the tree_util
    # spelling works on every version this repo supports.
    flatten_with_path = getattr(jax.tree, "flatten_with_path", None) \
        or jax.tree_util.tree_flatten_with_path
    leaves, treedef = flatten_with_path(tree)
    named = [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path), leaf) for path, leaf in leaves]
    return named, treedef


@dataclass
class CheckpointManager:
    directory: str | Path
    host_id: int = 0
    n_hosts: int = 1
    keep: int = 3
    _thread: Optional[threading.Thread] = field(default=None, repr=False)

    def __post_init__(self):
        self.directory = Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        # Pull device shards to host memory synchronously (cheap copy),
        # serialise + fsync in the background.  bfloat16 has no native
        # numpy storage — persist as uint16 bits + a dtype tag.
        named, _ = _flatten(tree)
        host_named = []
        bf16_keys = []
        for k, v in named:
            arr = np.asarray(v)
            if arr.dtype.name == "bfloat16":
                arr = arr.view(np.uint16)
                bf16_keys.append(k)
            host_named.append((k, arr))
        self.wait()

        def write():
            tmp = self.directory / f"step_{step:06d}.tmp"
            final = self.directory / f"step_{step:06d}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / f"shard_h{self.host_id:03d}.npz",
                     **dict(host_named))
            manifest = {
                "step": step,
                "n_hosts": self.n_hosts,
                "keys": [k for k, _ in host_named],
                "shapes": {k: list(v.shape) for k, v in host_named},
                "dtypes": {k: str(v.dtype) for k, v in host_named},
                "bf16_keys": bf16_keys,
                "time": time.time(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "COMMITTED").touch()
            if final.exists():
                # Another host already committed this step: merge our
                # shard + manifest into the shared directory.
                for f in tmp.iterdir():
                    os.replace(f, final / f.name)
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:06d}",
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in sorted(self.directory.glob("step_*")):
            if p.suffix == ".tmp" or not (p / "COMMITTED").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure of ``like`` (shapes must match)."""
        import ml_dtypes
        d = self.directory / f"step_{step:06d}"
        manifest = json.loads((d / "manifest.json").read_text())
        bf16 = set(manifest.get("bf16_keys", ()))
        data = np.load(d / f"shard_h{self.host_id:03d}.npz")
        named, treedef = _flatten(like)
        leaves = []
        for key, ref in named:
            arr = data[key]
            if key in bf16:
                arr = arr.view(ml_dtypes.bfloat16)
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {ref.shape}; "
                    "use repro.distributed.elastic.reshard_checkpoint")
            leaves.append(jax.device_put(arr).astype(ref.dtype) if hasattr(
                ref, "dtype") else arr)
        return jax.tree.unflatten(treedef, leaves)

    def restore_latest(self, like: Any) -> tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, like
        return step, self.restore(step, like)
