"""Straggler detection & mitigation policy.

On a 1000+ node fleet, a single slow host gates every synchronous
collective.  The monitor keeps a per-host EMA of step times, flags hosts
slower than ``threshold`` × the fleet median, and recommends actions the
trainer applies:

* ``rebalance``  — shift part of the loader shard range away from the
  straggler (works because the loader is keyed by (step, shard)),
* ``checkpoint_and_evict`` — persistent stragglers trigger an early
  checkpoint so the scheduler can replace the host and the job restarts
  elastically (see elastic.py).

The container has one host; tests drive the policy with synthetic
timings — the decision logic is exactly what a fleet deployment uses.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field


@dataclass
class StragglerMonitor:
    n_hosts: int
    ema: float = 0.9
    threshold: float = 1.5       # × median ⇒ straggler
    evict_after: int = 20        # consecutive flagged steps
    _ema_s: dict[int, float] = field(default_factory=dict)
    _flagged: dict[int, int] = field(default_factory=dict)

    def record(self, host_times_s: dict[int, float]) -> None:
        for h, t in host_times_s.items():
            prev = self._ema_s.get(h, t)
            self._ema_s[h] = self.ema * prev + (1 - self.ema) * t

    def stragglers(self) -> list[int]:
        if len(self._ema_s) < 2:
            return []
        # median_low: on tiny fleets the plain median of [fast, slow]
        # averages the straggler into the baseline and masks it.
        med = statistics.median_low(sorted(self._ema_s.values()))
        return [h for h, t in self._ema_s.items()
                if t > self.threshold * med]

    def step(self, host_times_s: dict[int, float]) -> list[dict]:
        """Record one step; return mitigation actions."""
        self.record(host_times_s)
        actions = []
        current = set(self.stragglers())
        for h in list(self._flagged):
            if h not in current:
                del self._flagged[h]
        for h in current:
            self._flagged[h] = self._flagged.get(h, 0) + 1
            if self._flagged[h] == 1:
                med = statistics.median(self._ema_s.values())
                actions.append({
                    "action": "rebalance", "host": h,
                    "shed_fraction": min(
                        0.5, 1.0 - med / self._ema_s[h])})
            elif self._flagged[h] >= self.evict_after:
                actions.append({"action": "checkpoint_and_evict",
                                "host": h})
                self._flagged[h] = 1  # reset after recommending eviction
        return actions

    def shard_weights(self) -> dict[int, float]:
        """Relative loader share per host ∝ 1/EMA (slow hosts get less).

        Hosts with no timing sample yet are assumed fleet-median speed
        (not dropped — every host in ``range(n_hosts)`` gets a share),
        and EMAs are clamped away from zero so a degenerate 0-second
        sample cannot divide out the whole distribution."""
        if not self._ema_s:
            return {h: 1.0 / self.n_hosts for h in range(self.n_hosts)}
        eps = 1e-9
        med = max(statistics.median(self._ema_s.values()), eps)
        hosts = set(range(self.n_hosts)) | set(self._ema_s)
        inv = {h: 1.0 / max(self._ema_s.get(h, med), eps) for h in hosts}
        z = sum(inv.values())
        return {h: v / z for h, v in inv.items()}
