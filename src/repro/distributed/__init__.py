from .checkpoint import CheckpointManager
from .elastic import gather_full_tree, reshard_checkpoint
from .straggler import StragglerMonitor

__all__ = ["CheckpointManager", "gather_full_tree", "reshard_checkpoint",
           "StragglerMonitor"]
