from .checkpoint import CheckpointManager
from .elastic import (gather_full_tree, mesh_for_hosts, replan_for_topology,
                      reshard_checkpoint, scale_batch_schedule)
from .straggler import StragglerMonitor

__all__ = ["CheckpointManager", "gather_full_tree", "reshard_checkpoint",
           "mesh_for_hosts", "replan_for_topology", "scale_batch_schedule",
           "StragglerMonitor"]
