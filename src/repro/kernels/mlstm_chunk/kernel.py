"""xLSTM mLSTM chunkwise Pallas TPU kernel.

Grid: (batch·heads, chunks) with the chunk dimension sequential,
carrying the (Dh, Dh) matrix memory C, the normaliser n (Dh,), and the
stabiliser m (scalar) in VMEM scratch.  Per chunk:

* intra-chunk: the (L, L) decay-masked qkᵀ quadratic — two MXU matmuls,
* inter-chunk: q reads the carried matrix memory with cumulative decay,
* state update: rank-L update of C with per-step forget products.

The stabilised exponential gating (max-subtraction) follows the xLSTM
paper's log-space formulation so f32 accumulation never overflows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, y_ref,
                  c_ref, n_ref, m_ref, *, chunk: int, dh: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    q = q_ref[0].astype(jnp.float32)                 # (L, Dh)
    k = k_ref[0].astype(jnp.float32) / (dh ** 0.5)   # xLSTM: scale k only
    v = v_ref[0].astype(jnp.float32)
    i_p = i_ref[0].astype(jnp.float32)               # (L,)
    logf = jax.nn.log_sigmoid(f_ref[0].astype(jnp.float32))

    F = jnp.cumsum(logf)                             # (L,) inclusive
    m_prev = m_ref[0, 0]
    # Stabiliser candidates: inter-chunk (m_prev + F_t) vs intra (D row max)
    L = q.shape[0]
    tpos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # D[t,s] = F_t - F_s + i_s for s<=t
    dmat = F[:, None] - F[None, :] + i_p[None, :]
    dmat = jnp.where(spos <= tpos, dmat, NEG)
    m_intra = jnp.max(dmat, axis=1)                  # (L,)
    m_t = jnp.maximum(m_prev + F, m_intra)

    inter_decay = jnp.exp(m_prev + F - m_t)          # (L,)
    dexp = jnp.exp(dmat - m_t[:, None])              # (L, L)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * dexp
    y_intra = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = (q @ c_ref[...]) * inter_decay[:, None]
    num = y_intra + y_inter
    n_inter = (q @ n_ref[...][:, None])[:, 0] * inter_decay
    denom = jnp.sum(w, axis=1) + n_inter
    denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m_t)) + 1e-6
    y_ref[0, ...] = (num / denom[:, None]).astype(y_ref.dtype)

    # ---- state update to end of chunk --------------------------------------
    m_new = m_t[-1]
    F_last = F[-1]
    # contribution of each step s: exp(F_last - F_s + i_s - m_new)
    upd = jnp.exp(F_last - F + i_p - m_new)          # (L,)
    decay_all = jnp.exp(m_prev + F_last - m_new)
    c_ref[...] = decay_all * c_ref[...] + jax.lax.dot_general(
        k * upd[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = decay_all * n_ref[...] + jnp.sum(k * upd[:, None], axis=0)
    m_ref[0, 0] = m_new


def mlstm_chunk(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                interpret: bool = True) -> jax.Array:
    """q,k,v (BH, S, Dh); i_pre,f_pre (BH, S) → y (BH, S, Dh) f32."""
    BH, S, Dh = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    kern = functools.partial(_mlstm_kernel, chunk=chunk, dh=Dh)
    return pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, chunk, Dh), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((Dh, Dh), jnp.float32),
            pltpu.VMEM((Dh,), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, i_pre, f_pre)
