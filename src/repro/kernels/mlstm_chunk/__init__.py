from . import ops, ref
