"""Jit wrapper: model layout (B,S,H,Dh) ↔ kernel layout (B·H,S,Dh)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import mlstm_chunk as _kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                interpret: bool = True):
    """q,k,v (B,S,H,Dh); i/f (B,S,H) → (B,S,H·Dh) f32."""
    B, S, H, Dh = q.shape
    def tok(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    y = _kernel(tok(q), tok(k), tok(v),
                i_pre.transpose(0, 2, 1).reshape(B * H, S),
                f_pre.transpose(0, 2, 1).reshape(B * H, S),
                chunk=chunk, interpret=interpret)
    return y.reshape(B, H, S, Dh).transpose(0, 2, 1, 3).reshape(
        B, S, H * Dh)
