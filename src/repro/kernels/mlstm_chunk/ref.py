"""Oracle: the models/xlstm.py parallel form over the full sequence."""
import jax.numpy as jnp

from repro.models.xlstm import _mlstm_parallel


def mlstm_ref(q, k, v, i_pre, f_pre):
    """q,k,v (BH,S,Dh); i/f (BH,S) → (BH,S,Dh) f32."""
    BH, S, Dh = q.shape
    y = _mlstm_parallel(q[:, :, None], k[:, :, None], v[:, :, None],
                        i_pre[:, :, None], f_pre[:, :, None])
    return y[:, :, 0].astype(jnp.float32)
