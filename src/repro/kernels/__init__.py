"""Pallas TPU kernels for the compute hot spots.

Each kernel package ships ``kernel.py`` (pl.pallas_call + explicit
BlockSpec VMEM tiling), ``ops.py`` (jit'd public wrapper) and ``ref.py``
(pure-jnp oracle).  On this CPU container kernels are validated with
``interpret=True``; on TPU the same BlockSpecs drive MXU/VMEM execution.
"""
