from . import ops, ref
