"""Fused RMSNorm Pallas TPU kernel: one HBM read + one write per element
(the unfused graph reads x three times: square-mean, normalise, scale).

Grid: (row_blocks,); each step loads a (row_block, D) tile into VMEM,
reduces within registers, normalises and scales in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            row_block: int = 256, interpret: bool = True) -> jax.Array:
    """x (R, D), scale (D,) → (R, D)."""
    R, D = x.shape
    row_block = min(row_block, R)
    assert R % row_block == 0
    kern = functools.partial(_rms_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(R // row_block,),
        in_specs=[pl.BlockSpec((row_block, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((row_block, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scale)
