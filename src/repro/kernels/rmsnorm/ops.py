"""Jit wrapper for fused RMSNorm (flattens leading dims)."""
from __future__ import annotations

import functools

import jax

from .kernel import rmsnorm as _kernel


@functools.partial(jax.jit, static_argnames=("eps", "row_block",
                                             "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, row_block: int = 256,
            interpret: bool = True):
    shape = x.shape
    y = _kernel(x.reshape(-1, shape[-1]), scale, eps=eps,
                row_block=min(row_block, max(x.size // shape[-1], 1)),
                interpret=interpret)
    return y.reshape(shape)
