"""Oracle: models/layers.py rms_norm."""
from repro.models.layers import rms_norm as rmsnorm_ref  # noqa: F401
