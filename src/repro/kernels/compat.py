"""Version-compat shims for the Pallas TPU API surface.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (the
``TPU`` prefix was redundant inside ``pallas.tpu``); depending on the
installed JAX exactly one of the two exists.  Every kernel imports
``CompilerParams`` from here so the five Pallas kernels stay agnostic to
which side of the rename the container is on.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
