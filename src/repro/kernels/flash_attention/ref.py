"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: int | None = None) -> jax.Array:
    """q (BH, G, Sq, Dh); k (BH, Skv, Dh); v (BH, Skv, Dv)."""
    BH, G, Sq, Dh = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bgqd,bkd->bgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(Dh)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqk,bkd->bgqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
