"""Public jit'd wrapper: model-layout (B,S,H,Dh) ↔ kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "q_block", "kv_block",
                                             "interpret"))
def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        window: int | None = None, q_block: int = 128,
        kv_block: int = 512, interpret: bool = True) -> jax.Array:
    """q (B,Sq,H,Dh); k/v (B,Skv,KVH,Dh) with GQA → (B,Sq,H,Dv)."""
    B, Sq, H, Dh = q.shape
    KVH = k.shape[2]
    G = H // KVH
    Dv = v.shape[-1]
    qk = q.reshape(B, Sq, KVH, G, Dh).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KVH, G, Sq, Dh)
    kk = k.transpose(0, 2, 1, 3).reshape(B * KVH, -1, Dh)
    vk = v.transpose(0, 2, 1, 3).reshape(B * KVH, -1, Dv)
    o = flash_attention(qk, kk, vk, causal=causal, window=window,
                        q_block=q_block, kv_block=kv_block,
                        interpret=interpret)
    return o.reshape(B, KVH, G, Sq, Dv).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sq, H, Dv)
