from . import ops, ref
