"""Flash attention Pallas TPU kernel: blockwise online softmax with GQA,
causal and sliding-window masking.

Grid: (batch·kv_heads, q_blocks, kv_blocks) — the last dimension is
sequential ("arbitrary") on TPU, carrying the running (m, l, acc)
statistics in VMEM scratch across kv blocks; batch·heads and q blocks are
parallel across cores.  Block shapes keep the working set
(q_tile + k_tile + v_tile + acc) in VMEM and the matmul dims
MXU-aligned: q/kv tiles default 128·512 with Dh up to 256.

HBM→VMEM movement per (bh, i) pass: q once, full K/V stream once — the
FlashAttention dataflow; nothing quadratic ever leaves VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams

NEG = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 q_block: int, kv_block: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (G, qb, Dh)
    k = k_ref[0].astype(jnp.float32)               # (kb, Dh)
    v = v_ref[0].astype(jnp.float32)               # (kb, Dv)

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # s: (G, qb, kb); mask from global positions
    qpos = i * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 0)
    kpos = j * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 1)
    mask = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    s = jnp.where(mask[None], s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_block: int = 128, kv_block: int = 512,
                    interpret: bool = True) -> jax.Array:
    """q (BH, G, Sq, Dh); k (BH, Skv, Dh); v (BH, Skv, Dv) →
    (BH, G, Sq, Dv).  BH = batch × kv_heads, G = query group size."""
    BH, G, Sq, Dh = q.shape
    Skv = k.shape[1]
    Dv = v.shape[2]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0
    nq, nk = Sq // q_block, Skv // kv_block
    scale = 1.0 / math.sqrt(Dh)

    kern = functools.partial(_attn_kernel, scale=scale, causal=causal,
                             window=window, q_block=q_block,
                             kv_block=kv_block)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G, q_block, Dh), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, kv_block, Dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, q_block, Dv),
                               lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, q_block), jnp.float32),
            pltpu.VMEM((G, q_block), jnp.float32),
            pltpu.VMEM((G, q_block, Dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
