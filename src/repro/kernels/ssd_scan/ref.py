"""Oracle: the sequential selective scan (models/ssm.py step form)."""
from repro.models.ssm import selective_scan_seq


def ssd_scan_ref(x, dt, A, Bm, Cm):
    y, _ = selective_scan_seq(x, dt, A, Bm, Cm)
    return y
