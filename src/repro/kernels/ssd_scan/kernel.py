"""Mamba selective-scan Pallas TPU kernel (chunked SSD form).

Grid: (batch, d_inner_blocks, chunks) — chunks iterate sequentially
("arbitrary"), carrying the (d_block, N) SSM state in VMEM scratch across
chunk steps; batch and channel blocks are parallel.  Within a chunk the
recurrence runs as a fori_loop entirely in VMEM/VREGs: the HBM traffic is
exactly one read of (x, dt, B, C) and one write of y per token — the
memory-optimal dataflow for the recurrence (it is memory-bound: ~6·N
flops per element against ~8 bytes moved).

Channel blocking keeps the VMEM working set at
chunk·d_block·(2+N/…) ≪ 16 MiB and d_block a lane multiple (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)       # (chunk, d_block)
    dt = dt_ref[0].astype(jnp.float32)     # (chunk, d_block)
    A = a_ref[...].astype(jnp.float32)     # (d_block, N)
    Bm = b_ref[0].astype(jnp.float32)      # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)      # (chunk, N)

    def step(t, carry):
        h, y = carry
        dA = jnp.exp(dt[t][:, None] * A)               # (d_block, N)
        h = dA * h + (dt[t] * x[t])[:, None] * Bm[t][None, :]
        y = y.at[t].set(h @ Cm[t])                     # (d_block,)
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_ref[...] = h
    y_ref[0, ...] = y.astype(y_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 128, d_block: int = 128,
             interpret: bool = True) -> jax.Array:
    """x, dt (B,S,Din); A (Din,N); Bm,Cm (B,S,N) → y (B,S,Din) f32."""
    B, S, Din = x.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    d_block = min(d_block, Din)
    assert S % chunk == 0 and Din % d_block == 0
    nc, nd = S // chunk, Din // d_block

    kern = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, d_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((d_block, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block),
                               lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, Din), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_block, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
