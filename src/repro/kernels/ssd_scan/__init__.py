from . import ops, ref
