"""Jit wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan as _kernel


@functools.partial(jax.jit,
                   static_argnames=("chunk", "d_block", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, d_block: int = 128,
             interpret: bool = True):
    return _kernel(x, dt, A, Bm, Cm, chunk=chunk, d_block=d_block,
                   interpret=interpret)
