"""Jit wrapper for the grouped expert matmul."""
from __future__ import annotations

import functools

import jax

from .kernel import moe_gmm as _kernel


@functools.partial(jax.jit, static_argnames=("c_block", "f_block",
                                             "d_block", "interpret"))
def moe_gmm(x, w, group_sizes, *, c_block: int = 128, f_block: int = 512,
            d_block: int = 512, interpret: bool = True):
    return _kernel(x, w, group_sizes, c_block=c_block, f_block=f_block,
                   d_block=d_block, interpret=interpret)
