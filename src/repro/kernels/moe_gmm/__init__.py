from . import ops, ref
