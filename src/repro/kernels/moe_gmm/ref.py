"""Oracle for the grouped expert matmul."""
import jax.numpy as jnp


def moe_gmm_ref(x, w, group_sizes):
    y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    C = x.shape[1]
    mask = jnp.arange(C)[None, :, None] < group_sizes[:, None, None]
    return jnp.where(mask, y, 0).astype(x.dtype)
