"""Grouped expert matmul Pallas TPU kernel.

Computes y[e] = x[e] @ w[e] for the (E, C, D)·(E, D, F) dispatched-expert
batch, with per-expert *valid row counts* (``group_sizes``) so padded
capacity slots cost no MXU work beyond their tile.

Grid: (E, C_blocks, F_blocks, D_blocks) — the contraction (last) dim is
sequential, accumulating into a VMEM f32 scratch tile; (E, C, F) tiles
are parallel.  Block shapes default to the MXU-native 128×128×512 so the
working set (x_tile + w_tile + acc) stays ≪ VMEM and every matmul dim is
lane-aligned.  Rows beyond ``group_sizes[e]`` are masked at the epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import CompilerParams


def _gmm_kernel(gs_ref, x_ref, w_ref, y_ref, acc_ref, *, c_block: int):
    d_i = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(d_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)        # (c_block, d_block)
    w = w_ref[0].astype(jnp.float32)        # (d_block, f_block)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    e = pl.program_id(0)
    ci = pl.program_id(1)

    @pl.when(d_i == nd - 1)
    def _epilogue():
        n_valid = gs_ref[e]
        row = ci * c_block + jax.lax.broadcasted_iota(
            jnp.int32, acc_ref.shape, 0)
        y_ref[0, ...] = jnp.where(row < n_valid, acc_ref[...],
                                  0).astype(y_ref.dtype)


def moe_gmm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
            c_block: int = 128, f_block: int = 512, d_block: int = 512,
            interpret: bool = True) -> jax.Array:
    """x (E, C, D) · w (E, D, F) with valid-row masking → (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[-1]
    c_block = min(c_block, C)
    f_block = min(f_block, F)
    d_block = min(d_block, D)
    assert C % c_block == 0 and F % f_block == 0 and D % d_block == 0

    kern = functools.partial(_gmm_kernel, c_block=c_block)
    return pl.pallas_call(
        kern,
        grid=(E, C // c_block, F // f_block, D // d_block),
        in_specs=[
            pl.BlockSpec((E,), lambda e, c, f, d: (0,)),
            pl.BlockSpec((1, c_block, d_block),
                         lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, d_block, f_block),
                         lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, c_block, f_block),
                               lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((c_block, f_block), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(group_sizes, x, w)
