"""stablelm-3b — dense MHA with LayerNorm and 25% partial rotary
[hf:stabilityai/stablelm-2-1_6b; unverified]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab=50304, head_dim=80,
        norm="ln", rope_pct=0.25,
        sub_quadratic=False,
        source="hf:stabilityai/stablelm-2-1_6b",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256, head_dim=16,
        norm="ln", rope_pct=0.25,
        sub_quadratic=False,
        source="hf:stabilityai/stablelm-2-1_6b",
    )
