"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].  SWA window 4096 bounds the decode state,
making long_500k applicable (window-bounded KV)."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10240, vocab=32000, head_dim=120,
        attn_window=4096,
        sub_quadratic=True,     # SWA: decode state bounded by the window
        source="arXiv:2401.16818",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="danube-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        attn_window=16,
        sub_quadratic=True,
        source="arXiv:2401.16818",
    )
