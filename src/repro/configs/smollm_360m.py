"""smollm-360m — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab=49152, head_dim=64,
        tie_embeddings=True,
        sub_quadratic=False,
        source="hf:HuggingFaceTB/SmolLM-360M",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m-smoke", family="dense",
        n_layers=2, d_model=60, n_heads=5, n_kv_heads=1,
        d_ff=128, vocab=256, head_dim=12,
        tie_embeddings=True,
        sub_quadratic=False,
        source="hf:HuggingFaceTB/SmolLM-360M",
    )
