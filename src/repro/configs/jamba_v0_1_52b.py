"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with MoE 16e
top-2 [arXiv:2403.19887; hf].  Attention (GQA kv=8) at layer i%8==3; MoE
FFN on odd layers (period-2, as the Jamba paper's e=2)."""
from .base import ArchConfig, MambaConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=65536, head_dim=128,
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
        attn_every=8, attn_offset=3,
        moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=14336),
        moe_every=2,
        sub_quadratic=True,     # 28/32 layers are Mamba; attn is 1:7
        source="arXiv:2403.19887",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2, chunk=16),
        attn_every=8, attn_offset=3,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_expert=128,
                      capacity_factor=4.0),
        moe_every=2,
        sub_quadratic=True,
        source="arXiv:2403.19887",
    )
