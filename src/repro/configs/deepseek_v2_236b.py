"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed experts
top-6 [arXiv:2405.04434; hf].  First layer is dense FFN (d_ff 12288, the
HF config's intermediate_size); routed experts use d_expert=1536 (the
assignment's d_ff column = moe_intermediate_size)."""
from .base import ArchConfig, MLAConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=1536, vocab=102400, head_dim=192,  # 128 nope + 64 rope
        moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_expert=1536),
        n_dense_layers=1, dense_d_ff=12288,
        mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64,
                      nope_dim=128, v_dim=128),
        sub_quadratic=False,    # MLA is full quadratic attention
        source="arXiv:2405.04434",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=256, head_dim=24,  # 16 nope + 8 rope
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=32,
                      capacity_factor=4.0),
        n_dense_layers=1, dense_d_ff=128,
        mla=MLAConfig(kv_lora=16, q_lora=24, rope_dim=8,
                      nope_dim=16, v_dim=16),
        sub_quadratic=False,
        source="arXiv:2405.04434",
    )
