"""deepseek-v3-671b — MLA, 1 shared + 256 routed experts top-8, MTP
[arXiv:2412.19437; hf].  First 3 layers dense (d_ff 18432); MTP depth-1
head; bf16 AdamW moments as in the V3 paper's low-precision recipe."""
from .base import ArchConfig, MLAConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=2048, vocab=129280, head_dim=192,  # 128 nope + 64 rope
        moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, d_expert=2048),
        n_dense_layers=3, dense_d_ff=18432,
        mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64,
                      nope_dim=128, v_dim=128),
        mtp=True,
        opt_moment_dtype="bf16",
        sub_quadratic=False,
        source="arXiv:2412.19437",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-smoke", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=256, head_dim=24,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_expert=32,
                      capacity_factor=4.0),
        n_dense_layers=1, dense_d_ff=128,
        mla=MLAConfig(kv_lora=16, q_lora=24, rope_dim=8,
                      nope_dim=16, v_dim=16),
        mtp=True,
        opt_moment_dtype="bf16",
        sub_quadratic=False,
        source="arXiv:2412.19437",
    )
