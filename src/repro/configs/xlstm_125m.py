"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
d_ff=0: the projections live inside the xLSTM blocks (mLSTM pre-up-projects
2x; the sLSTM block carries a gated 8/3x FFN).  sLSTM at i%8==3 (the
paper's [7:1] ratio); the sLSTM recurrence is sequence-sequential, so its
``seq`` dim is marked non-shardable for the parallelizer."""
from .base import ArchConfig, XLSTMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, head_dim=192,
        xlstm=XLSTMConfig(slstm_every=8, slstm_offset=3,
                          proj_factor_mlstm=2, d_ff_slstm=2048, chunk=256),
        sub_quadratic=True,
        source="arXiv:2405.04517",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=256, head_dim=16,
        xlstm=XLSTMConfig(slstm_every=4, slstm_offset=1,
                          proj_factor_mlstm=2, d_ff_slstm=128, chunk=16),
        sub_quadratic=True,
        source="arXiv:2405.04517",
    )
