"""musicgen-large — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284; hf].  The EnCodec frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(batch, seq, d_model); the 4-codebook interleaving is collapsed to a
single vocab=2048 head (stub noted in DESIGN.md)."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=2048, head_dim=64,
        frontend="audio_frames",
        norm="ln",
        sub_quadratic=False,    # full attention → long_500k skipped
        source="arXiv:2306.05284",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=64, head_dim=16,
        frontend="audio_frames", norm="ln",
        sub_quadratic=False,
        source="arXiv:2306.05284",
    )
