"""llama-3.2-vision-11b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  Every 5th layer
cross-attends to image patch embeddings; the vision tower is a STUB per
the assignment (``input_specs()`` provides precomputed patch embeddings of
shape (batch, 1600, d_model))."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=128256, head_dim=128,
        cross_attn_every=5, n_img_tokens=1600,
        frontend="vision",
        sub_quadratic=False,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="llama-vision-smoke", family="vlm",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16,
        cross_attn_every=5, n_img_tokens=8,
        frontend="vision",
        sub_quadratic=False,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )
