"""Architecture configuration schema.

One ``ArchConfig`` fully describes an assigned architecture: the block
pattern (dense attention / SWA / cross-attn / Mamba / sLSTM / mLSTM), the
FFN flavour (dense or MoE with shared experts), MLA compression, and the
modality frontend (tokens / stubbed audio frames / stubbed vision patches).

``layer_groups`` compresses the per-layer pattern into homogeneous repeated
segments so models can ``lax.scan`` over stacked parameters — essential to
keep dry-run HLO small for the 60-layer configs.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Sequence


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0          # expert intermediate dim
    capacity_factor: float = 1.25
    router_dtype: str = "f32"


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8        # sLSTM at layer index % every == offset
    slstm_offset: int = 3
    proj_factor_mlstm: int = 2
    d_ff_slstm: int = 0         # gated FFN inside the sLSTM block
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                   # dense FFN dim, or MoE expert dim for moe
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    norm: str = "rms"           # rms|ln
    rope_pct: float = 1.0       # partial rotary (stablelm)
    attn_window: Optional[int] = None   # sliding-window attention
    cross_attn_every: Optional[int] = None  # vlm: cross-attn layer stride
    n_img_tokens: int = 1024    # vlm stub: image patch embeddings
    moe: Optional[MoEConfig] = None
    moe_every: int = 1          # MoE FFN at layer index % moe_every == 1
    n_dense_layers: int = 0     # leading dense-FFN layers (deepseek)
    dense_d_ff: int = 0         # FFN dim of those dense layers
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    attn_every: int = 0         # hybrid: attention at index % every == offset
    attn_offset: int = 3
    xlstm: Optional[XLSTMConfig] = None
    frontend: str = "tokens"    # tokens|audio_frames|vision
    mtp: bool = False           # multi-token-prediction head (deepseek-v3)
    tie_embeddings: bool = False
    dtype: str = "bf16"
    opt_moment_dtype: str = "f32"  # bf16 for deepseek-v3 (as its paper)
    sub_quadratic: bool = False    # eligible for long_500k
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # -- per-layer block descriptors -----------------------------------------
    def block_kind(self, i: int) -> str:
        """Sequence-mixer kind of layer ``i``."""
        if self.xlstm is not None:
            x = self.xlstm
            return ("slstm" if i % x.slstm_every == x.slstm_offset
                    else "mlstm")
        if self.mamba is not None and self.attn_every:
            return ("attn" if i % self.attn_every == self.attn_offset
                    else "mamba")
        if self.cross_attn_every and i % self.cross_attn_every == (
                self.cross_attn_every - 1):
            return "xattn"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """Channel-mixer kind of layer ``i``."""
        if self.xlstm is not None:
            return "none"       # projections live inside the xLSTM blocks
        if self.moe is None:
            return "dense"
        if i < self.n_dense_layers:
            return "dense"
        if self.moe_every > 1 and i % self.moe_every != 1:
            return "dense"
        return "moe"

    def layer_kinds(self) -> list[tuple[str, str]]:
        return [(self.block_kind(i), self.ffn_kind(i))
                for i in range(self.n_layers)]

    def layer_groups(self) -> list[tuple[tuple[tuple[str, str], ...], int]]:
        """Compress layers into (pattern, repeats) groups for scanning.

        Finds the smallest period p such that the kind sequence is
        (prefix, p-periodic body); emits the prefix layer-by-layer and the
        body as one scanned group of super-blocks."""
        kinds = self.layer_kinds()
        n = len(kinds)
        for period in range(1, n + 1):
            for start in range(0, min(period, n - 1) + 1):
                body = kinds[start:]
                if len(body) % period != 0:
                    continue
                pattern = tuple(body[:period])
                if all(tuple(body[j * period:(j + 1) * period]) == pattern
                       for j in range(len(body) // period)):
                    groups = [((k,), 1) for k in kinds[:start]]
                    groups.append((pattern, len(body) // period))
                    return groups
        return [(tuple(kinds), 1)]


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                   # train|prefill|decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (assignment rule)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention architecture; "
                       "long_500k requires sub-quadratic attention "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""
