"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from .base import SHAPES, ArchConfig, ShapeSpec, shape_applicable
from ..core.generate import SYNTH_CONFIGS, get_synth, list_synths

_ARCH_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "xlstm-125m": "xlstm_125m",
    "musicgen-large": "musicgen_large",
    "smollm-135m": "smollm_135m",
    "stablelm-3b": "stablelm_3b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "smollm-360m": "smollm_360m",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v3-671b": "deepseek_v3_671b",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)
    return mod.smoke_config() if smoke else mod.config()


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "shape_applicable",
           "get_config", "list_archs",
           # Synthetic scale-stress graphs ride the same registry so
           # benches and tests resolve them next to the real archs.
           "SYNTH_CONFIGS", "get_synth", "list_synths"]
