"""Intensity- and connection-aware dataflow parallelization — paper
Section 6.5 / Algorithm 4, re-targeted from FPGA loop-unroll factors to
TPU mesh-axis sharding factors.

Steps (paper numbering):

1. **Intensity & connection analysis** — per shared buffer, build the
   permutation map (which loop level of the producer aligns with which loop
   level of the consumer) and the scaling map (access-stride ratio).
2. **Node sorting** — descending by connection count, intensity as the
   tie-breaker.
3. **Parallel factor generation** — per-node max parallel factor
   proportional to intensity under the global budget (the chip count).
4. **Node parallelization** — constrained DSE per node: proposals are
   mesh-axis→loop-dim assignments (the TPU quantization of unroll
   factors); a proposal is invalid when (a) any factor is mutually
   indivisible with the constraint projected from an already-parallelized
   connected node through the scaling+permutation maps, or (b) the node's
   total parallelism exceeds its intensity-derived parallel factor.  Valid
   proposals are scored with the roofline QoR estimator; the best one is
   applied.

Ablation switches (``ia``, ``ca``) reproduce the paper's IA-only / CA-only
/ naive arms (Fig. 11).

Beyond the paper's greedy step 4, the DSE is a **beam search over joint
multi-node proposals** (``beam_width``, ``joint_radius``):

* A *beam state* is one whole-schedule assignment, held as an
  ``IncrementalEstimator`` snapshot; switching between sibling states
  re-applies only the differing nodes.
* The beam is seeded with the converged greedy state plus the family of
  *uniform* axis→dim assignments (one coordinated layout applied to every
  node at once) — the joint moves that rescue schedules locked into an
  all-unsharded basin, where every single-node move pays two reshard
  boundaries that exceed its own gain.  This subsumes the former
  ``seed_uniform`` escape hatch.
* Each round expands the best states through *joint moves*: pick an
  origin node (reshard-paying endpoints first, then by roofline latency),
  take its top runner-up proposals from the memoized enumeration, apply
  one, then greedily re-DSE every node within ``joint_radius`` hops of
  the origin in the affected-set graph.  The resulting whole-schedule
  states compete for the ``beam_width`` slots on total QoR.
* The winner gets full coordinate-descent refinement sweeps, and the
  greedy result is kept when nothing beats it — beam QoR is ≥ greedy QoR
  on every schedule *by construction* (``tests/test_beam.py``).

Compile-time engineering (the DSE is the whole ``optimize()`` hot path;
``benchmarks/bench_compile_time.py`` tracks it PR-over-PR, and its
``--compare`` mode fails on >2× regressions):

* Proposals are scored through the **read-only**
  :meth:`~.incremental.IncrementalEstimator.score` — O(deg) per proposal
  with bit-identical totals to the batch estimator, and no undo-log
  traffic on the scan path.
* ``_proposals()`` enumeration (and each proposal's unroll factors and
  canonical-preference penalty) is memoized per node — the pf cap is fixed
  for the whole ``parallelize()`` call, so every later scan reuses the
  sweep-1 enumeration.
* Constraint projection only scans the connections *incident* to the node
  under DSE (hoisted per-node incidence lists) rather than every
  connection in the schedule.
* Coordinate-descent sweeps keep a **dirty set**: a node is only re-DSE'd
  when its DSE inputs may have changed.  Scoring node *n*'s proposals
  varies the latencies of *n* and its direct consumers only, and reads
  the committed state of *n*'s neighbours (constraints, neighbour-axes
  tie-break) and of the *co-producers* feeding a shared consumer (their
  reshard contribution shifts the consumer's ``max()`` roofline term).
  So a change to node *x* dirties ``neighbours(x) ∪ co_producers(x)``,
  and a clean node provably re-selects the same proposal (its search is
  independent of its own current assignment).
* Sweeps are **graph-colored**: the frontier is level-scheduled over the
  affected-set graph so that every node's earlier-ordered conflicting
  neighbours land in earlier levels.  Nodes within one level have
  non-overlapping DSE neighbourhoods, are scored against the same frozen
  committed state (via the pure ``score()`` path — thread-safe, so
  ``sweep_workers`` can fan a level out over a thread pool), and commit
  together.  In exact arithmetic this chooses the same plan as the serial
  in-order sweep: a same-level commit only shifts a later node's
  re-summed totals by a constant, which cannot reorder its proposals.
  The float re-summation makes that a near- rather than bit-level
  guarantee (a sub-ulp tie could in principle round differently across
  the shift); ``tests/test_beam.py`` asserts plan equality empirically on
  every config.
"""
from __future__ import annotations

import itertools
import math
import re
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Optional

from .estimator import EstimateContext, MeshSpec, ScheduleCost, estimate
from .faults import corrupt_value, fault_point
from .incremental import IncrementalEstimator, Snapshot
from .ir import Node, Schedule
from .rewrite import RegionSpec, dse_regions

# Mesh-axis affinity by loop-dim name: which axes a dim may take, in
# preference order.  Batch-like dims soak up the pure-DP axes; everything
# else competes for the model axis (and may spill onto data/pod when the
# batch is too small to fill them, e.g. long_500k decode with batch=1).
_DATA_AXES = ("pod", "data")
_DIM_AXIS_PREF: dict[str, tuple[str, ...]] = {
    # batch never takes the model axis: mixing DP and TP on one dim breeds
    # the resharding chains GSPMD resolves by full rematerialization.
    # And nothing except batch takes the pod axis: TP/EP/SP across the DCN
    # is never right at this scale.
    "batch": ("pod", "data"),
    "seq": ("model", "data"),
    "kv_seq": ("model", "data"),
}
_DEFAULT_PREF = ("model", "data")


def axis_pref(dim: str) -> tuple[str, ...]:
    for key, pref in _DIM_AXIS_PREF.items():
        if dim == key or dim.startswith(key + "_"):
            return pref
    return _DEFAULT_PREF


# --------------------------------------------------------------------------
# Step 1 — connections
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Connection:
    """A producer→consumer link through a shared buffer (paper Table 4)."""

    src: str
    dst: str
    buffer: str
    # Per buffer axis: (src loop dim, src stride, dst loop dim, dst stride).
    axes: tuple[tuple[Optional[str], Fraction, Optional[str], Fraction], ...]

    def project(self, factors: dict[str, int], from_src: bool
                ) -> dict[str, Fraction]:
        """Project ``factors`` of one endpoint onto the other endpoint's
        loop dims: multiply by the scaling map, permute by the permutation
        map (Alg. 4 lines 3-8)."""
        out: dict[str, Fraction] = {}
        for sdim, sstride, ddim, dstride in self.axes:
            if from_src:
                odim, ostride, mdim, mstride = sdim, sstride, ddim, dstride
            else:
                odim, ostride, mdim, mstride = ddim, dstride, sdim, sstride
            if odim is None or mdim is None:
                continue
            f = factors.get(odim)
            if f is None:
                continue
            out[mdim] = Fraction(f) * ostride / mstride
        return out


def analyze_connections(sched: Schedule) -> list[Connection]:
    topo = sched.topology()
    conns: list[Connection] = []
    for src, dst, bname in topo.edges:
        p, c = sched.node(src), sched.node(dst)
        pam = topo.access_for(p, bname)
        cam = topo.access_for(c, bname)
        if pam is None or cam is None:
            continue
        axes = tuple(
            (pam.entries[i][0], pam.entries[i][1],
             cam.entries[i][0], cam.entries[i][1])
            for i in range(len(pam.entries)))
        conns.append(Connection(src, dst, bname, axes))
    return conns


def connection_count(sched: Schedule,
                     conns: list[Connection] | None = None
                     ) -> dict[str, int]:
    if conns is None:
        conns = analyze_connections(sched)
    count: dict[str, int] = {n.name: 0 for n in sched.nodes}
    for c in conns:
        count[c.src] += 1
        count[c.dst] += 1
    return count


# --------------------------------------------------------------------------
# Step 3 — intensity-proportional parallel factors
# --------------------------------------------------------------------------

def parallel_factors(sched: Schedule, max_pf: int, ia: bool
                     ) -> dict[str, int]:
    """pf(node) ∝ intensity, rounded up to a power of two, capped at
    ``max_pf`` (paper Table 5).  Without IA every node gets ``max_pf``.

    The power-of-two rounding is integer bit-length arithmetic: the
    smallest power of two ≥ x equals the smallest power of two ≥ ⌈x⌉, and
    ``1 << (need - 1).bit_length()`` computes the latter exactly — unlike
    ``2 ** ceil(log2(x))``, whose float log could round an exact power of
    two up a full octave."""
    if not ia:
        return {n.name: max_pf for n in sched.nodes}
    peak = max((n.intensity() for n in sched.nodes), default=1) or 1
    out: dict[str, int] = {}
    for n in sched.nodes:
        share = n.intensity() / peak
        need = max(1, math.ceil(share * max_pf))
        out[n.name] = max(1, min(max_pf, 1 << (need - 1).bit_length()))
    return out


# --------------------------------------------------------------------------
# Step 4 — constrained per-node DSE
# --------------------------------------------------------------------------

def _divisible(constraint: Fraction, factor: int) -> bool:
    """Paper Alg. 4 line 15: mutually indivisible → invalid."""
    if constraint <= 0:
        return True
    a = constraint / factor
    b = Fraction(factor) / constraint
    return a.denominator == 1 or b.denominator == 1


def _shardable_dims(node: Node) -> dict[str, int]:
    # Memoized on the node: the body (and so loop_dims / no_shard) is
    # fixed once the node exists, and the DSE asks for this on every
    # proposal — recomputing it was ~15% of a 5k-node compile.  Callers
    # treat the returned dict as read-only.
    cached = node.__dict__.get("_shardable_memo")
    if cached is not None:
        return cached
    dims = node.loop_dims()
    blocked: set[str] = set()
    for o in node.body:
        blocked.update(o.attrs.get("no_shard", ()))
    cached = {d: s for d, s in dims.items() if s > 1 and d not in blocked}
    node.__dict__["_shardable_memo"] = cached
    return cached


def _proposals(node: Node, mesh: MeshSpec, pf_cap: int
               ) -> list[dict[str, tuple[str, ...]]]:
    """Enumerate mesh-axis→dim assignments.  Each axis is assigned to at
    most one loop dim (or left unused); a dim may take several axes.  The
    factor of a dim is the product of its axes' sizes; dim size must be
    divisible by its factor; total parallelism must not exceed ``pf_cap``
    (Alg. 4 line 17)."""
    dims = _shardable_dims(node)
    axes = list(mesh.axes)
    choices_per_axis: list[list[Optional[str]]] = []
    for aname, asize in axes:
        opts: list[Optional[str]] = [None]
        for d, size in dims.items():
            if aname in axis_pref(d):
                opts.append(d)
        choices_per_axis.append(opts)
    out: list[dict[str, tuple[str, ...]]] = []
    for combo in itertools.product(*choices_per_axis):
        assign: dict[str, list[str]] = {}
        for (aname, asize), d in zip(axes, combo):
            if d is not None:
                assign.setdefault(d, []).append(aname)
        total = 1
        ok = True
        for d, alist in assign.items():
            f = 1
            for a in alist:
                f *= mesh.size(a)
            if dims[d] % f != 0:
                ok = False
                break
            # TPU adaptation of the paper's parallel-factor budget: chips
            # are not a consumable resource (unlike DSPs) — pure data
            # parallelism over the batch dim is free, so only
            # communication-bearing dims count against the IA budget.
            if not (d == "batch" or d.startswith("batch_")):
                total *= f
        if not ok or total > pf_cap:
            continue
        out.append({d: tuple(a) for d, a in assign.items()})
    return out


def _apply(node: Node, proposal: dict[str, tuple[str, ...]],
           mesh: MeshSpec) -> None:
    node.axis_map = dict(proposal)
    node.unroll = {
        d: math.prod(mesh.size(a) for a in axes)
        for d, axes in proposal.items()}


def canonical_node_key(index: int, name: str) -> str:
    """Process-independent node identity for cached assignments.

    Raw node names carry a process-global counter (``task_26`` in one
    build is ``task_59`` in the next), so a snapshot keyed by raw names
    never matches a freshly constructed schedule.  The canonical key
    strips the counter and pins the node's position in schedule order —
    stable across processes for the same (config, shape) pipeline, and a
    harmless miss (not a mis-seed) when structures diverge."""
    return f"{re.sub(r'_[0-9]+$', '', name)}@{index}"


def canonical_snapshot(sched: Schedule) -> Snapshot:
    """The schedule's current assignment keyed by
    :func:`canonical_node_key` — the form the plan cache persists and
    :func:`parallelize` accepts as ``warm_start``/``warm_entries``."""
    return {canonical_node_key(i, n.name): (dict(n.axis_map),
                                            dict(n.unroll))
            for i, n in enumerate(sched.nodes)}


def _remap_warm(frag: Snapshot, canon: dict[str, str],
                live: set[str]) -> Snapshot:
    """Translate a cached fragment onto live node names: raw names that
    still exist pass through, canonical keys map via ``canon``, anything
    else is dropped (a miss, covered by the normal per-node DSE)."""
    out: Snapshot = {}
    for k, v in frag.items():
        if k in live:
            out[k] = v
        elif k in canon:
            out[canon[k]] = v
    return out


def _sanitize_warm(node: Node, axis_map: dict[str, tuple[str, ...]],
                   pf_cap: int, mesh: MeshSpec
                   ) -> dict[str, tuple[str, ...]]:
    """Quantize a cached assignment fragment onto ``node`` under the
    *current* mesh and IA budget: drop dims the node cannot shard, axes
    the mesh does not have (or that another dim of this node already
    took), non-divisible factors, and over-budget entries.  The warm-start
    analogue of :func:`_uniform_proposal` — a seed from a different mesh
    or shape bucket degrades to its legal subset instead of poisoning the
    search with an illegal assignment."""
    dims = _shardable_dims(node)
    names = set(mesh.names)
    prop: dict[str, tuple[str, ...]] = {}
    total = 1
    used: set[str] = set()
    for d, axes in axis_map.items():
        if d not in dims:
            continue
        keep = tuple(a for a in axes if a in names and a not in used)
        if len(keep) != len(tuple(axes)):
            # A partially-legal entry changes the factor; re-check below.
            axes = keep
        if not axes:
            continue
        f = math.prod(mesh.size(a) for a in axes)
        if dims[d] % f:
            continue
        if not (d == "batch" or d.startswith("batch_")):
            if total * f > pf_cap:
                continue
            total *= f
        used.update(axes)
        prop[d] = tuple(axes)
    return prop


# --------------------------------------------------------------------------
# Uniform-assignment family (beam seeds + degradation-ladder bottom rung)
# --------------------------------------------------------------------------

def _uniform_proposal(node: Node, assign: dict[str, tuple[str, ...]],
                      pf_cap: int, mesh: MeshSpec
                      ) -> dict[str, tuple[str, ...]]:
    """Quantize one uniform axis→dim layout onto ``node``: keep only the
    dims the node can shard, drop non-divisible factors, and respect the
    node's IA parallel-factor budget (batch-like dims are budget-free,
    matching ``_proposals``)."""
    dims = _shardable_dims(node)
    prop: dict[str, tuple[str, ...]] = {}
    total = 1
    for d, axes in assign.items():
        if d not in dims:
            continue
        f = math.prod(mesh.size(a) for a in axes)
        if dims[d] % f:
            continue
        if not (d == "batch" or d.startswith("batch_")):
            if total * f > pf_cap:
                continue
            total *= f
        prop[d] = axes
    return prop


#: Above this many schedule nodes the uniform family enumerates only the
#: most-covered dims (below it, every dim — bit-identical to the
#: historical behaviour on every real config, all ≤ 43 nodes).
_UNIFORM_SCALE_N = 256

#: Dim cap for the scaled regime.  The family is quadratic in the dim
#: count, and synthetic 5k-node graphs carry a dozen distinct hidden-dim
#: names whose members score near-identically: a dim shardable in 2% of
#: nodes cannot move a 5k-node total.  Coverage-ranked, ties broken by
#: name for determinism.
_UNIFORM_DIM_CAP = 6


def _uniform_assignments(sched: Schedule) -> list[dict[str, tuple[str, ...]]]:
    """The uniform-assignment family: every (data-axis dim, model-axis
    dim) pairing over the schedule's shardable dims — one coordinated
    layout applied to every node at once.  Past ``_UNIFORM_SCALE_N``
    nodes, only the ``_UNIFORM_DIM_CAP`` dims shardable in the most
    nodes enumerate (scale-aware bound; see the constants above)."""
    cover: dict[str, int] = {}
    for n in sched.nodes:
        for d in _shardable_dims(n):
            cover[d] = cover.get(d, 0) + 1
    all_dims = sorted(cover)
    if (len(sched.nodes) > _UNIFORM_SCALE_N
            and len(all_dims) > _UNIFORM_DIM_CAP):
        all_dims = sorted(sorted(
            cover, key=lambda d: (-cover[d], d))[:_UNIFORM_DIM_CAP])
    cands = []
    for d1 in all_dims + [None]:
        for d2 in all_dims + [None]:
            a: dict[str, tuple[str, ...]] = {}
            if d1 and "data" in axis_pref(d1):
                a[d1] = ("data",)
            if d2 and "model" in axis_pref(d2):
                a[d2] = (a.get(d2, ()) + ("model",))
            if a:
                cands.append(a)
    return cands


def best_uniform(sched: Schedule, mesh: MeshSpec, *,
                 max_parallel_factor: int | None = None,
                 ia: bool = True, training: bool = True,
                 regions: "list[RegionSpec] | None" = None
                 ) -> tuple[dict[str, tuple[str, ...]], ScheduleCost]:
    """Apply the best member of the uniform-assignment family (including
    the all-replicated empty assignment) to ``sched`` in place and return
    ``(assignment, cost)``.

    This is the degradation ladder's bottom DSE rung and the QoR floor
    reference: it deliberately bypasses the incremental engine and every
    fault-injection site — plain proposal application plus the batch
    :func:`~repro.core.estimator.estimate` — so it stays serviceable when
    the machinery above it is the thing that failed.

    With ``regions`` (a :func:`~repro.core.rewrite.dse_regions`
    partition), the floor is **region-aware**: after the whole-schedule
    scan, one coordinate-descent pass re-tries the strongest uniform
    layouts *per region* (complement held fixed) and keeps strict
    improvements.  The result can only be ≤ the whole-schedule floor, so
    a single degraded region can no longer drag the composed plan below
    the old floor.  The returned ``assignment`` is still the best
    whole-schedule family member (the in-place state may be a per-region
    mix of family members)."""
    max_pf = max_parallel_factor or mesh.chips
    pf = parallel_factors(sched, max_pf, ia)
    uniforms = [{}] + _uniform_assignments(sched)
    # One topology walk for the whole scan: every family member (and the
    # per-region retries below) only rewrites axis_map/unroll, so the
    # edge/consumer/weight structure behind EstimateContext never moves.
    # Rebuilding it per estimate() call was O(members × edges) — the
    # dominant cost of the floor at 1k+ nodes.
    ctx = EstimateContext(sched)
    best: tuple[ScheduleCost, dict, dict] | None = None
    scored: list[tuple[float, int]] = []
    for ui, assign in enumerate(uniforms):
        for n in sched.nodes:
            _apply(n, _uniform_proposal(n, assign, pf[n.name], mesh), mesh)
        cost = estimate(sched, mesh, training=training, ctx=ctx)
        scored.append((cost.total_s, ui))
        if best is None or cost.total_s < best[0].total_s:
            best = (cost, assign,
                    {n.name: (dict(n.axis_map), dict(n.unroll))
                     for n in sched.nodes})
    cost, assign, state = best
    for n in sched.nodes:
        n.axis_map, n.unroll = state[n.name]

    if regions and len(regions) > 1:
        # Per-region refinement over the few strongest family members
        # (plus the replicated layout) — bounded at regions × 4 batch
        # estimates so the floor stays serviceable as a fallback.
        scored.sort()
        retry = [uniforms[ui] for _s, ui in scored[:3]]
        if uniforms[0] not in retry:
            retry.append(uniforms[0])
        # Each retry costs a whole-schedule estimate, so at scale the
        # regions × retries product must be budgeted or the floor rung
        # takes minutes at 10k nodes.  Refine the largest regions first
        # (most cost mass); every real config's partition fits inside
        # the budget, so this is a no-op below ~64 regions.
        budget = 256
        if len(regions) * len(retry) > budget:
            regions = sorted(regions, key=lambda s: (-len(s.nodes),
                                                     s.index))
            regions = sorted(regions[:max(1, budget // len(retry))],
                             key=lambda s: s.index)
        node_by_name = {n.name: n for n in sched.nodes}
        for spec in regions:
            rnodes = [node_by_name[nm] for nm in spec.nodes
                      if nm in node_by_name]
            if not rnodes:
                continue
            keep = {n.name: (dict(n.axis_map), dict(n.unroll))
                    for n in rnodes}
            for rassign in retry:
                for n in rnodes:
                    _apply(n, _uniform_proposal(n, rassign, pf[n.name],
                                                mesh), mesh)
                c = estimate(sched, mesh, training=training, ctx=ctx)
                if c.total_s < cost.total_s:
                    cost = c
                    keep = {n.name: (dict(n.axis_map), dict(n.unroll))
                            for n in rnodes}
            for n in rnodes:
                n.axis_map, n.unroll = keep[n.name]
    return assign, cost


# --------------------------------------------------------------------------
# Region summaries (the inner→outer interface of the hierarchical DSE)
# --------------------------------------------------------------------------

def _tuplify(x):
    """Recursively convert lists to tuples (JSON round-trip helper)."""
    if isinstance(x, list):
        return tuple(_tuplify(v) for v in x)
    return x


def _listify(x):
    """Recursively convert tuples to lists (inverse of :func:`_tuplify`)."""
    if isinstance(x, tuple):
        return [_listify(v) for v in x]
    return x


def _frag_sig(frag: Snapshot) -> tuple:
    """Canonical signature of an assignment fragment (``axis_map`` only —
    ``unroll`` is derived from it under a fixed mesh)."""
    return tuple(sorted(
        (nm, tuple(sorted((d, tuple(axes)) for d, axes in am.items())))
        for nm, (am, _ur) in frag.items()))


def _region_boundary_sig(spec: RegionSpec,
                         conn_by_edge: dict, buffers: dict) -> tuple:
    """Renaming-stable signature of a region's boundary connections:
    per crossing edge, its direction relative to the region, the shared
    buffer's shape/bytes, and the connection's (dim, stride) axis pairs.
    No node or buffer *names* enter the signature, so renaming every node
    in the schedule leaves it bit-identical (``tests/test_hierarchical``
    pins this)."""
    inside = set(spec.nodes)
    sig = []
    for s, d, bname in spec.boundary:
        direction = "in" if d in inside else "out"
        buf = buffers[bname]
        c = conn_by_edge.get((s, d, bname))
        axes = () if c is None else tuple(
            (sd or "", str(ss), dd or "", str(ds))
            for sd, ss, dd, ds in c.axes)
        sig.append((direction, tuple(buf.shape), buf.bytes, axes))
    return tuple(sorted(sig))


@dataclass
class RegionEntry:
    """One candidate assignment for a region, as scored by its inner
    search with the complement of the schedule held at the converged
    greedy state."""

    #: region-restricted assignment fragment (keys = region node names).
    assignment: Snapshot
    #: whole-schedule QoR with this fragment applied (complement greedy).
    total_s: float
    #: incremental QoR delta vs. the all-greedy schedule (≤ 0 is a win).
    delta_s: float
    #: whole-schedule HBM bytes/device with this fragment applied.
    hbm_bytes: int
    #: region-scoped HBM footprint of this fragment.
    region_hbm_bytes: int
    #: "greedy" | "uniform" | "search".
    origin: str

    def key(self) -> tuple[float, int]:
        return (self.total_s, self.hbm_bytes)

    def to_dict(self) -> dict:
        return {
            "assignment": {
                nm: {"axis_map": {d: list(axes)
                                  for d, axes in am.items()},
                     "unroll": dict(ur)}
                for nm, (am, ur) in self.assignment.items()},
            "total_s": self.total_s, "delta_s": self.delta_s,
            "hbm_bytes": self.hbm_bytes,
            "region_hbm_bytes": self.region_hbm_bytes,
            "origin": self.origin}

    @classmethod
    def from_dict(cls, d: dict) -> "RegionEntry":
        return cls(
            assignment={
                nm: ({dim: tuple(axes)
                      for dim, axes in st["axis_map"].items()},
                     {dim: int(f) for dim, f in st["unroll"].items()})
                for nm, st in d["assignment"].items()},
            total_s=d["total_s"], delta_s=d["delta_s"],
            hbm_bytes=d["hbm_bytes"],
            region_hbm_bytes=d["region_hbm_bytes"], origin=d["origin"])


@dataclass
class RegionSummary:
    """What one region's inner search hands the outer composition level:
    its top-k entries (best first, the converged-greedy entry always
    present), the renaming-stable boundary-connection signature, and the
    region's resource footprint.  JSON round-trips exactly through
    :meth:`to_dict` / :meth:`from_dict`."""

    index: int
    nodes: tuple[str, ...]
    entries: list[RegionEntry]
    boundary_sig: tuple
    #: region-scoped HBM footprint at the greedy entry.
    hbm_bytes: int
    #: wall time of this region's inner search.
    inner_s: float = 0.0
    #: non-empty when the inner search failed and the region was pinned
    #: to its greedy/uniform entries (the ``dse.inner`` ladder rung).
    degraded: str = ""

    def greedy_index(self) -> int:
        return next(i for i, e in enumerate(self.entries)
                    if e.origin == "greedy")

    def to_dict(self) -> dict:
        return {"index": self.index, "nodes": list(self.nodes),
                "entries": [e.to_dict() for e in self.entries],
                "boundary_sig": _listify(self.boundary_sig),
                "hbm_bytes": self.hbm_bytes, "inner_s": self.inner_s,
                "degraded": self.degraded}

    @classmethod
    def from_dict(cls, d: dict) -> "RegionSummary":
        return cls(index=d["index"], nodes=tuple(d["nodes"]),
                   entries=[RegionEntry.from_dict(e)
                            for e in d["entries"]],
                   boundary_sig=_tuplify(d["boundary_sig"]),
                   hbm_bytes=d["hbm_bytes"], inner_s=d["inner_s"],
                   degraded=d["degraded"])


@dataclass
class ParallelizeResult:
    order: list[str] = field(default_factory=list)
    pf: dict[str, int] = field(default_factory=dict)
    evaluated: int = 0
    rejected_constraint: int = 0
    rejected_budget: int = 0
    log: list[str] = field(default_factory=list)
    #: final schedule cost from the incremental engine (bit-identical to
    #: ``estimate(sched, mesh, training)`` on the returned assignment).
    cost: ScheduleCost | None = None
    #: ``total_s`` of the converged greedy coordinate descent, before the
    #: beam phase — the invariant ``cost.total_s <= greedy_total_s`` holds
    #: by construction whenever the beam ran.
    greedy_total_s: float = 0.0
    #: whole-schedule states examined by the beam (seeds + joint-move
    #: successors, before dedup/truncation to the beam width).
    beam_states: int = 0
    #: joint (origin + neighbourhood re-DSE) moves expanded.
    joint_moves: int = 0
    #: degradations taken inside the DSE (e.g. a beam-phase failure that
    #: fell back to the converged greedy snapshot); surfaced into
    #: ``OptimizeReport.degradations`` by ``optimize()``.
    degraded: list[str] = field(default_factory=list)
    #: True when the wall-clock ``deadline`` expired and the search
    #: returned its best-so-far snapshot instead of running to fixpoint.
    budget_expired: bool = False
    #: which DSE actually ran: "flat" (the whole-schedule beam, also the
    #: single-region / ablation path), "hierarchical", or "warm" (seeded
    #: from a cached assignment, beam skipped).
    dse_mode: str = "flat"
    #: True when a ``warm_start`` snapshot seeded the search.
    warm: bool = False
    #: nodes of the schedule covered by the (sanitized) warm seed.
    warm_covered: int = 0
    #: number of regions the hierarchical DSE partitioned the schedule
    #: into (1 when the flat beam ran).
    regions: int = 1
    #: per-region inner-search summaries (hierarchical mode only).
    region_summaries: list[RegionSummary] = field(default_factory=list)
    #: wall time of the inner (per-region) level of the hierarchical DSE.
    inner_dse_s: float = 0.0
    #: wall time of the outer (inter-region composition) level.
    outer_dse_s: float = 0.0


def parallelize(sched: Schedule, mesh: MeshSpec, *,
                max_parallel_factor: int | None = None,
                ia: bool = True, ca: bool = True,
                training: bool = True,
                beam_width: int = 8,
                joint_radius: int = 1,
                beam_rounds: int = 3,
                sweep_workers: int | None = None,
                colored_sweeps: bool = True,
                seed_uniform: bool | None = None,
                deadline: float | None = None,
                dse_mode: str = "hierarchical",
                warm_start: Snapshot | None = None,
                warm_entries: list[Snapshot] | None = None
                ) -> ParallelizeResult:
    """Paper Section 6.5 steps 1-4 over a Structural schedule (in place).

    Steps 1-3 follow the paper; step 4 runs the paper's greedy
    most-connected-first pass, converges it by coordinate descent, then —
    when connection-aware scoring is on — improves it with a beam search
    over joint multi-node proposals (see the module docstring for the
    full design).

    Args:
        sched: Structural schedule; node ``unroll`` / ``axis_map`` are
            assigned in place.
        mesh: target mesh (axis names and sizes).
        max_parallel_factor: global parallel-factor budget (defaults to
            the chip count).
        ia: intensity-aware parallel-factor capping (paper Fig. 11 arm).
        ca: connection-aware scoring and constraint projection (paper
            Fig. 11 arm).  The beam phase requires ``ca``; with it off,
            the result is the paper's greedy per-node DSE.
        training: include weight-gradient sync traffic in the QoR.
        beam_width: number of whole-schedule states kept per beam round.
            ``<= 1`` disables the beam phase entirely (pure greedy
            coordinate descent, the pre-beam behaviour).
        joint_radius: how many hops of the affected-set graph are greedily
            re-optimized around a joint move's origin node.  Radius 1
            covers the producer/consumer pairs whose coordinated unroll
            choices single-node moves cannot reach.
        beam_rounds: maximum joint-move expansion rounds (the beam stops
            early as soon as a round fails to improve the best state).
        sweep_workers: when > 1, each graph-color level of a refinement
            sweep is scored on a thread pool (the scoring path is
            read-only and thread-safe).  Does not change the chosen plan.
            Under the CPython GIL the pure-Python scoring cannot actually
            run concurrently, so this is a small net *slowdown* today —
            it exists for free-threaded builds; leave ``None`` otherwise.
        colored_sweeps: level-schedule sweep frontiers over the
            affected-set graph and score each level as a batch (the
            default).  ``False`` forces strictly serial in-order sweeps —
            the reference semantics, same plan in exact arithmetic (see
            the module docstring for the float-tie caveat;
            ``tests/test_beam.py`` asserts equality on every config).
        seed_uniform: **deprecated, ignored** — the beam's seeding with
            the uniform-assignment family subsumes it (kept so existing
            call sites don't break; pass ``beam_width=0`` *and*
            ``seed_uniform=True`` to run the legacy escape hatch).
        deadline: absolute ``time.perf_counter()`` instant after which
            the search becomes *anytime*: convergence sweeps and beam
            rounds stop at the next boundary and the best-so-far
            snapshot is restored (O(1) via the incremental engine).
            The initial greedy pass always completes — a full assignment
            must exist before "best so far" means anything.  ``None``
            (the default) never interrupts.
        dse_mode: ``"hierarchical"`` (default) runs the two-level DSE —
            per-region inner beams (:func:`~repro.core.rewrite.dse_regions`
            partition) composed by an inter-region outer beam over
            :class:`RegionSummary` entries, with the ``deadline`` budget
            split adaptively between the levels.  ``"flat"`` forces the
            whole-schedule beam (the differential-testing oracle —
            ``tests/test_hierarchical.py`` asserts hierarchical QoR ≤
            flat QoR on every config).  Schedules the partitioner leaves
            whole (or the CA-off / ``beam_width<=1`` arms) always take
            the flat path, bit-identically to ``dse_mode="flat"``.
        warm_start: estimator snapshot from a previous compile of a
            *similar* config (nearest plan-cache entry).  Each covered
            node is seeded with its cached assignment — quantized onto
            the current mesh/shapes by :func:`_sanitize_warm` — instead
            of a fresh greedy scan; uncovered nodes run the normal
            per-node DSE.  The seed then converges by coordinate descent
            and the beam phase is **skipped** (replaced by a cheap
            uniform-family floor scan plus the ``warm_entries``
            alternatives), so the warm wall is a fraction of the cold
            wall.  QoR ≥ the *warm greedy* path by the monotonicity of
            ``converge`` — the cache layer above only serves warm results
            that also beat its recorded cold QoR.
        warm_entries: optional extra assignment fragments (e.g. PR 7
            ``RegionEntry`` summaries from the cached plan's regions)
            tried as whole-schedule alternatives after convergence; the
            best strict improvement wins.
    """
    if dse_mode not in ("hierarchical", "flat"):
        raise ValueError(f"unknown dse_mode {dse_mode!r}")
    if seed_uniform is not None:
        warnings.warn(
            "parallelize(seed_uniform=...) is deprecated: the beam search "
            "seeds itself with the uniform-assignment family "
            "(beam_width/joint_radius control it); see "
            "docs/ARCHITECTURE.md.", DeprecationWarning, stacklevel=2)
    res = ParallelizeResult()
    max_pf = max_parallel_factor or mesh.chips
    conns = analyze_connections(sched)
    counts = connection_count(sched, conns)
    res.pf = parallel_factors(sched, max_pf, ia)
    est = IncrementalEstimator(sched, mesh, training=training)

    # Hoisted DSE structure: per-node incident connections (in global conn
    # order), neighbourhood sets for the dirty-set sweeps, and the memoized
    # proposal enumeration (the pf cap is fixed per node for this call, so
    # the enumeration — and each proposal's unroll factors and static
    # preference penalty — is computed exactly once per node).
    incident: dict[str, list[Connection]] = {n.name: [] for n in sched.nodes}
    affected: dict[str, set[str]] = {n.name: set() for n in sched.nodes}
    producers_of: dict[str, set[str]] = {}
    for c in conns:
        incident[c.src].append(c)
        incident[c.dst].append(c)
        affected[c.src].add(c.dst)
        affected[c.dst].add(c.src)
        producers_of.setdefault(c.dst, set()).add(c.src)
    # Co-producers of a shared consumer influence each other's DSE ranking
    # through the consumer's max() roofline term — they must invalidate
    # each other even though no connection links them directly.
    for prods in producers_of.values():
        for p in prods:
            affected[p] |= prods - {p}

    prop_cache: dict[str, list[tuple[dict[str, tuple[str, ...]],
                                     dict[str, int], int]]] = {}

    def proposals_for(node: Node):
        entry = prop_cache.get(node.name)
        if entry is None:
            entry = []
            for proposal in _proposals(node, mesh, res.pf[node.name]):
                unroll = {
                    d: math.prod(mesh.size(a) for a in axes)
                    for d, axes in proposal.items()}
                pref_pen = sum(
                    0 if axes and axes[0] == axis_pref(d)[0] else 1
                    for d, axes in proposal.items())
                entry.append((proposal, unroll, pref_pen))
            prop_cache[node.name] = entry
        return entry

    # Step 2: sort by (connections, intensity) descending.
    ordered = sorted(
        sched.nodes,
        key=lambda n: (counts.get(n.name, 0), n.intensity()), reverse=True)
    res.order = [n.name for n in ordered]
    all_names = {n.name for n in sched.nodes}

    if warm_start is not None:
        canon = {canonical_node_key(i, n.name): n.name
                 for i, n in enumerate(sched.nodes)}
        warm_start = _remap_warm(warm_start, canon, all_names)
        warm_entries = [_remap_warm(f, canon, all_names)
                        for f in (warm_entries or [])] or None

    def rank_node(node: Node, done: set[str], k: int
                  ) -> tuple[list[tuple[tuple, dict, dict]], int, int]:
        """Constrained DSE scan for ``node`` against the *committed*
        estimator state: returns the ``k`` best ``(key, proposal,
        unroll)`` plus (evaluated, rejected) counts.  Pure — scoring goes
        through the read-only ``est.score()``, so concurrent calls for
        nodes with non-overlapping neighbourhoods are safe."""
        constraints: list[dict[str, Fraction]] = []
        neighbor_axes: dict[str, tuple[str, ...]] = {}
        if ca:
            for c in incident[node.name]:
                if c.src == node.name and c.dst in done:
                    other = sched.node(c.dst)
                    proj = c.project(other.unroll, from_src=False)
                elif c.dst == node.name and c.src in done:
                    other = sched.node(c.src)
                    proj = c.project(other.unroll, from_src=True)
                else:
                    continue
                constraints.append(proj)
                # Remember which mesh axes the neighbour used on the mapped
                # dims so the QoR tie-break prefers axis-identical layouts.
                for sdim, _, ddim, _ in c.axes:
                    mine = ddim if c.dst == node.name else sdim
                    theirs = sdim if c.dst == node.name else ddim
                    if mine and theirs and theirs in other.axis_map:
                        neighbor_axes.setdefault(
                            mine, other.axis_map[theirs])

        evaluated = rejected = 0
        scored: list[tuple[tuple, dict, dict]] = []
        for proposal, unroll, pref_penalty in proposals_for(node):
            evaluated += 1
            valid = True
            for constr in constraints:
                for d, cval in constr.items():
                    if not _divisible(cval, unroll.get(d, 1)):
                        valid = False
                        break
                if not valid:
                    break
            if not valid:
                rejected += 1
                continue
            s = est.score(node.name, proposal, unroll)
            if ca:
                neigh_penalty = sum(
                    1 for d, axes in neighbor_axes.items()
                    if proposal.get(d, ()) != axes)
                key = (corrupt_value("dse.score", s.total_s),
                       s.hbm_bytes, neigh_penalty, pref_penalty)
            else:
                # CA off: ignore the coupling cost, exactly the failure
                # mode Fig. 11 demonstrates.
                key = (corrupt_value("dse.score", s.node_compute_s),
                       -s.node_parallel_factor)
            scored.append((key, proposal, unroll))
        # Stable sort: among equal keys the earliest-enumerated proposal
        # wins, matching the strict `<` selection of a linear scan.
        scored.sort(key=lambda t: t[0])
        return scored[:k], evaluated, rejected

    def dse_node(node: Node, done: set[str]) -> bool:
        """One constrained DSE for ``node`` (Alg. 4).  Returns True when
        the assignment changed."""
        fault_point("dse.node")
        top, evaluated, rejected = rank_node(node, done, 1)
        res.evaluated += evaluated
        res.rejected_constraint += rejected
        best, best_unroll = (top[0][1], top[0][2]) if top else ({}, {})
        prev = dict(node.axis_map)
        est.apply(node.name, best, best_unroll)
        return dict(node.axis_map) != prev

    pool = (ThreadPoolExecutor(max_workers=sweep_workers)
            if colored_sweeps and sweep_workers and sweep_workers > 1
            else None)

    def sweep(frontier: list[Node]) -> tuple[list[str], int]:
        """One coordinate-descent sweep over ``frontier`` (in DSE order),
        graph-colored: the frontier is level-scheduled over the
        affected-set graph (every node lands one level after its last
        earlier-ordered conflicting neighbour), each level is scored
        against the frozen committed state — concurrently when a pool is
        configured — and committed as a batch.  Within a level no node is
        in another's affected set, so the selections are independent of
        commit order and the resulting plan matches the serial in-order
        sweep (exact in real arithmetic; see the module docstring for the
        float-tie caveat; asserted on every config by
        ``tests/test_beam.py``).

        Returns ``(changed node names, color count)`` — color count 0 for
        the serial reference mode."""
        if not colored_sweeps:
            return [node.name for node in frontier
                    if dse_node(node, all_names)], 0
        level: dict[str, int] = {}
        for node in frontier:
            lv = 0
            for m in affected[node.name]:
                if m in level:
                    lv = max(lv, level[m] + 1)
            level[node.name] = lv
        classes: list[list[Node]] = [
            [] for _ in range(1 + max(level.values(), default=0))]
        for node in frontier:
            classes[level[node.name]].append(node)

        changed: list[str] = []
        for cls in classes:
            if pool is not None and len(cls) > 1:
                # Data-sized batching: hand each worker a contiguous
                # slice (~2 slices per worker for tail balance) instead
                # of one node per pool task.  At 1k+ nodes a color class
                # can hold hundreds of nodes, and per-task dispatch
                # overhead was beating the scoring work itself.  Slicing
                # is order-preserving, so the zip below and the serial
                # reference stay byte-identical.
                chunk = max(1, -(-len(cls) // (sweep_workers * 2)))
                batches = [cls[b:b + chunk]
                           for b in range(0, len(cls), chunk)]
                picks = [p for sub in pool.map(
                    lambda ns: [rank_node(n, all_names, 1) for n in ns],
                    batches) for p in sub]
            else:
                picks = [rank_node(n, all_names, 1) for n in cls]
            for node, (top, evaluated, rejected) in zip(cls, picks):
                res.evaluated += evaluated
                res.rejected_constraint += rejected
                best, best_unroll = (top[0][1], top[0][2]) if top else ({}, {})
                prev = dict(node.axis_map)
                est.apply(node.name, best, best_unroll)
                if dict(node.axis_map) != prev:
                    changed.append(node.name)
        return changed, len(classes)

    def converge(dirty: set[str], max_sweeps: int, tag: str,
                 within: set[str] | None = None,
                 until: float | None = None) -> None:
        """Full-order coordinate descent to a fixpoint: every sweep covers
        the *whole* current frontier (no first-change short-circuit) and
        re-dirties the affected sets of whatever changed.  Under a
        ``deadline`` each sweep boundary is an interruption point —
        committed state is always a complete, consistent assignment.

        ``within`` restricts the descent to one region: the frontier and
        every re-dirtied set are intersected with it, so nodes outside
        are never touched (the hierarchical DSE's inner level — the
        complement is frozen by protocol).  ``until`` is a sub-deadline
        for this call only (a region's share of the inner budget);
        ``res.budget_expired`` is raised only when the *global* deadline
        is the one that passed."""
        stop_at = deadline if until is None else until
        for s in range(max_sweeps):
            if stop_at is not None and time.perf_counter() >= stop_at:
                if deadline is not None \
                        and time.perf_counter() >= deadline:
                    res.budget_expired = True
                res.log.append(f"{tag} sweep{s + 1}: budget expired")
                break
            frontier = [n for n in ordered if n.name in dirty]
            if not frontier:
                break
            changed, ncolors = sweep(frontier)
            res.log.append(
                f"{tag} sweep{s + 1}: {len(changed)}/{len(frontier)} "
                f"nodes changed "
                f"({f'{ncolors} colors' if ncolors else 'serial'})")
            if not changed:
                break
            dirty = set()
            for name in changed:
                dirty |= affected[name]
            if within is not None:
                dirty &= within

    try:
        # ---- greedy phase: the paper's most-connected-first pass, then
        # coordinate descent (sweeps re-run each node's DSE with *all*
        # neighbours parallelized, converging the chain onto one layout basin
        # — greedy one-pass can lock attention into SP while the FFN picks TP,
        # paying a reshard at every boundary).
        done: set[str] = set()
        if warm_start is not None:
            # Warm seeding: covered nodes take their cached assignment
            # (sanitized onto this mesh — an empty map is still a
            # deliberate cached choice, "replicated"), uncovered nodes
            # run the normal constrained scan against the seeded state.
            res.warm = True
            res.dse_mode = "warm"
            for node in ordered:
                frag = warm_start.get(node.name)
                if frag is not None:
                    prop = _sanitize_warm(
                        node, frag[0], res.pf[node.name], mesh)
                    est.apply(node.name, prop)
                    res.warm_covered += 1
                else:
                    dse_node(node, done)
                done.add(node.name)
            res.log.append(
                f"warm seed: {res.warm_covered}/{len(ordered)} nodes "
                f"covered by cached assignment")
        else:
            for node in ordered:
                dse_node(node, done)
                done.add(node.name)
        converge(set(all_names), max_sweeps=4,
                 tag="warm" if warm_start is not None else "greedy")
        greedy_snap = est.snapshot()
        greedy_key = (est.total_s, est.hbm_bytes_per_device)
        res.greedy_total_s = greedy_key[0]

        def apply_uniform(assign: dict[str, tuple[str, ...]]) -> None:
            """One joint move of radius ∞: the same axis→dim layout applied to
            every node at once (routed through the incremental engine, so each
            candidate costs O(edges), not a batch re-estimate).  Nodes whose
            quantized proposal already matches their live assignment are
            skipped — consecutive family members share most of their
            per-node layouts, so sweeps over the family are diff-priced."""
            for n in sched.nodes:
                prop = _uniform_proposal(n, assign, res.pf[n.name], mesh)
                if prop != n.axis_map:
                    est.apply(n.name, prop)

        def uniform_candidates() -> list[dict[str, tuple[str, ...]]]:
            return _uniform_assignments(sched)

        def neighborhood(origin: str, radius: int) -> list[str]:
            """Nodes within ``radius`` hops of ``origin`` in the affected-set
            graph (origin excluded), in DSE order."""
            seen = {origin}
            frontier = {origin}
            for _ in range(radius):
                frontier = {m for x in frontier for m in affected[x]} - seen
                seen |= frontier
            seen.discard(origin)
            return [n.name for n in ordered if n.name in seen]

        # ---- warm finish: the beam is what makes cold DSE expensive, so
        # the warm path replaces it with two cheap scans over already-
        # enumerated families — (a) the warm_entries fragments (region
        # summaries of the donor plan) as whole-schedule alternatives,
        # (b) the uniform-assignment floor family — keeping strict
        # improvements only.  Everything after the converged warm-greedy
        # state runs inside an error boundary; the converged state is the
        # guaranteed floor.
        if warm_start is not None:
            warm_key = (est.total_s, est.hbm_bytes_per_device)
            warm_snap = est.snapshot()
            res.greedy_total_s = warm_key[0]
            best: list = [warm_key, warm_snap]
            try:
                for frag in (warm_entries or [])[:16]:
                    est.restore(best[1])
                    changed = 0
                    for nm, (am, _ur) in frag.items():
                        if nm not in all_names:
                            continue
                        node = sched.node(nm)
                        prop = _sanitize_warm(node, am, res.pf[nm], mesh)
                        if prop != node.axis_map:
                            est.apply(nm, prop)
                            changed += 1
                    if not changed:
                        continue
                    key = (est.total_s, est.hbm_bytes_per_device)
                    if key < best[0]:
                        best[:] = [key, est.snapshot()]
                for a in uniform_candidates():
                    apply_uniform(a)
                    key = (est.total_s, est.hbm_bytes_per_device)
                    if key < best[0]:
                        best[:] = [key, est.snapshot()]
                est.restore(best[1])
                if best[0] < warm_key:
                    # An alternative won; one short re-converge around it
                    # (restored if it somehow regresses).
                    converge(set(all_names), max_sweeps=2,
                             tag="warm-refine")
                    k2 = (est.total_s, est.hbm_bytes_per_device)
                    if best[0] < k2:
                        est.restore(best[1])
                    res.log.append(
                        f"warm alt: {warm_key[0]*1e3:.3f} -> "
                        f"{min(k2, best[0])[0]*1e3:.3f}ms")
            except Exception as e:
                res.degraded.append(
                    f"warm finish failed ({type(e).__name__}: {e}); "
                    "restored converged warm seed")
                res.log.append(res.degraded[-1])
                est.restore(warm_snap)

        # ---- beam phase: joint multi-node proposals, flat or two-level.
        # The whole phase — region partition, seeding, rounds, refinement
        # — runs inside one error boundary: the beam is an *optimization*
        # over the converged greedy state, never a correctness
        # dependency, so any failure inside it restores the best
        # fully-committed snapshot seen so far (at worst the greedy one)
        # and the compile proceeds.
        elif ca and beam_width > 1:
            # Best fully-committed (key, snapshot) seen anywhere in the
            # phase — the error boundary restores it on failure.
            safe: list = [greedy_key, greedy_snap]

            def expired() -> bool:
                if deadline is not None and time.perf_counter() >= deadline:
                    res.budget_expired = True
                    return True
                return False

            region_specs: list[RegionSpec] = []
            if dse_mode == "hierarchical":
                try:
                    region_specs = dse_regions(sched)
                except Exception as e:
                    res.log.append(
                        f"region partition failed "
                        f"({type(e).__name__}: {e}); flat beam")
                if len(region_specs) < 2:
                    # Single-region schedules take the flat path —
                    # bit-identical to dse_mode="flat" by construction.
                    region_specs = []

            def run_flat() -> None:
                """Whole-schedule beam over joint moves — the original
                flat search, kept as the differential-testing oracle
                (``dse_mode="flat"``) and the single-region path."""
                def sig(snap: Snapshot):
                    return tuple(sorted(
                        (nm, tuple(sorted((d, axes)
                                          for d, axes in am.items())))
                        for nm, (am, _ur) in snap.items()))

                states: dict[tuple, tuple[tuple, Snapshot]] = {}

                def add_state(snap: Snapshot, key: tuple) -> None:
                    s = sig(snap)
                    if s not in states or key < states[s][0]:
                        states[s] = (key, snap)

                add_state(greedy_snap, greedy_key)
                for a in uniform_candidates():
                    apply_uniform(a)
                    key = (est.total_s, est.hbm_bytes_per_device)
                    add_state(est.snapshot(), key)
                beam = sorted(states.values(),
                              key=lambda t: t[0])[:beam_width]
                best_key = beam[0][0]
                if best_key < safe[0]:
                    safe[:] = beam[0]
                res.log.append(
                    f"beam init: {len(states)} states, best "
                    f"{best_key[0]*1e3:.3f}ms"
                    f" (greedy {greedy_key[0]*1e3:.3f}ms)")

                expand_states = max(1, beam_width // 2)
                max_origins = 4
                joint_runners = 2
                for rnd in range(beam_rounds):
                    if expired():
                        res.log.append(
                            f"beam round {rnd + 1}: budget expired")
                        break
                    successors: dict[tuple, tuple[tuple, Snapshot]] = {
                        sig(snap): (key, snap) for key, snap in beam}
                    for key, snap in beam[:expand_states]:
                        if expired():
                            break
                        est.restore(snap)
                        mm = est.mismatched_nodes()
                        origins = sorted(
                            (n for n in ordered if proposals_for(n)),
                            key=lambda n: (n.name not in mm,
                                           -est.node_latency_s(n.name)))
                        for node in origins[:max_origins]:
                            ranked, evaluated, rejected = rank_node(
                                node, all_names, joint_runners + 1)
                            res.evaluated += evaluated
                            res.rejected_constraint += rejected
                            tried = 0
                            for _pkey, prop, unroll in ranked:
                                if prop == node.axis_map:
                                    continue
                                if tried >= joint_runners:
                                    break
                                fault_point("dse.joint")
                                tried += 1
                                res.joint_moves += 1
                                est.apply(node.name, prop, unroll)
                                for m in neighborhood(node.name,
                                                      joint_radius):
                                    dse_node(sched.node(m), all_names)
                                skey = (est.total_s,
                                        est.hbm_bytes_per_device)
                                succ = est.snapshot()
                                s = sig(succ)
                                if s not in successors \
                                        or skey < successors[s][0]:
                                    successors[s] = (skey, succ)
                                est.restore(snap)
                    beam = sorted(successors.values(),
                                  key=lambda t: t[0])[:beam_width]
                    res.log.append(
                        f"beam round {rnd + 1}: {len(successors)} states, "
                        f"best {beam[0][0][0]*1e3:.3f}ms")
                    if beam[0][0] < safe[0]:
                        safe[:] = beam[0]
                    if not beam[0][0] < best_key:
                        break
                    best_key = beam[0][0]
                res.beam_states = len(states) + res.joint_moves

                # Refine the winner with full sweeps; keep whichever of
                # {refined, pre-refinement best, greedy} scores best — beam
                # QoR can therefore never fall below greedy QoR.
                est.restore(beam[0][1])
                converge(set(all_names), max_sweeps=4, tag="beam-refine")
                final_key = (est.total_s, est.hbm_bytes_per_device)
                if beam[0][0] < final_key:
                    est.restore(beam[0][1])
                    final_key = beam[0][0]
                if greedy_key < final_key:
                    est.restore(greedy_snap)

            def run_hier() -> None:
                """Two-level DSE: per-region inner beams composed by an
                inter-region outer beam (HIDA §4 — solve each region's
                local design space, compose summaries one level up)."""
                res.dse_mode = "hierarchical"
                res.regions = len(region_specs)
                t_inner0 = time.perf_counter()
                conn_by_edge = {(c.src, c.dst, c.buffer): c for c in conns}
                uniforms = uniform_candidates()

                # Score the global uniform family once: the outer level
                # seeds with these snapshots verbatim (the flat beam's
                # uniform seeds), and the inner level quantizes only the
                # strongest few per region — quantizing all ~O(dims ×
                # axes) members per region is where a naive inner level
                # spends most of its time.
                scored_uniforms: list[tuple[tuple, Snapshot, dict]] = []
                for a in uniforms:
                    if expired():
                        break
                    apply_uniform(a)
                    scored_uniforms.append(
                        ((est.total_s, est.hbm_bytes_per_device),
                         est.snapshot(), a))
                est.restore(greedy_snap)
                inner_uniforms = [
                    a for _k, _s, a in sorted(
                        scored_uniforms, key=lambda t: t[0])[:6]]
                region_topk = 4
                inner_origins = 2
                # Bound the *total* deepening work, not the per-region
                # work: many small regions each get a shallow beam, few
                # large regions get the full flat-beam expansion width.
                inner_seeds = max(1, min(beam_width // 2,
                                         (2 * beam_width)
                                         // len(region_specs)))
                joint_runners = 2

                # Budget split: the inner level gets INNER_SHARE of the
                # remaining budget, sliced across regions on an absolute
                # timeline (a region finishing early donates its slack to
                # the next); the outer level keeps the rest, and the
                # adaptive re-search below spends outer leftovers on the
                # most uncertain region.
                INNER_SHARE = 0.6
                if deadline is not None:
                    inner_until = min(
                        deadline,
                        t_inner0
                        + max(0.0, deadline - t_inner0) * INNER_SHARE)
                else:
                    inner_until = None

                summaries: list[RegionSummary] = []
                for spec in region_specs:
                    t_r = time.perf_counter()
                    r_until = None
                    if inner_until is not None:
                        r_until = (t_inner0
                                   + (inner_until - t_inner0)
                                   * (spec.index + 1) / len(region_specs))

                    def r_expired() -> bool:
                        return (expired()
                                or (r_until is not None
                                    and time.perf_counter() >= r_until))

                    rnames = set(spec.nodes)
                    view = est.region_view(spec.nodes)
                    r_nodes = [n for n in ordered if n.name in rnames]
                    greedy_frag = view.snapshot()
                    entries: dict[tuple, RegionEntry] = {}

                    def note(origin: str) -> None:
                        frag = view.snapshot()
                        e = RegionEntry(
                            assignment=frag, total_s=est.total_s,
                            delta_s=est.total_s - greedy_key[0],
                            hbm_bytes=est.hbm_bytes_per_device,
                            region_hbm_bytes=view.hbm_bytes,
                            origin=origin)
                        k = _frag_sig(frag)
                        old = entries.get(k)
                        if old is None:
                            entries[k] = e
                        elif e.key() < old.key():
                            # Same fragment against the same complement
                            # scores identically; keep the greedy label.
                            if old.origin == "greedy":
                                e.origin = "greedy"
                            entries[k] = e

                    degraded_note = ""
                    try:
                        fault_point("dse.inner")
                        note("greedy")
                        # Region quantizations of the strongest uniform
                        # family members (the full family still seeds
                        # the outer level as whole-schedule states).
                        seen_frags: set = set()
                        for a in inner_uniforms:
                            frag: Snapshot = {}
                            for n in r_nodes:
                                prop = _uniform_proposal(
                                    n, a, res.pf[n.name], mesh)
                                unroll = {
                                    d: math.prod(mesh.size(x)
                                                 for x in axes)
                                    for d, axes in prop.items()}
                                frag[n.name] = (prop, unroll)
                            k = _frag_sig(frag)
                            if k in seen_frags:
                                continue
                            seen_frags.add(k)
                            view.restore(frag)
                            note("uniform")
                        # Deepen the strongest entries: region-scoped
                        # coordinate descent + within-region joint moves.
                        seeds = sorted(entries.values(),
                                       key=RegionEntry.key)[:inner_seeds]
                        for seed in seeds:
                            if r_expired():
                                break
                            view.restore(seed.assignment)
                            if seed.origin != "greedy":
                                # (The greedy entry is already a global
                                # coordinate-descent fixpoint.)  One
                                # region-scoped sweep: the outer winner
                                # gets the full refinement afterwards.
                                converge(set(rnames), max_sweeps=1,
                                         tag=f"inner r{spec.index}",
                                         within=rnames, until=r_until)
                                note("search")
                            base = view.snapshot()
                            mm = est.mismatched_nodes()
                            origins = sorted(
                                (n for n in r_nodes
                                 if proposals_for(n)),
                                key=lambda n: (
                                    n.name not in mm,
                                    -est.node_latency_s(n.name)))
                            for node in origins[:inner_origins]:
                                if r_expired():
                                    break
                                ranked, evaluated, rejected = rank_node(
                                    node, all_names, joint_runners + 1)
                                res.evaluated += evaluated
                                res.rejected_constraint += rejected
                                tried = 0
                                for _pk, prop, unroll in ranked:
                                    if prop == node.axis_map:
                                        continue
                                    if tried >= joint_runners:
                                        break
                                    tried += 1
                                    res.joint_moves += 1
                                    est.apply(node.name, prop, unroll)
                                    for m in neighborhood(node.name,
                                                          joint_radius):
                                        if m in rnames:
                                            dse_node(sched.node(m),
                                                     all_names)
                                    note("search")
                                    view.restore(base)
                    except Exception as exc:
                        degraded_note = f"{type(exc).__name__}: {exc}"
                        res.degraded.append(
                            f"inner DSE failed on region {spec.index} "
                            f"({degraded_note}); region pinned to its "
                            "greedy/uniform entries")
                        res.log.append(res.degraded[-1])
                    finally:
                        # The complement of later regions must see this
                        # region at greedy — entries are scored against
                        # an all-greedy complement by protocol.
                        view.restore(greedy_frag)
                    if not entries:
                        # dse.inner fired before the greedy entry landed.
                        entries[_frag_sig(greedy_frag)] = RegionEntry(
                            assignment=greedy_frag,
                            total_s=greedy_key[0], delta_s=0.0,
                            hbm_bytes=greedy_key[1],
                            region_hbm_bytes=view.hbm_bytes,
                            origin="greedy")
                    ranked_entries = sorted(entries.values(),
                                            key=RegionEntry.key)
                    top = ranked_entries[:region_topk]
                    if not any(e.origin == "greedy" for e in top):
                        top.append(next(e for e in ranked_entries
                                        if e.origin == "greedy"))
                    summaries.append(RegionSummary(
                        index=spec.index, nodes=spec.nodes, entries=top,
                        boundary_sig=_region_boundary_sig(
                            spec, conn_by_edge, sched.buffers),
                        hbm_bytes=view.hbm_bytes,
                        inner_s=time.perf_counter() - t_r,
                        degraded=degraded_note))
                res.region_summaries = summaries
                res.inner_dse_s = time.perf_counter() - t_inner0
                res.log.append(
                    "inner level: "
                    + ", ".join(f"r{s.index}:{len(s.entries)}e"
                                + ("!" if s.degraded else "")
                                for s in summaries))

                # ---- outer level: compose one entry per region.  A
                # combo is a tuple of entry indices; scoring re-applies
                # only the differing fragments (O(diff × deg) via
                # est.restore) — boundary resharding and the composed
                # footprint come out of the same topology-cached edge
                # terms the flat beam scores with.
                t_outer0 = time.perf_counter()
                fault_point("dse.outer")
                combo_keys: dict[tuple[int, ...], tuple] = {}

                def eval_combo(combo: tuple[int, ...]) -> tuple:
                    key = combo_keys.get(combo)
                    if key is not None:
                        return key
                    snap = dict(greedy_snap)
                    for summ, ei in zip(summaries, combo):
                        snap.update(summ.entries[ei].assignment)
                    est.restore(snap)
                    key = (est.total_s, est.hbm_bytes_per_device)
                    combo_keys[combo] = key
                    return key

                greedy_combo = tuple(s.greedy_index() for s in summaries)
                eval_combo(greedy_combo)
                eval_combo(tuple(0 for _ in summaries))
                # Global uniform states: a truncated family member may
                # not be expressible as a combo, so seed the flat beam's
                # uniform states directly (scored once, up front) — the
                # outer winner can never lose to a uniform layout.
                extra: list[tuple[tuple, Snapshot]] = [
                    (k, snap) for k, snap, _a in scored_uniforms]

                expand_states = max(1, beam_width // 2)
                # Regions with the widest entry spread first: that is
                # where composition choices move the total the most.
                region_order = sorted(
                    range(len(summaries)),
                    key=lambda r: (-(summaries[r].entries[-1].total_s
                                     - summaries[r].entries[0].total_s),
                                   r))
                for rnd in range(beam_rounds):
                    if expired():
                        res.log.append(
                            f"outer round {rnd + 1}: budget expired")
                        break
                    prev_best = min(combo_keys.values())
                    frontier = sorted(
                        combo_keys.items(),
                        key=lambda kv: kv[1])[:expand_states]
                    for combo, _k in frontier:
                        if expired():
                            break
                        for r in region_order:
                            for ei in range(len(summaries[r].entries)):
                                if ei == combo[r]:
                                    continue
                                cand = (combo[:r] + (ei,)
                                        + combo[r + 1:])
                                if cand in combo_keys:
                                    continue
                                fault_point("dse.outer")
                                eval_combo(cand)
                    best_now = min(combo_keys.values())
                    res.log.append(
                        f"outer round {rnd + 1}: {len(combo_keys)} "
                        f"combos, best {best_now[0]*1e3:.3f}ms")
                    if not best_now < prev_best:
                        break
                res.beam_states += len(combo_keys) + len(extra)

                # Winner = best of every combo and every uniform seed.
                win_combo = min(combo_keys,
                                key=lambda c: combo_keys[c])
                win_key = combo_keys[win_combo]
                win_snap = dict(greedy_snap)
                for summ, ei in zip(summaries, win_combo):
                    win_snap.update(summ.entries[ei].assignment)
                for key, snap in extra:
                    if key < win_key:
                        win_key, win_snap = key, snap
                if win_key < safe[0]:
                    safe[:] = [win_key, win_snap]
                res.log.append(
                    f"outer level: best {win_key[0]*1e3:.3f}ms "
                    f"(greedy {greedy_key[0]*1e3:.3f}ms)")

                # Full-schedule refinement of the winner; keep the best
                # of {refined, winner, greedy} — hierarchical QoR can
                # never fall below greedy QoR, exactly like the flat
                # beam.
                est.restore(win_snap)
                converge(set(all_names), max_sweeps=4,
                         tag="outer-refine")
                final_key = (est.total_s, est.hbm_bytes_per_device)
                if win_key < final_key:
                    est.restore(win_snap)
                    final_key = win_key
                if greedy_key < final_key:
                    est.restore(greedy_snap)
                    final_key = greedy_key
                if final_key < safe[0]:
                    safe[:] = [final_key, est.snapshot()]

                # Adaptive split: whatever outer budget is left goes to
                # deepening the most uncertain region (widest entry
                # spread) from the final composition.
                if deadline is not None and summaries \
                        and not expired():
                    r = region_order[0]
                    base_snap = est.snapshot()
                    base_key = final_key
                    converge(set(summaries[r].nodes), max_sweeps=3,
                             tag=f"outer-deepen r{r}",
                             within=set(summaries[r].nodes))
                    k2 = (est.total_s, est.hbm_bytes_per_device)
                    if k2 < base_key:
                        res.log.append(
                            f"outer-deepen r{r}: {base_key[0]*1e3:.3f}"
                            f" -> {k2[0]*1e3:.3f}ms")
                        if k2 < safe[0]:
                            safe[:] = [k2, est.snapshot()]
                    else:
                        est.restore(base_snap)
                res.outer_dse_s = time.perf_counter() - t_outer0

            try:
                if region_specs:
                    run_hier()
                else:
                    run_flat()
            except Exception as e:
                res.degraded.append(
                    f"beam phase failed ({type(e).__name__}: {e}); "
                    "restored best pre-failure snapshot")
                res.log.append(res.degraded[-1])
                est.restore(safe[1])
        elif seed_uniform:
            # Legacy pre-beam escape hatch (deprecated): best uniform
            # assignment, then two refinement sweeps over the full node order
            # (an earlier version short-circuited at the first changed node).
            best_state = est.snapshot()
            best_cost = est.total_s
            for a in uniform_candidates():
                apply_uniform(a)
                cost = est.total_s
                if cost < best_cost:
                    best_cost, best_state = cost, est.snapshot()
                    res.log.append(f"uniform-seed: {a} -> {cost*1e3:.2f}ms")
            est.restore(best_state)
            for _ in range(2):
                if not any([dse_node(n, all_names) for n in ordered]):
                    break
            if est.total_s > best_cost:
                est.restore(best_state)

    finally:
        if pool is not None:
            pool.shutdown()
    for node in ordered:
        res.log.append(
            f"{node.name}: pf={res.pf[node.name]} "
            f"factors={node.unroll} axes={node.axis_map}")
    res.cost = est.schedule_cost()
    return res
