"""Intensity- and connection-aware dataflow parallelization — paper
Section 6.5 / Algorithm 4, re-targeted from FPGA loop-unroll factors to
TPU mesh-axis sharding factors.

Steps (paper numbering):

1. **Intensity & connection analysis** — per shared buffer, build the
   permutation map (which loop level of the producer aligns with which loop
   level of the consumer) and the scaling map (access-stride ratio).
2. **Node sorting** — descending by connection count, intensity as the
   tie-breaker.
3. **Parallel factor generation** — per-node max parallel factor
   proportional to intensity under the global budget (the chip count).
4. **Node parallelization** — constrained DSE per node: proposals are
   mesh-axis→loop-dim assignments (the TPU quantization of unroll
   factors); a proposal is invalid when (a) any factor is mutually
   indivisible with the constraint projected from an already-parallelized
   connected node through the scaling+permutation maps, or (b) the node's
   total parallelism exceeds its intensity-derived parallel factor.  Valid
   proposals are scored with the roofline QoR estimator; the best one is
   applied.

Ablation switches (``ia``, ``ca``) reproduce the paper's IA-only / CA-only
/ naive arms (Fig. 11).

Compile-time engineering (the DSE is the whole ``optimize()`` hot path;
``benchmarks/bench_compile_time.py`` tracks it PR-over-PR):

* Proposals are scored through :class:`~.incremental.IncrementalEstimator`
  — re-scoring one node's proposal is O(deg) instead of the batch
  estimator's O(nodes × ops), with bit-identical totals.
* ``_proposals()`` enumeration (and each proposal's unroll factors and
  canonical-preference penalty) is memoized per node — the pf cap is fixed
  for the whole ``parallelize()`` call, so sweeps 2+ reuse the sweep-1
  enumeration.
* Constraint projection only scans the connections *incident* to the node
  under DSE (hoisted per-node incidence lists) rather than every
  connection in the schedule.
* Coordinate-descent sweeps keep a **dirty set**: a node is only re-DSE'd
  when its DSE inputs may have changed.  Scoring node *n*'s proposals
  varies the latencies of *n* and its direct consumers only, and reads
  the committed state of *n*'s neighbours (constraints, neighbour-axes
  tie-break) and of the *co-producers* feeding a shared consumer (their
  reshard contribution shifts the consumer's ``max()`` roofline term).
  So a change to node *x* dirties ``neighbours(x) ∪ co_producers(x)`` —
  immediately, so later-ordered nodes re-run within the same sweep, as
  the full sweep would — and a clean node provably re-selects the same
  proposal (its search is independent of its own current assignment).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Optional

from .estimator import MeshSpec, ScheduleCost
from .incremental import IncrementalEstimator
from .ir import Node, Schedule

# Mesh-axis affinity by loop-dim name: which axes a dim may take, in
# preference order.  Batch-like dims soak up the pure-DP axes; everything
# else competes for the model axis (and may spill onto data/pod when the
# batch is too small to fill them, e.g. long_500k decode with batch=1).
_DATA_AXES = ("pod", "data")
_DIM_AXIS_PREF: dict[str, tuple[str, ...]] = {
    # batch never takes the model axis: mixing DP and TP on one dim breeds
    # the resharding chains GSPMD resolves by full rematerialization.
    # And nothing except batch takes the pod axis: TP/EP/SP across the DCN
    # is never right at this scale.
    "batch": ("pod", "data"),
    "seq": ("model", "data"),
    "kv_seq": ("model", "data"),
}
_DEFAULT_PREF = ("model", "data")


def axis_pref(dim: str) -> tuple[str, ...]:
    for key, pref in _DIM_AXIS_PREF.items():
        if dim == key or dim.startswith(key + "_"):
            return pref
    return _DEFAULT_PREF


# --------------------------------------------------------------------------
# Step 1 — connections
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Connection:
    """A producer→consumer link through a shared buffer (paper Table 4)."""

    src: str
    dst: str
    buffer: str
    # Per buffer axis: (src loop dim, src stride, dst loop dim, dst stride).
    axes: tuple[tuple[Optional[str], Fraction, Optional[str], Fraction], ...]

    def project(self, factors: dict[str, int], from_src: bool
                ) -> dict[str, Fraction]:
        """Project ``factors`` of one endpoint onto the other endpoint's
        loop dims: multiply by the scaling map, permute by the permutation
        map (Alg. 4 lines 3-8)."""
        out: dict[str, Fraction] = {}
        for sdim, sstride, ddim, dstride in self.axes:
            if from_src:
                odim, ostride, mdim, mstride = sdim, sstride, ddim, dstride
            else:
                odim, ostride, mdim, mstride = ddim, dstride, sdim, sstride
            if odim is None or mdim is None:
                continue
            f = factors.get(odim)
            if f is None:
                continue
            out[mdim] = Fraction(f) * ostride / mstride
        return out


def analyze_connections(sched: Schedule) -> list[Connection]:
    conns: list[Connection] = []
    for src, dst, bname in sched.edges():
        p, c = sched.node(src), sched.node(dst)
        pam, cam = p.access_for(bname), c.access_for(bname)
        if pam is None or cam is None:
            continue
        axes = tuple(
            (pam.entries[i][0], pam.entries[i][1],
             cam.entries[i][0], cam.entries[i][1])
            for i in range(len(pam.entries)))
        conns.append(Connection(src, dst, bname, axes))
    return conns


def connection_count(sched: Schedule,
                     conns: list[Connection] | None = None
                     ) -> dict[str, int]:
    if conns is None:
        conns = analyze_connections(sched)
    count: dict[str, int] = {n.name: 0 for n in sched.nodes}
    for c in conns:
        count[c.src] += 1
        count[c.dst] += 1
    return count


# --------------------------------------------------------------------------
# Step 3 — intensity-proportional parallel factors
# --------------------------------------------------------------------------

def parallel_factors(sched: Schedule, max_pf: int, ia: bool
                     ) -> dict[str, int]:
    """pf(node) ∝ intensity, rounded up to a power of two, capped at
    ``max_pf`` (paper Table 5).  Without IA every node gets ``max_pf``."""
    if not ia:
        return {n.name: max_pf for n in sched.nodes}
    peak = max((n.intensity() for n in sched.nodes), default=1) or 1
    out: dict[str, int] = {}
    for n in sched.nodes:
        share = n.intensity() / peak
        pf = max(1, min(max_pf, 2 ** math.ceil(math.log2(max(
            share * max_pf, 1)))))
        out[n.name] = pf
    return out


# --------------------------------------------------------------------------
# Step 4 — constrained per-node DSE
# --------------------------------------------------------------------------

def _divisible(constraint: Fraction, factor: int) -> bool:
    """Paper Alg. 4 line 15: mutually indivisible → invalid."""
    if constraint <= 0:
        return True
    a = constraint / factor
    b = Fraction(factor) / constraint
    return a.denominator == 1 or b.denominator == 1


def _shardable_dims(node: Node) -> dict[str, int]:
    dims = node.loop_dims()
    blocked: set[str] = set()
    for o in node.body:
        blocked.update(o.attrs.get("no_shard", ()))
    return {d: s for d, s in dims.items() if s > 1 and d not in blocked}


def _proposals(node: Node, mesh: MeshSpec, pf_cap: int
               ) -> list[dict[str, tuple[str, ...]]]:
    """Enumerate mesh-axis→dim assignments.  Each axis is assigned to at
    most one loop dim (or left unused); a dim may take several axes.  The
    factor of a dim is the product of its axes' sizes; dim size must be
    divisible by its factor; total parallelism must not exceed ``pf_cap``
    (Alg. 4 line 17)."""
    dims = _shardable_dims(node)
    axes = list(mesh.axes)
    choices_per_axis: list[list[Optional[str]]] = []
    for aname, asize in axes:
        opts: list[Optional[str]] = [None]
        for d, size in dims.items():
            if aname in axis_pref(d):
                opts.append(d)
        choices_per_axis.append(opts)
    out: list[dict[str, tuple[str, ...]]] = []
    for combo in itertools.product(*choices_per_axis):
        assign: dict[str, list[str]] = {}
        for (aname, asize), d in zip(axes, combo):
            if d is not None:
                assign.setdefault(d, []).append(aname)
        total = 1
        ok = True
        for d, alist in assign.items():
            f = 1
            for a in alist:
                f *= mesh.size(a)
            if dims[d] % f != 0:
                ok = False
                break
            # TPU adaptation of the paper's parallel-factor budget: chips
            # are not a consumable resource (unlike DSPs) — pure data
            # parallelism over the batch dim is free, so only
            # communication-bearing dims count against the IA budget.
            if not (d == "batch" or d.startswith("batch_")):
                total *= f
        if not ok or total > pf_cap:
            continue
        out.append({d: tuple(a) for d, a in assign.items()})
    return out


def _apply(node: Node, proposal: dict[str, tuple[str, ...]],
           mesh: MeshSpec) -> None:
    node.axis_map = dict(proposal)
    node.unroll = {
        d: math.prod(mesh.size(a) for a in axes)
        for d, axes in proposal.items()}


@dataclass
class ParallelizeResult:
    order: list[str] = field(default_factory=list)
    pf: dict[str, int] = field(default_factory=dict)
    evaluated: int = 0
    rejected_constraint: int = 0
    rejected_budget: int = 0
    log: list[str] = field(default_factory=list)
    #: final schedule cost from the incremental engine (bit-identical to
    #: ``estimate(sched, mesh, training)`` on the returned assignment).
    cost: ScheduleCost | None = None


def parallelize(sched: Schedule, mesh: MeshSpec, *,
                max_parallel_factor: int | None = None,
                ia: bool = True, ca: bool = True,
                training: bool = True,
                seed_uniform: bool = False) -> ParallelizeResult:
    """Paper Section 6.5 steps 1-4 over a Structural schedule (in place)."""
    res = ParallelizeResult()
    max_pf = max_parallel_factor or mesh.chips
    conns = analyze_connections(sched)
    counts = connection_count(sched, conns)
    res.pf = parallel_factors(sched, max_pf, ia)
    est = IncrementalEstimator(sched, mesh, training=training)

    # Hoisted DSE structure: per-node incident connections (in global conn
    # order), neighbourhood sets for the dirty-set sweeps, and the memoized
    # proposal enumeration (the pf cap is fixed per node for this call, so
    # the enumeration — and each proposal's unroll factors and static
    # preference penalty — is computed exactly once per node).
    incident: dict[str, list[Connection]] = {n.name: [] for n in sched.nodes}
    affected: dict[str, set[str]] = {n.name: set() for n in sched.nodes}
    producers_of: dict[str, set[str]] = {}
    for c in conns:
        incident[c.src].append(c)
        incident[c.dst].append(c)
        affected[c.src].add(c.dst)
        affected[c.dst].add(c.src)
        producers_of.setdefault(c.dst, set()).add(c.src)
    # Co-producers of a shared consumer influence each other's DSE ranking
    # through the consumer's max() roofline term — they must invalidate
    # each other even though no connection links them directly.
    for prods in producers_of.values():
        for p in prods:
            affected[p] |= prods - {p}

    prop_cache: dict[str, list[tuple[dict[str, tuple[str, ...]],
                                     dict[str, int], int]]] = {}

    def proposals_for(node: Node):
        entry = prop_cache.get(node.name)
        if entry is None:
            entry = []
            for proposal in _proposals(node, mesh, res.pf[node.name]):
                unroll = {
                    d: math.prod(mesh.size(a) for a in axes)
                    for d, axes in proposal.items()}
                pref_pen = sum(
                    0 if axes and axes[0] == axis_pref(d)[0] else 1
                    for d, axes in proposal.items())
                entry.append((proposal, unroll, pref_pen))
            prop_cache[node.name] = entry
        return entry

    # Step 2: sort by (connections, intensity) descending.
    ordered = sorted(
        sched.nodes,
        key=lambda n: (counts.get(n.name, 0), n.intensity()), reverse=True)
    res.order = [n.name for n in ordered]

    def dse_node(node: Node, done: set[str]) -> bool:
        """One constrained DSE for ``node`` (Alg. 4).  Returns True when
        the assignment changed."""
        constraints: list[dict[str, Fraction]] = []
        neighbor_axes: dict[str, tuple[str, ...]] = {}
        if ca:
            for c in incident[node.name]:
                if c.src == node.name and c.dst in done:
                    other = sched.node(c.dst)
                    proj = c.project(other.unroll, from_src=False)
                elif c.dst == node.name and c.src in done:
                    other = sched.node(c.src)
                    proj = c.project(other.unroll, from_src=True)
                else:
                    continue
                constraints.append(proj)
                # Remember which mesh axes the neighbour used on the mapped
                # dims so the QoR tie-break prefers axis-identical layouts.
                for sdim, _, ddim, _ in c.axes:
                    mine = ddim if c.dst == node.name else sdim
                    theirs = sdim if c.dst == node.name else ddim
                    if mine and theirs and theirs in other.axis_map:
                        neighbor_axes.setdefault(
                            mine, other.axis_map[theirs])

        prev = dict(node.axis_map)
        best = None
        best_unroll: dict[str, int] = {}
        best_key = None
        for proposal, unroll, pref_penalty in proposals_for(node):
            res.evaluated += 1
            valid = True
            for constr in constraints:
                for d, cval in constr.items():
                    if not _divisible(cval, unroll.get(d, 1)):
                        valid = False
                        break
                if not valid:
                    break
            if not valid:
                res.rejected_constraint += 1
                continue
            est.propose(node.name, proposal, unroll)
            neigh_penalty = sum(
                1 for d, axes in neighbor_axes.items()
                if proposal.get(d, ()) != axes)
            if ca:
                key = (est.total_s, est.hbm_bytes_per_device,
                       neigh_penalty, pref_penalty)
            else:
                # CA off: ignore the coupling cost, exactly the failure
                # mode Fig. 11 demonstrates.
                key = (est.node_compute_s(node.name),
                       -est.node_parallel_factor(node.name))
            est.rollback()
            if best_key is None or key < best_key:
                best_key, best, best_unroll = key, proposal, unroll
        if best is None:
            best, best_unroll = {}, {}
        est.apply(node.name, best, best_unroll)
        return dict(node.axis_map) != prev

    # Sweep 1: the paper's greedy order (most-connected first).  Further
    # sweeps re-run each node's DSE with *all* neighbours parallelized —
    # coordinate descent that converges the chain onto one layout basin
    # (greedy one-pass can lock attention into SP while the FFN picks TP,
    # paying a reshard at every boundary).  The dirty set short-circuits
    # sweeps 3+: only nodes with a changed neighbour can select differently.
    done: set[str] = set()
    for node in ordered:
        dse_node(node, done)
        done.add(node.name)
    dirty = {n.name for n in ordered}
    for sweep in range(3):
        changed_names: list[str] = []
        for node in ordered:
            if node.name not in dirty:
                continue
            dirty.discard(node.name)
            if dse_node(node, done):
                changed_names.append(node.name)
                dirty |= affected[node.name]
        res.log.append(f"sweep{sweep + 2}: {len(changed_names)} nodes changed")
        if not changed_names:
            break

    if seed_uniform:
        # Beyond-paper escape hatch for coordination lock-in: per-node
        # moves cannot leave an all-unsharded basin when each single move
        # pays two reshard boundaries that exceed its own gain (a joint
        # move is needed).  Evaluate a small family of *uniform* axis→dim
        # assignments applied to every node at once; adopt the best if it
        # beats the per-node result, then refine with two more sweeps.
        # All bulk mutations are routed through the incremental engine, so
        # each candidate costs O(edges), not a batch re-estimate.
        def snapshot():
            return {n.name: (dict(n.unroll), dict(n.axis_map))
                    for n in sched.nodes}

        def restore(state):
            for n in sched.nodes:
                unroll, axis_map = state[n.name]
                est.apply(n.name, dict(axis_map), dict(unroll))

        def apply_uniform(assign: dict[str, tuple[str, ...]]):
            for n in sched.nodes:
                dims = _shardable_dims(n)
                prop = {}
                total = 1
                for d, axes in assign.items():
                    if d not in dims:
                        continue
                    f = math.prod(mesh.size(a) for a in axes)
                    if dims[d] % f:
                        continue
                    if not (d == "batch" or d.startswith("batch_")):
                        if total * f > res.pf[n.name]:
                            continue
                        total *= f
                    prop[d] = axes
                est.apply(n.name, prop)

        best_state = snapshot()
        best_cost = est.total_s
        all_dims = sorted({d for n in sched.nodes
                           for d in _shardable_dims(n)})
        cands = []
        for d1 in all_dims + [None]:
            for d2 in all_dims + [None]:
                a: dict[str, tuple[str, ...]] = {}
                if d1 and "data" in axis_pref(d1):
                    a[d1] = ("data",)
                if d2 and "model" in axis_pref(d2):
                    a[d2] = (a.get(d2, ()) + ("model",))
                if a:
                    cands.append(a)
        for a in cands:
            apply_uniform(a)
            cost = est.total_s
            if cost < best_cost:
                best_cost, best_state = cost, snapshot()
                res.log.append(f"uniform-seed: {a} -> {cost*1e3:.2f}ms")
        restore(best_state)
        for sweep in range(2):
            if not any(dse_node(n, done) for n in ordered):
                break
        final = est.total_s
        if final > best_cost:
            restore(best_state)

    for node in ordered:
        res.log.append(
            f"{node.name}: pf={res.pf[node.name]} "
            f"factors={node.unroll} axes={node.axis_map}")
    res.cost = est.schedule_cost()
    return res
