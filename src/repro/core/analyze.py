"""Static dataflow hazard analyzer for the ``(Schedule, ShardingPlan)`` IR.

:mod:`repro.core.verify` answers "is this plan a *legal* sharding of this
schedule"; this module answers the orthogonal question HIDA's dataflow
semantics raise: "can this schedule *hang or corrupt data* when it runs"?
A hierarchical dataflow implementation is only sound if every
reconvergent path's skew is absorbed by buffer ``stages`` / FIFO depth
(otherwise the producer stalls and the design artificially deadlocks —
the classic hazard the dataflow-architectural-template and
HLS-transformations literature guard against), if no two sharded
instances write the same buffer region, and if every consumed region has
a single happens-before writer.  ``balance.py`` *inserts* skew chains
and soft FIFOs; nothing before this module ever *proved* they suffice —
degraded-ladder exits, chaos-lane outputs and cache-loaded plans all
shipped unchecked.

Architecture: a **rule registry** in the style of the verifier's check
families, but pluggable — each rule is a named function registered with
:func:`register_rule`, grouped into four hazard families:

* **deadlock** —
  ``deadlock.depth``: recomputes per-edge skew from the cached
  :class:`~repro.core.ir.ScheduleTopology` depth map and proves each
  buffer's ``stages`` absorbs it (``stages >= skew + 1``, the
  ``balance.py`` soft-FIFO contract).  Codes: ``fifo-underdepth`` (an
  external soft FIFO too shallow for its edge's skew),
  ``reconvergent-deadlock`` (an on-chip buffer on a reconvergent
  diamond without the staging to cover the long path), and
  ``token-missing`` (warning: a skewed soft-FIFO edge without its
  elastic ordering token).
  ``deadlock.cycle``: Kahn over the *union* of dataflow and token
  edges — a cycle through a token edge (``token-cycle``) or through
  dataflow alone (``deadlock-cycle``) can never make progress; tokens
  naming unknown nodes are ``token-dangling``.
* **shard-race** —
  ``race.shard``: cross-checks writer access maps (and the plan's
  rules, when given) for write-write overlap: two *writers* whose
  access maps index the same buffer axis by different loop dims put
  their unrolled/sharded instances on overlapping regions
  (``shard-race``), and a read-modify-write node unrolled over a loop
  dim its access map never indexes has every instance clobbering the
  others' updates (``rw-lost-update``).  Reader-side dim aliasing
  (e.g. attention reading a ``seq``-indexed buffer under ``kv_seq``)
  is *not* flagged — under value semantics a disagreeing read is a
  legal resharding, which is why the detector is writer-only.
* **ordering** —
  ``order.writers``: every pair of writers of a shared buffer must be
  ordered by happens-before (dataflow ∪ token edges), else the
  consumed region has no single last writer (``write-order``) — the
  invariant multi-producer elimination exists to establish.
  ``order.alias``: ``add_role_alias`` bookkeeping — an alias whose
  source is itself an alias goes stale under the one-hop
  ``apply_rule_change`` refresh (``alias-chain``), a source without a
  spec is dangling (``alias-missing``), and an alias spec that no
  longer mirrors its source is stale (``alias-drift``).  Runs from a
  plan alone (``plan_only``), so the plan cache can gate loads on it.
* **invariant** —
  ``invariant.index``: cheap session-invariant lint — the maintained
  :class:`ScheduleTopology` must match a from-scratch rebuild
  (``topology-stale``; capped at :data:`DEEP_CHECK_NODE_CAP` nodes,
  the skip is recorded in ``stats``), its memoized topo order / depth
  map must match re-derivation (``order-stale`` / ``depth-stale``),
  and the schedule's name→node cache must agree with the node list
  (``node-cache-stale``).  The from-scratch sweeps the selfcheck mode
  of the rewrite sessions runs under tests, runnable on any schedule.

Every rule runs inside its own guard with a ``fault_point
("analyze.rules")`` injection site: a crashing rule becomes an
``analyze-internal`` issue on the report (and a recorded
``Degradation`` in ``optimize()``), never an exception — the analyzer
shares the verifier's never-take-the-pipeline-down contract.  It is
read-only and draws no fresh names, so the zero-fault compile path
stays bit-identical with or without it.

Where it runs: on every :func:`repro.core.optimize.optimize` exit
(every degradation-ladder rung included — ``report.analyze`` /
``report.analyze_s``), on :meth:`repro.core.plan_cache.PlanCache.fetch`
before a cached plan is reused (plan-only rules, via
:func:`analyze_plan`), as a serving pre-flight in
``repro.launch.serve``, and as the CI CLI ``python -m repro.lint``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from .estimator import MeshSpec
from .faults import fault_point
from .ir import (MemoryEffect, Schedule, ScheduleTopology, depth_map_over,
                 topo_order_over)
from .plan import ShardingPlan

__all__ = ["AnalysisIssue", "AnalysisRule", "AnalyzeReport", "analyze",
           "analyze_plan", "register_rule", "registered_rules",
           "DEEP_CHECK_NODE_CAP"]

#: node-count ceiling for the invariant family's from-scratch topology
#: rebuild (O(nodes × args) — ~150 ms at 5k nodes, far over the lint's
#: per-compile budget).  Above it the deep compare is skipped and the
#: skip recorded in ``report.stats["invariant_deep_skipped"]`` — never a
#: silent cap.  The memo checks (order/depth) stay on at every size.
DEEP_CHECK_NODE_CAP = 3000


@dataclass(frozen=True)
class AnalysisIssue:
    code: str       # machine-readable hazard identifier (see module doc)
    severity: str   # "error" | "warning"
    site: str       # node / buffer / token / alias name ("" = global)
    message: str

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"[{self.severity}:{self.code}] {self.site}: {self.message}"


@dataclass
class AnalyzeReport:
    issues: list[AnalysisIssue] = field(default_factory=list)
    #: individual hazard predicates evaluated (an empty schedule
    #: trivially passes — assert on this to know the rules did work).
    checks: int = 0
    #: rules that ran to completion (crashed rules are absent here and
    #: present as ``analyze-internal`` issues instead).
    rules_run: list[str] = field(default_factory=list)
    stats: dict = field(default_factory=dict)
    elapsed_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.errors()

    def errors(self) -> list[AnalysisIssue]:
        return [i for i in self.issues if i.severity == "error"]

    def warnings(self) -> list[AnalysisIssue]:
        return [i for i in self.issues if i.severity == "warning"]

    def codes(self) -> set[str]:
        return {i.code for i in self.issues}

    def crashed_rules(self) -> list[str]:
        """Rules whose guard caught an exception (``analyze-internal``)."""
        return sorted({i.site for i in self.issues
                       if i.code == "analyze-internal"})

    def summary(self) -> str:
        errs, warns = self.errors(), self.warnings()
        if not errs and not warns:
            return (f"analyze: clean ({self.checks} checks, "
                    f"{len(self.rules_run)} rules)")
        head = (f"analyze: {len(errs)} hazard(s), {len(warns)} warning(s) "
                f"over {self.checks} checks")
        lines = [str(i) for i in errs[:8]] + \
            ([f"... {len(errs) - 8} more"] if len(errs) > 8 else []) + \
            [str(i) for i in warns[:4]]
        return "\n".join([head] + lines)


# --------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class AnalysisRule:
    name: str           # dotted id, e.g. "deadlock.depth"
    family: str         # deadlock | shard-race | ordering | invariant
    plan_only: bool     # runnable from (plan, mesh) alone — cache gate
    fn: Callable[["_Context"], None]


_RULES: dict[str, AnalysisRule] = {}


def register_rule(name: str, *, family: str, plan_only: bool = False):
    """Register an analysis rule.  Rules run in registration order;
    each receives the :class:`_Context` and reports through
    ``ctx.issue`` — returning findings by raising is a crash, not a
    report.  Third-party / test rules may register too; ``analyze``'s
    ``rules=`` argument selects a subset by name."""
    def deco(fn):
        if name in _RULES:
            raise ValueError(f"analysis rule {name!r} already registered")
        _RULES[name] = AnalysisRule(name, family, plan_only, fn)
        return fn
    return deco


def registered_rules() -> tuple[str, ...]:
    """Registered rule names, in run order."""
    return tuple(_RULES)


@dataclass
class _Context:
    """What a rule sees.  ``sched``/``topo`` are ``None`` for plan-only
    invocations (:func:`analyze_plan`); ``plan``/``mesh`` are ``None``
    when a bare schedule is analyzed."""
    sched: Optional[Schedule]
    plan: Optional[ShardingPlan]
    mesh: Optional[MeshSpec]
    topo: Optional[ScheduleTopology]
    rep: AnalyzeReport

    def issue(self, code: str, site: str, message: str,
              severity: str = "error") -> None:
        self.rep.issues.append(AnalysisIssue(code, severity, site, message))

    def check(self, n: int = 1) -> None:
        self.rep.checks += n


# --------------------------------------------------------------------------
# Family 1: deadlock / FIFO-depth sufficiency
# --------------------------------------------------------------------------

@register_rule("deadlock.depth", family="deadlock")
def _rule_deadlock_depth(ctx: _Context) -> None:
    """stages >= skew + 1 on every positive-skew edge (Fig. 8 contract)."""
    sched, topo = ctx.sched, ctx.topo
    if sched is None or topo is None:
        return
    try:
        depth = topo.depth_of(sched.nodes, sched.name)
    except ValueError:
        return  # cyclic — deadlock.cycle owns that report
    tokens = {(t.src, t.dst) for t in sched.tokens}
    for src, dst, bname in topo.edges:
        skew = depth[dst] - depth[src] - 1
        if skew <= 0:
            continue
        ctx.check()
        buf = sched.buffers.get(bname)
        if buf is None:
            continue
        need = skew + 1
        if buf.stages < need:
            if buf.placement == "external":
                ctx.issue(
                    "fifo-underdepth", bname,
                    f"soft FIFO has stages={buf.stages} but edge "
                    f"{src}->{dst} skips {skew} pipeline level(s) — "
                    f"needs stages >= {need} to absorb the skew "
                    f"(balance.py soft-FIFO contract)")
            else:
                ctx.issue(
                    "reconvergent-deadlock", bname,
                    f"reconvergent path {src}->{dst} skips {skew} "
                    f"pipeline level(s) but the buffer holds only "
                    f"{buf.stages} stage(s): the producer stalls after "
                    f"{buf.stages} frame(s) while the long path still "
                    f"needs {need} in flight — artificial deadlock")
        elif buf.placement == "external" and (src, dst) not in tokens:
            ctx.issue(
                "token-missing", bname,
                f"skewed soft-FIFO edge {src}->{dst} (skew {skew}) has "
                "no TokenEdge ordering the rotation — elastic execution "
                "can reorder producer/consumer iterations",
                severity="warning")


@register_rule("deadlock.cycle", family="deadlock")
def _rule_deadlock_cycle(ctx: _Context) -> None:
    """No cycle through the dataflow ∪ token happens-before relation."""
    sched, topo = ctx.sched, ctx.topo
    if sched is None or topo is None:
        return
    names = {n.name for n in sched.nodes}
    union: list[tuple[str, str]] = [(s, d) for s, d, _ in topo.edges]
    for t in sched.tokens:
        ctx.check()
        missing = [x for x in (t.src, t.dst) if x not in names]
        if missing:
            ctx.issue("token-dangling", f"{t.src}->{t.dst}",
                      f"token edge names unknown node(s) {missing}")
            continue
        union.append((t.src, t.dst))
    ctx.check()
    succ: dict[str, set[str]] = {n: set() for n in names}
    indeg: dict[str, int] = {n: 0 for n in names}
    for s, d in union:
        if d not in succ[s]:
            succ[s].add(d)
            indeg[d] += 1
    ready = [n for n in names if indeg[n] == 0]
    emitted = 0
    while ready:
        n = ready.pop()
        emitted += 1
        for m in succ[n]:
            indeg[m] -= 1
            if indeg[m] == 0:
                ready.append(m)
    if emitted == len(names):
        return
    leftover = {n for n in names if indeg[n] > 0}
    token_in_cycle = any(t.src in leftover and t.dst in leftover
                         for t in sched.tokens)
    sample = ", ".join(sorted(leftover)[:6])
    tail = "..." if len(leftover) > 6 else ""
    ctx.issue(
        "token-cycle" if token_in_cycle else "deadlock-cycle", sched.name,
        f"{len(leftover)} node(s) sit on a happens-before cycle "
        f"({'through a token edge' if token_in_cycle else 'dataflow only'})"
        f": {sample}{tail} — no iteration of these nodes can ever start")


# --------------------------------------------------------------------------
# Family 2: shard-race detection
# --------------------------------------------------------------------------

@register_rule("race.shard", family="shard-race")
def _rule_race_shard(ctx: _Context) -> None:
    """Write-write overlap across unrolled/sharded node instances."""
    sched, topo, plan = ctx.sched, ctx.topo, ctx.plan
    if sched is None or topo is None:
        return
    # Writer-side dim disagreement per buffer axis: instance i of writer
    # A owns the slice dim_A == i while instance i of writer B owns
    # dim_B == i — different dims means the slices overlap.  Readers are
    # exempt: a disagreeing *read* is a legal resharding/gather under
    # value semantics (attention reads seq-produced buffers under
    # kv_seq on half the zoo).
    for bname, per_axis in topo.axis_owner_dims.items():
        writers = {n.name for n in topo.producers.get(bname, ())}
        if len(writers) < 2:
            continue
        for axis, pairs in enumerate(per_axis):
            ctx.check()
            wdims: dict[str, str] = {}
            for node, dim in pairs:
                if node.name in writers:
                    wdims.setdefault(dim, node.name)
            if len(wdims) > 1:
                rules = ""
                if plan is not None:
                    rules = "; rules map " + ", ".join(
                        f"{d!r}->{tuple(plan.rules.get(d, ()))}"
                        for d in sorted(wdims))
                ctx.issue(
                    "shard-race", bname,
                    f"axis {axis} is written under disagreeing loop dims "
                    f"{sorted(wdims)} by {sorted(wdims.values())} — "
                    f"sharded/unrolled writer instances touch "
                    f"overlapping regions{rules}")
    # Lost update: a read-modify-write node unrolled over a dim its
    # access map never indexes runs every instance against the whole
    # region — each read-modify-write clobbers the others.  (A pure
    # writer in the same position is a reduction, handled by psum.)
    for node in sched.nodes:
        for value, eff in node.args.items():
            if eff != MemoryEffect.READ_WRITE:
                continue
            ctx.check()
            am = topo.access_for(node, value)
            if am is None:
                continue
            named = {e[0] for e in am.entries if e[0] is not None}
            for dim, f in node.unroll.items():
                if f and f > 1 and dim not in named:
                    ctx.issue(
                        "rw-lost-update", node.name,
                        f"read-modify-write of {value!r} unrolled x{f} "
                        f"over dim {dim!r}, which its access map never "
                        "indexes — concurrent instances overwrite each "
                        "other's updates")


# --------------------------------------------------------------------------
# Family 3: stale-alias / multi-producer ordering
# --------------------------------------------------------------------------

def _reaches(succ: dict[str, list[str]], src: str, dst: str) -> bool:
    seen = {src}
    stack = [src]
    while stack:
        for m in succ.get(stack.pop(), ()):
            if m == dst:
                return True
            if m not in seen:
                seen.add(m)
                stack.append(m)
    return False


@register_rule("order.writers", family="ordering")
def _rule_order_writers(ctx: _Context) -> None:
    """Each shared buffer's writers are totally happens-before ordered."""
    sched, topo = ctx.sched, ctx.topo
    if sched is None or topo is None:
        return
    multi = {b: ps for b, ps in topo.producers.items() if len(ps) > 1}
    if not multi:
        return
    succ: dict[str, list[str]] = {}
    for s, d, _ in sched.happens_before_edges():
        succ.setdefault(s, []).append(d)
    for bname, prods in sorted(multi.items()):
        names = [p.name for p in prods]
        for i in range(len(names)):
            for j in range(i + 1, len(names)):
                ctx.check()
                a, b = names[i], names[j]
                if not (_reaches(succ, a, b) or _reaches(succ, b, a)):
                    ctx.issue(
                        "write-order", bname,
                        f"writers {a!r} and {b!r} are unordered by "
                        "happens-before (no dataflow or token path "
                        "either way) — the consumed region has no "
                        "single last writer")


@register_rule("order.alias", family="ordering", plan_only=True)
def _rule_order_alias(ctx: _Context) -> None:
    """add_role_alias chains stay single-hop, fresh and resolvable."""
    plan = ctx.plan
    if plan is None:
        return
    for role, source in plan.role_sources.items():
        ctx.check()
        if source in plan.role_sources:
            ctx.issue(
                "alias-chain", role,
                f"alias source {source!r} is itself an alias of "
                f"{plan.role_sources[source]!r} — apply_rule_change "
                "re-projects one hop, so chained aliases go stale on "
                "the next rule change")
        if source not in plan.buffer_specs:
            ctx.issue("alias-missing", role,
                      f"alias source {source!r} has no stored spec")
        elif plan.buffer_specs.get(role) != plan.buffer_specs[source]:
            ctx.issue(
                "alias-drift", role,
                f"alias spec {plan.buffer_specs.get(role)} no longer "
                f"mirrors source {source!r} spec "
                f"{plan.buffer_specs[source]} — stale alias")


# --------------------------------------------------------------------------
# Family 4: session-invariant lint
# --------------------------------------------------------------------------

def _same_owner_lists(a: dict, b: dict) -> bool:
    """Name-compare two {buffer: [Node, ...]} maps without materialising
    fingerprint dicts (the rewrite-session selfcheck's
    ``schedule_topology_fingerprint`` builds full name dumps — fine for
    tests, ~3x the rebuild cost here)."""
    ka = {k for k, v in a.items() if v}
    if ka != {k for k, v in b.items() if v}:
        return False
    for k in ka:
        va, vb = a[k], b[k]
        if len(va) != len(vb):
            return False
        for x, y in zip(va, vb):
            if x.name != y.name:
                return False
    return True


def _topology_matches(cached: ScheduleTopology,
                      fresh: ScheduleTopology) -> bool:
    """Semantic equality of two topologies (lazy ``_access`` and memo
    caches excluded), early-exit piecewise."""
    if cached.edges != fresh.edges:
        return False
    if cached.axis_dims != fresh.axis_dims:
        return False
    if cached.buffers_of_dim != fresh.buffers_of_dim:
        return False
    if not _same_owner_lists(cached.producers, fresh.producers):
        return False
    if not _same_owner_lists(cached.consumers, fresh.consumers):
        return False
    if cached.axis_owner_dims.keys() != fresh.axis_owner_dims.keys():
        return False
    for bname, per_axis in cached.axis_owner_dims.items():
        other = fresh.axis_owner_dims[bname]
        if len(per_axis) != len(other):
            return False
        for pa, pb in zip(per_axis, other):
            if len(pa) != len(pb):
                return False
            for (na, da), (nb, db) in zip(pa, pb):
                if da != db or na.name != nb.name:
                    return False
    return True


@register_rule("invariant.index", family="invariant")
def _rule_invariant_index(ctx: _Context) -> None:
    """Maintained topology / memos / node cache match from-scratch."""
    sched = ctx.sched
    if sched is None:
        return
    cached = sched._topology
    if cached is not None \
            and cached.signature == sched.structure_signature():
        # A cached topology whose signature mismatches is merely lazy
        # (topology() rebuilds it) — the hazard is a *matching*
        # signature over stale content: a maintenance bug every
        # downstream consumer (DSE, plan projection, this analyzer)
        # would silently trust.
        if len(sched.nodes) <= DEEP_CHECK_NODE_CAP:
            ctx.check()
            fresh = ScheduleTopology.build(sched)
            if not _topology_matches(cached, fresh):
                ctx.issue(
                    "topology-stale", sched.name,
                    "maintained ScheduleTopology no longer matches a "
                    "from-scratch rebuild despite a matching structure "
                    "signature — index maintenance bug")
        else:
            ctx.rep.stats["invariant_deep_skipped"] = len(sched.nodes)
        try:
            if cached._order_memo is not None:
                ctx.check()
                want = [n.name for n in topo_order_over(
                    sched.nodes, cached.edges, sched.name)]
                if [n.name for n in cached._order_memo] != want:
                    ctx.issue("order-stale", sched.name,
                              "memoized topo order differs from "
                              "re-derivation over the same edges")
            if cached._depth_memo is not None:
                ctx.check()
                want_d = depth_map_over(sched.nodes, cached.edges,
                                        sched.name)
                if cached._depth_memo != want_d:
                    ctx.issue("depth-stale", sched.name,
                              "memoized depth map differs from "
                              "re-derivation over the same edges")
        except ValueError:
            pass  # cyclic — deadlock.cycle owns that report
    cache = sched._node_cache
    if cache is not None and sched._node_cache_len == len(sched.nodes):
        ctx.check()
        live = {n.name: n for n in sched.nodes}
        if set(cache) != set(live) or any(
                live.get(k) is not v for k, v in cache.items()):
            ctx.issue("node-cache-stale", sched.name,
                      "name->node cache disagrees with the node list "
                      "(missed rename or in-place replacement)")


# --------------------------------------------------------------------------
# Drivers
# --------------------------------------------------------------------------

def analyze(sched: Optional[Schedule], plan: ShardingPlan | None = None,
            mesh: MeshSpec | None = None, *,
            topology: ScheduleTopology | None = None,
            rules: Sequence[str] | None = None) -> AnalyzeReport:
    """Run the registered hazard rules over ``(sched, plan, mesh)``.

    Read-only and total: a crashing rule (organic or injected via the
    ``analyze.rules`` fault site) becomes an ``analyze-internal`` issue,
    never an exception.  ``sched=None`` runs only the ``plan_only``
    rules (what :func:`analyze_plan` does); ``rules=`` selects a subset
    by registered name.

    Args:
        sched: the Structural schedule, or ``None`` for plan-only lint.
        plan: sharding plan (enables plan-aware context in shard-race
            messages and the alias rules).
        mesh: target mesh (context for rules that want axis sizes).
        topology: shared :class:`ScheduleTopology`; defaults to the
            schedule's cached one.
        rules: rule-name subset (default: all registered).
    """
    t0 = time.perf_counter()
    rep = AnalyzeReport()
    if rules is None:
        selected = list(_RULES.values())
    else:
        unknown = [r for r in rules if r not in _RULES]
        if unknown:
            raise ValueError(f"unknown analysis rule(s) {unknown}; "
                             f"registered: {sorted(_RULES)}")
        selected = [_RULES[r] for r in rules]

    topo = topology
    if sched is not None and topo is None:
        try:
            topo = sched.topology()
        except Exception as e:
            rep.issues.append(AnalysisIssue(
                "analyze-internal", "error", "topology",
                f"topology construction failed: {type(e).__name__}: {e}"))
    ctx = _Context(sched=sched, plan=plan, mesh=mesh, topo=topo, rep=rep)

    skipped = 0
    for rule in selected:
        if sched is None and not rule.plan_only:
            skipped += 1
            continue
        try:
            fault_point("analyze.rules")
            rule.fn(ctx)
            rep.rules_run.append(rule.name)
        except Exception as e:  # never take the pipeline down
            rep.issues.append(AnalysisIssue(
                "analyze-internal", "error", rule.name,
                f"rule crashed: {type(e).__name__}: {e}"))
    if skipped:
        rep.stats["rules_skipped_no_schedule"] = skipped
    if sched is not None:
        rep.stats.setdefault("nodes", len(sched.nodes))
        rep.stats.setdefault("buffers", len(sched.buffers))
    rep.elapsed_s = time.perf_counter() - t0
    return rep


def analyze_plan(plan: ShardingPlan, mesh: MeshSpec) -> AnalyzeReport:
    """Schedule-free hazard lint of a plan — the plan-cache *reuse*
    gate, complementing :func:`repro.core.verify.verify_static`.  Runs
    only the ``plan_only`` rules (today: the alias-ordering family;
    ``role_sources`` is not serialized, so disk-tier entries trivially
    pass — the gate defends the memory tier, where plans are mutated in
    place by ``apply_rule_change``).  Microsecond-cheap; same
    never-crash contract as :func:`analyze`."""
    return analyze(None, plan, mesh,
                   rules=[n for n, r in _RULES.items() if r.plan_only])
