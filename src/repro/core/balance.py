"""Data-path balancing — paper Section 6.4.2.

A dataflow with unequal-length paths stalls: a producer cannot issue the
next frame until the *longest* downstream path drains (ResNet shortcuts are
the canonical case; in LMs it is the residual stream skipping a heavy
attention/FFN/expert path, and in pipeline-parallel execution it is any
skip connection crossing stage boundaries).

Two mechanisms, chosen per buffer by a byte-budget heuristic:

1. **On-chip buffer duplication** — insert ``skew`` copy nodes along the
   short path, one per level of imbalance, each with its own duplicate
   buffer (Fig. 8(b)).  On TPU these become the extra staging slots the
   pipeline runtime carries for skip tensors.

2. **Soft FIFO in external memory** — for large tensors, mark the buffer as
   an ``external`` soft FIFO with ``stages = skew + 1`` and *rotate access
   indices* instead of shifting data (Fig. 8(c)); explicit ``TokenEdge``s
   keep producer/consumer ordering elastic (no FSM — on TPU the rotation is
   a circular microbatch index and the tokens are data dependencies /
   optimization barriers for host-offload staging).

The skew analysis reads the cached
:class:`~repro.core.ir.ScheduleTopology` edges, and every mutation (copy
nodes, duplicate buffers, consumer re-pointing, soft-FIFO attributes,
token edges) flows through one transactional
:class:`~repro.core.rewrite.ScheduleRewriteSession`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .faults import fault_point
from .ir import Buffer, MemoryEffect, Node, Schedule, fresh_name
from .rewrite import ScheduleRewriteSession, make_copy_op


@dataclass
class BalanceStats:
    copy_nodes: int = 0
    soft_fifos: int = 0
    max_skew: int = 0
    log: list[str] = field(default_factory=list)


def path_skew(sched: Schedule) -> dict[tuple[str, str, str], int]:
    """Per (producer, consumer, buffer) edge: depth(consumer) - depth
    (producer) - 1, i.e. how many pipeline levels the edge skips.  Both
    the edge list and the depths come from the cached topology."""
    depth = sched.depth_of()
    return {(s, d, b): depth[d] - depth[s] - 1 for s, d, b in sched.edges()}


def balance_paths(sched: Schedule, onchip_budget_bytes: int = 1 << 27,
                  selfcheck: bool = False) -> BalanceStats:
    stats = BalanceStats()
    with ScheduleRewriteSession(sched, selfcheck=selfcheck) as rs:
        # The skew map is computed once against the pre-balance topology
        # (inserting a copy node shifts downstream depths; re-deriving
        # mid-pass would over-balance), straight off the session's edges.
        depth = rs.depth_of()
        skews = {(s, d, b): depth[d] - depth[s] - 1
                 for s, d, b in rs.edges()}
        for (src, dst, bname), skew in sorted(skews.items()):
            if skew <= 0:
                continue
            stats.max_skew = max(stats.max_skew, skew)
            fault_point("balance.edge")
            buf = sched.buffers[bname]
            dup_bytes = buf.bytes * skew
            if dup_bytes <= onchip_budget_bytes:
                _duplicate_chain(rs, src, dst, bname, skew, stats)
            else:
                _soft_fifo(rs, src, dst, bname, skew, stats)
    return stats


def _duplicate_chain(rs: ScheduleRewriteSession, src: str, dst: str,
                     bname: str, skew: int, stats: BalanceStats) -> None:
    """Fig. 8(b): chain of copy nodes along the short path."""
    sched = rs.sched
    base = sched.buffers[bname]
    cur = bname
    for level in range(skew):
        dup = fresh_name(f"{bname}_skid")
        rs.add_buffer(Buffer(
            name=dup, shape=base.shape, dtype=base.dtype, dims=base.dims,
            stages=2, placement=base.placement))
        copy_node = Node(
            name=fresh_name(f"balance_copy_{bname}"),
            args={cur: MemoryEffect.READ, dup: MemoryEffect.WRITE},
            body=[make_copy_op(base, cur, dup)])
        # Place right before the consumer so topo depth lands mid-path.
        rs.add_node(copy_node, index=rs.position(sched.node(dst)))
        cur = dup
        stats.copy_nodes += 1
    consumer = sched.node(dst)
    # Consumer now reads the deepest duplicate.
    rs.rename_arg(consumer, bname, cur)
    stats.log.append(f"dup-chain {bname} x{skew} for {src}->{dst}")


def _soft_fifo(rs: ScheduleRewriteSession, src: str, dst: str,
               bname: str, skew: int, stats: BalanceStats) -> None:
    """Fig. 8(c): rotate access into an external soft FIFO, ordering kept
    by explicit tokens (elastic node execution)."""
    # One buffer can carry several skewed edges (a fan-out feeding
    # consumers at different depths); the FIFO must be as deep as the
    # *deepest* edge demands.  The edges iterate in name order, not skew
    # order, so a later smaller-skew edge must not shrink stages below
    # an earlier edge's skew+1 requirement.
    cur = rs.sched.buffers[bname].stages
    rs.set_buffer_attrs(bname, stages=max(cur, skew + 1),
                        placement="external")
    rs.add_token(src, dst)
    stats.soft_fifos += 1
    stats.log.append(
        f"soft-fifo {bname} stages={rs.sched.buffers[bname].stages} "
        f"token {src}->{dst}")
