"""Dataflow pipelining over the ``pod`` axis — the TPU realisation of
HIDA's coarse-grained task pipeline.

HIDA's Structural schedule executes nodes as a pipeline whose initiation
interval is the critical node (Section 2 / 6.4).  Across pods, DCN
latency makes pure DP expensive for the gradient sync of very large
models; instead the layer stack is split into ``n_stages`` contiguous
stages (balanced by HIDA node intensities), microbatches stream through
a GPipe schedule implemented with ``shard_map`` + ``collective_permute``
ring transfers, and the ping-pong ``buffer`` semantics of HIDA-IR appear
as the rotating staging slots between stages.  Residual/skip tensors that
cross stage boundaries get ``stages = skew+1`` slots — exactly the
data-path balancing transform (Fig. 8) applied at pipeline granularity.

The implementation is mesh-size agnostic (tested with 4-8 host devices);
on the production mesh the stage axis is ``pod``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .ir import Schedule


def compute_stages(sched: Schedule, n_stages: int) -> dict[str, int]:
    """Pure stage analysis: balance HIDA nodes across pipeline stages by
    intensity (the critical-node II is what the paper's fusion pass
    already minimised).  Returns ``node name -> stage`` without touching
    the schedule — apply with :func:`apply_stages`."""
    order = sched.topo_order()
    total = sum(n.intensity() for n in order) or 1
    target = total / n_stages
    acc, stage = 0.0, 0
    out: dict[str, int] = {}
    for n in order:
        out[n.name] = stage
        acc += n.intensity()
        if acc >= target * (stage + 1) and stage < n_stages - 1:
            stage += 1
    return out


def apply_stages(sched: Schedule, stages: dict[str, int]) -> None:
    """Write a stage mapping onto the schedule through one transactional
    :class:`~repro.core.rewrite.ScheduleRewriteSession` — either every
    node's ``stage`` is updated or (on error) none is, so callers can
    never observe a half-applied mapping."""
    from .rewrite import ScheduleRewriteSession
    with ScheduleRewriteSession(sched) as rs:
        for name, stage in stages.items():
            rs.set_stage(sched.node(name), stage)


def assign_stages(sched: Schedule, n_stages: int) -> dict[str, int]:
    """:func:`compute_stages` + :func:`apply_stages` in one step.

    Unlike the old implementation (which mutated ``n.stage`` node by node
    *while* computing the mapping, so an exception mid-walk left the
    schedule half-staged), the mutation is an explicit all-or-nothing
    rewrite applied only after the analysis completes."""
    stages = compute_stages(sched, n_stages)
    apply_stages(sched, stages)
    return stages


@dataclass
class PipelineConfig:
    n_stages: int
    n_microbatches: int
    stage_axis: str = "pod"


def gpipe(stage_fn: Callable, cfg: PipelineConfig, mesh: Mesh,
          in_spec: P, out_spec: P):
    """Build a GPipe-style pipelined forward: ``stage_fn(params, x, stage)``
    is one stage's computation; microbatches rotate through stages via
    ``collective_permute`` (the HIDA ``stream`` between schedule nodes).

    Returns ``run(stacked_stage_params, microbatches)`` where
    ``microbatches`` has leading dim n_microbatches and stage params have
    leading dim n_stages (sharded over the stage axis).
    """
    S, M = cfg.n_stages, cfg.n_microbatches
    axis = cfg.stage_axis
    perm = [(i, (i + 1) % S) for i in range(S)]

    def per_stage(params, mb):
        # params: this stage's slice (leading dim 1); mb: (M, ...) replicated
        params = jax.tree.map(lambda p: p[0], params)
        stage_id = jax.lax.axis_index(axis)
        mb_shape = jax.tree.map(lambda x: x[0], mb)
        state = jax.tree.map(jnp.zeros_like, mb_shape)   # staging slot
        outs = jax.tree.map(
            lambda x: jnp.zeros((M,) + x.shape, x.dtype), mb_shape)

        def tick(t, carry):
            state, outs = carry
            # Stage 0 injects microbatch t; others consume the ring slot.
            inject = jax.tree.map(
                lambda m, s: jnp.where(t < M, m[jnp.minimum(t, M - 1)], s),
                mb, state)
            x = jax.tree.map(
                lambda inj, s: jnp.where(stage_id == 0, inj, s),
                inject, state)
            y = stage_fn(params, x, stage_id)
            # Emit: the last stage writes its completed microbatch.
            mb_idx = t - stage_id
            valid = (mb_idx >= 0) & (mb_idx < M)
            outs = jax.tree.map(
                lambda o, yi: jnp.where(
                    valid & (stage_id == S - 1),
                    o.at[jnp.clip(mb_idx, 0, M - 1)].set(yi), o),
                outs, y)
            # Rotate: every stage forwards its activation to the next —
            # the ping-pong buffer hand-off.
            state = jax.tree.map(
                lambda yi: jax.lax.ppermute(yi, axis, perm), y)
            return state, outs

        _, outs = jax.lax.fori_loop(0, M + S - 1, tick,
                                    (state, outs))
        # Only the last stage holds real outputs; share them.
        outs = jax.tree.map(
            lambda o: jax.lax.psum(
                jnp.where(stage_id == S - 1, o, jnp.zeros_like(o)), axis),
            outs)
        return outs

    def run(stage_params, microbatches):
        f = shard_map(per_stage, mesh=mesh,
                      in_specs=(P(axis), P()),
                      out_specs=P(),
                      check_rep=False)
        return f(stage_params, microbatches)

    return run
