"""Incremental QoR estimation engine: O(Δ) re-scoring for the DSE.

``estimator.estimate()`` is the *batch reference*: one call walks every
node's ops, every shared-buffer edge and every weight buffer, which makes
it O(nodes × ops) per call.  The IA+CA parallelizer (Alg. 4) scores
thousands of single-node proposals per schedule, so the batch path makes
``optimize()`` super-linear in design size — 20s+ on deepseek-v3-671b
(43 nodes, ~4.2k proposals), the exact "design grows → DSE collapses"
failure mode HIDA's QoR-driven transform ordering exists to avoid.

``IncrementalEstimator`` splits the roofline model along its dependence
structure:

* **Static (built once per schedule)** — everything that does not depend
  on ``unroll`` / ``axis_map``: per-node FLOPs and repeat factors, the
  per-buffer access pairs behind ``buffer_shard_factor``, per-op
  reduction-dim sets and output-shard descriptors, the shared-buffer edge
  topology, and the weight→first-consumer sync map.  The edge/owner/access
  structure comes from the schedule's cached
  :class:`~repro.core.ir.ScheduleTopology` — the same substrate the plan
  layer (``build_plan`` / ``apply_rule_change``) projects through, so the
  optimizer and the emitted plan can never walk divergent topologies.
* **Cached state (per node / per edge)** — the compute / memory /
  reduction terms of each node, each edge's reshard contribution, each
  node's weight-sync bytes, and the resulting per-node latency.

Re-scoring a proposal for one node then touches only that node's local
terms plus its incident edges — O(deg) instead of O(nodes × ops) — via a
``propose() / commit() / rollback()`` API.  Aggregates (``total_s``,
``hbm_bytes_per_device``) are maintained as **segment trees** over the
per-node caches, reducing in the fixed perfect-binary-tree order of
:func:`~repro.core.estimator.tree_sum` — the batch path sums through the
same shape, so a leaf-to-root point update lands on bit-exactly the
total a from-scratch batch walk would produce.  That makes aggregate
reads O(1) and ``score()`` O(deg · log n) instead of O(n) per proposal
(the former sequential re-sum was the DSE's hidden quadratic term past
~1k nodes), while keeping the engine **bit-identical** to
``estimate()``, not merely approximately equal (per-edge and per-sync
terms are integers, so their delta maintenance is exact; float terms
are only ever re-reduced through the shared tree shape, never
delta-adjusted).

Three access patterns sit on top of the cached state:

* :meth:`~IncrementalEstimator.propose` / ``commit`` / ``rollback`` —
  the transactional single-node mutation path (at most one outstanding).
* :meth:`~IncrementalEstimator.score` — a **read-only** evaluation of a
  single-node proposal: same arithmetic (and bit-identical results) as
  propose → read → rollback, but with no mutation and no undo log.  Being
  pure, concurrent ``score()`` calls are safe, which is what the
  parallelizer's graph-colored sweeps rely on.
* :meth:`~IncrementalEstimator.snapshot` / ``restore`` — whole-schedule
  assignment states for the beam search; ``restore`` re-applies only the
  nodes that differ, so switching between sibling beam states costs
  O(diff × deg), not O(schedule).

Equivalence is enforced by ``tests/test_incremental.py`` across every
model config and the PolyBench graphs, including after arbitrary
propose/rollback sequences.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

from .estimator import (FIXED_NODE_OVERHEAD_S, HBM_BW, ICI_BW, PEAK_FLOPS,
                        MeshSpec, NodeCost, ScheduleCost)
from .ir import Node, Schedule

#: sentinel for "no access map" (shard factor 1) in output-shard descriptors
_NO_ACCESS = None

#: one whole-schedule assignment: node name -> (axis_map, unroll)
Snapshot = dict[str, tuple[dict[str, tuple[str, ...]], dict[str, int]]]


class ProposalScore(NamedTuple):
    """Read-only QoR of a single-node proposal (see
    :meth:`IncrementalEstimator.score`).  ``total_s`` and ``hbm_bytes``
    are bit-identical to what ``propose()`` + the ``total_s`` /
    ``hbm_bytes_per_device`` properties would report; ``node_compute_s``
    and ``node_parallel_factor`` are the per-node terms the CA-off
    ablation ranks by."""

    total_s: float
    hbm_bytes: int
    node_compute_s: float
    node_parallel_factor: int


def _shard_factor(pairs: tuple[tuple[str, int], ...],
                  unroll: dict[str, int]) -> int:
    """``estimator.buffer_shard_factor`` over precomputed (dim, axis_size)
    pairs (entries whose loop dim is None are dropped at build time)."""
    f = 1
    for dim, size in pairs:
        if dim in unroll:
            f *= min(unroll[dim], size)
    return max(f, 1)


def _out_shard(dims: tuple[str, ...] | None, unroll: dict[str, int]) -> int:
    """``estimator._op_out_shard`` over a precomputed non-None dim tuple."""
    if dims is _NO_ACCESS:
        return 1
    f = 1
    for d in dims:
        f *= unroll.get(d, 1)
    return max(f, 1)


class _SumTree:
    """Segment tree over floats whose root is **bit-identical** to
    :func:`~repro.core.estimator.tree_sum` of the leaf values.

    Leaves are padded with ``0.0`` to the next power of two and every
    internal node is the sum of its two children — exactly the reduction
    shape ``tree_sum`` walks — so a point update (:meth:`set`) replays
    only the log-depth path of additions from that leaf to the root and
    lands on the same bits a from-scratch re-reduction would.
    :meth:`root_with` evaluates the root under a sparse leaf override
    **without mutating anything** (copy-on-write level walk), which is
    what makes ``score()`` O(deg · log n).
    """

    __slots__ = ("size", "tree")

    def __init__(self, values: list[float]):
        size = 1
        while size < max(len(values), 1):
            size *= 2
        self.size = size
        t = [0.0] * (2 * size)
        t[size:size + len(values)] = [float(v) for v in values]
        for i in range(size - 1, 0, -1):
            t[i] = t[2 * i] + t[2 * i + 1]
        self.tree = t

    def set(self, i: int, v: float) -> None:
        j = self.size + i
        t = self.tree
        t[j] = v
        j >>= 1
        while j:
            t[j] = t[2 * j] + t[2 * j + 1]
            j >>= 1

    @property
    def root(self) -> float:
        return self.tree[1]

    def root_with(self, overrides: dict[int, float]) -> float:
        """Root value if leaves ``i`` held ``overrides[i]`` — pure read."""
        if not overrides:
            return self.tree[1]
        t = self.tree
        level = {self.size + i: float(v) for i, v in overrides.items()}
        while 1 not in level:
            nxt: dict[int, float] = {}
            for j in level:
                p = j >> 1
                if p in nxt:
                    continue
                left = p << 1
                right = left | 1
                nxt[p] = (level.get(left, t[left])
                          + level.get(right, t[right]))
            level = nxt
        return level[1]


@dataclass
class _NodeStatic:
    """Unroll-independent structure of one node."""

    flops: float
    repeat: float
    #: (buffer bytes, ((dim, axis_size), ...)) per buffer arg, in args order
    mem_terms: list[tuple[int, tuple[tuple[str, int], ...]]]
    #: (reduction dims, ((value bytes, out dims), ...), op repeat) per body op
    red_ops: list[tuple[tuple[str, ...],
                        tuple[tuple[int, tuple[str, ...] | None], ...],
                        float]]
    #: (weight bytes, shard pairs, weight dims) per weight buffer whose
    #: first consumer is this node
    sync_terms: list[tuple[int, tuple[tuple[str, int], ...],
                           frozenset[str]]] = field(default_factory=list)


@dataclass
class _EdgeStatic:
    """One producer→consumer shared-buffer edge."""

    src: int
    dst: int
    #: (producer dim, consumer dim) per buffer axis (None when unmapped)
    axes: tuple[tuple[str | None, str | None], ...]
    buf_bytes: int
    #: shard pairs of (buffer, producer) for the payload size
    src_pairs: tuple[tuple[str, int], ...]


class IncrementalEstimator:
    """Stateful roofline scorer over a Structural schedule.

    The estimator owns the schedule's parallelization state: mutations go
    through :meth:`propose` / :meth:`commit` / :meth:`rollback` (or the
    one-shot :meth:`apply`), which write ``node.unroll`` / ``node.axis_map``
    on the underlying :class:`Node` objects and incrementally refresh the
    cached cost terms.  At most one proposal may be outstanding, and a
    rollback restores every cached term bit-identically (asserted by
    ``tests/test_beam.py``).

    The DSE scan path uses :meth:`score` instead — the same O(deg)
    arithmetic with zero mutation — and the beam search moves between
    whole-schedule states with :meth:`snapshot` / :meth:`restore`.
    External bulk mutation of node state requires a :meth:`refresh`.
    """

    def __init__(self, sched: Schedule, mesh: MeshSpec,
                 training: bool = True):
        self.sched = sched
        self.mesh = mesh
        self.training = training
        self._nodes: list[Node] = list(sched.nodes)
        self._idx = {n.name: i for i, n in enumerate(self._nodes)}
        self._build_static()
        n = len(self._nodes)
        self._comp = [0.0] * n        # compute_s
        self._mem = [0.0] * n         # memory_s
        self._nbytes = [0.0] * n      # HBM bytes (pre-division by BW)
        self._red = [0.0] * n         # intra-node reduction bytes
        self._sync = [0] * n          # weight-sync bytes (int)
        self._reshard = [0] * n       # Σ incident in-edge contributions (int)
        self._contrib = [0] * len(self._edges)
        self._lat = [0.0] * n         # latency_s
        self._undo: list | None = None
        self.refresh()

    # -- static structure ---------------------------------------------------

    def _build_static(self) -> None:
        sched = self.sched
        topo = sched.topology()
        statics: list[_NodeStatic] = []
        for node in self._nodes:
            mem_terms = []
            for v in node.args:
                buf = sched.buffers.get(v)
                if buf is None:
                    continue
                am = topo.access_for(node, v)
                pairs = () if am is None else tuple(
                    (dim, buf.shape[axis])
                    for axis, (dim, _stride) in enumerate(am.entries)
                    if dim is not None)
                mem_terms.append((buf.bytes, pairs))
            red_ops = []
            for op in node.body:
                out_dims: set[str] = set()
                for v in op.outs:
                    am = op.access.get(v)
                    if am:
                        out_dims.update(d for d, _ in am.entries if d)
                in_dims: set[str] = set()
                for v in op.ins:
                    am = op.access.get(v)
                    if am:
                        in_dims.update(d for d, _ in am.entries if d)
                red = (in_dims - out_dims) | set(op.attrs.get("reduce", ()))
                if not red:
                    continue
                outs = tuple(
                    (sched.value_bytes.get(v, 0),
                     _NO_ACCESS if op.access.get(v) is None else tuple(
                         d for d, _ in op.access[v].entries
                         if d is not None))
                    for v in op.outs)
                red_ops.append((tuple(red), outs, op.repeat))
            statics.append(_NodeStatic(
                flops=node.intensity(), repeat=node.repeat,
                mem_terms=mem_terms, red_ops=red_ops))
        self._static = statics

        edges: list[_EdgeStatic] = []
        for src, dst, bname in topo.edges:
            p, c = sched.node(src), sched.node(dst)
            buf = sched.buffers[bname]
            pam, cam = topo.access_for(p, bname), topo.access_for(c, bname)
            if pam is None or cam is None:
                continue
            axes = tuple(
                (pam.entries[axis][0] or None, cam.entries[axis][0] or None)
                for axis in range(len(buf.shape)))
            src_pairs = tuple(
                (dim, buf.shape[axis])
                for axis, (dim, _stride) in enumerate(pam.entries)
                if dim is not None)
            edges.append(_EdgeStatic(
                src=self._idx[src], dst=self._idx[dst], axes=axes,
                buf_bytes=buf.bytes, src_pairs=src_pairs))
        self._edges = edges
        self._edges_of: list[list[int]] = [[] for _ in self._nodes]
        for e, edge in enumerate(edges):
            self._edges_of[edge.src].append(e)
            if edge.dst != edge.src:
                self._edges_of[edge.dst].append(e)

        if self.training:
            for bname, buf in sched.buffers.items():
                if not buf.is_weight:
                    continue
                consumers = topo.consumers.get(bname, ())
                if not consumers:
                    continue
                n = consumers[0]
                am = topo.access_for(n, bname)
                pairs = () if am is None else tuple(
                    (dim, buf.shape[axis])
                    for axis, (dim, _stride) in enumerate(am.entries)
                    if dim is not None)
                w_dims = frozenset(
                    d for d, _ in am.entries if d) if am else frozenset()
                self._static[self._idx[n.name]].sync_terms.append(
                    (buf.bytes, pairs, w_dims))

    # -- per-node term recomputation ----------------------------------------

    def _local_terms(self, i: int, unroll: dict[str, int],
                     axis_map: dict[str, tuple[str, ...]]
                     ) -> tuple[float, float, float, float, int]:
        """Pure form of the unroll/axis-dependent local terms of node ``i``
        (same arithmetic, in the same order, as the batch estimator):
        returns ``(compute_s, memory_s, hbm_bytes, reduction_bytes,
        sync_bytes)`` without touching the caches."""
        st = self._static[i]
        pf = 1
        for v in unroll.values():
            pf *= v
        pf = max(pf, 1)
        comp = st.flops / pf / PEAK_FLOPS

        total = 0.0
        for buf_bytes, pairs in st.mem_terms:
            total += buf_bytes / _shard_factor(pairs, unroll)
        nbytes = total * st.repeat
        mem = nbytes / HBM_BW

        red = 0.0
        for red_dims, outs, op_repeat in st.red_ops:
            k = 1
            for d in red_dims:
                k *= unroll.get(d, 1)
            if k <= 1:
                continue
            out_bytes = sum(vbytes / _out_shard(dims, unroll)
                            for vbytes, dims in outs)
            red += 2.0 * out_bytes * (k - 1) / k * op_repeat

        sync = 0
        for buf_bytes, pairs, w_dims in st.sync_terms:
            shard = buf_bytes // max(_shard_factor(pairs, unroll), 1)
            w_axes = {a for d in w_dims for a in axis_map.get(d, ())}
            sync_ways = 1
            for a, s in self.mesh.axes:
                if a not in w_axes:
                    sync_ways *= s
            if sync_ways > 1:
                sync += int(2 * shard * (sync_ways - 1) / sync_ways
                            * st.repeat)
        return comp, mem, nbytes, red, sync

    def _node_local(self, i: int) -> None:
        """Recompute the cached local terms of node ``i`` from its current
        ``unroll`` / ``axis_map``."""
        node = self._nodes[i]
        (self._comp[i], self._mem[i], self._nbytes[i], self._red[i],
         self._sync[i]) = self._local_terms(i, node.unroll, node.axis_map)

    def _edge_contrib(self, edge: _EdgeStatic, ov_i: int = -1,
                      ov_axis_map: dict[str, tuple[str, ...]] | None = None,
                      ov_unroll: dict[str, int] | None = None) -> int:
        """Reshard bytes of one edge.  When ``ov_i`` matches an endpoint,
        that endpoint's state is read from the ``ov_*`` overrides instead
        of the node object (the read-only :meth:`score` path)."""
        p = self._nodes[edge.src]
        c = self._nodes[edge.dst]
        p_axis_map = ov_axis_map if edge.src == ov_i else p.axis_map
        c_axis_map = ov_axis_map if edge.dst == ov_i else c.axis_map
        mismatch = False
        for pdim, cdim in edge.axes:
            paxes = tuple(p_axis_map.get(pdim, ())) if pdim else ()
            caxes = tuple(c_axis_map.get(cdim, ())) if cdim else ()
            if paxes != caxes:
                mismatch = True
        if not mismatch:
            return 0
        p_unroll = ov_unroll if edge.src == ov_i else p.unroll
        return edge.buf_bytes // max(
            _shard_factor(edge.src_pairs, p_unroll), 1)

    def _latency(self, i: int) -> float:
        coll = (self._reshard[i] + self._sync[i] + self._red[i]) / ICI_BW
        return max(self._comp[i], self._mem[i], coll) + FIXED_NODE_OVERHEAD_S

    # -- state maintenance ---------------------------------------------------

    def refresh(self) -> None:
        """Full resync from the nodes' current ``unroll`` / ``axis_map``
        (used at construction and after bulk external mutation)."""
        self._undo = None
        for i in range(len(self._nodes)):
            self._node_local(i)
        for i in range(len(self._nodes)):
            self._reshard[i] = 0
        for e, edge in enumerate(self._edges):
            v = self._edge_contrib(edge)
            self._contrib[e] = v
            self._reshard[edge.dst] += v
        for i in range(len(self._nodes)):
            self._lat[i] = self._latency(i)
        # Rebuild the aggregate trees wholesale; point updates keep them
        # in sync from here on.
        self._lat_tree = _SumTree(self._lat)
        self._nbytes_tree = _SumTree(self._nbytes)

    def _update_node(self, i: int, record: list | None) -> None:
        """Refresh node ``i``'s local terms and incident edges; ``record``
        collects (slot-restorer) undo entries when proposing."""
        if record is not None:
            record.append(("local", i, self._comp[i], self._mem[i],
                           self._nbytes[i], self._red[i], self._sync[i]))
        self._node_local(i)
        self._nbytes_tree.set(i, self._nbytes[i])
        touched = {i}
        for e in self._edges_of[i]:
            edge = self._edges[e]
            new = self._edge_contrib(edge)
            old = self._contrib[e]
            if new != old:
                if record is not None:
                    record.append(("edge", e, old))
                self._contrib[e] = new
                self._reshard[edge.dst] += new - old
                touched.add(edge.dst)
        for j in touched:
            if record is not None:
                record.append(("lat", j, self._lat[j]))
            self._lat[j] = self._latency(j)
            self._lat_tree.set(j, self._lat[j])

    # -- mutation API --------------------------------------------------------

    def propose(self, name: str, axis_map: dict[str, tuple[str, ...]],
                unroll: dict[str, int] | None = None) -> "IncrementalEstimator":
        """Tentatively assign ``axis_map`` (and its ``unroll`` factors) to
        node ``name``; must be resolved by :meth:`commit` or
        :meth:`rollback` before the next proposal."""
        if self._undo is not None:
            raise RuntimeError("a proposal is already outstanding")
        i = self._idx[name]
        node = self._nodes[i]
        if unroll is None:
            unroll = {
                d: _axes_product(self.mesh, axes)
                for d, axes in axis_map.items()}
        record: list = [("node", i, node.unroll, node.axis_map)]
        node.axis_map = dict(axis_map)
        node.unroll = dict(unroll)
        self._update_node(i, record)
        self._undo = record
        return self

    def commit(self) -> None:
        self._undo = None

    def rollback(self) -> None:
        if self._undo is None:
            raise RuntimeError("no outstanding proposal")
        for entry in reversed(self._undo):
            kind = entry[0]
            if kind == "node":
                _, i, unroll, axis_map = entry
                self._nodes[i].unroll = unroll
                self._nodes[i].axis_map = axis_map
            elif kind == "local":
                _, i, comp, mem, nbytes, red, sync = entry
                self._comp[i] = comp
                self._mem[i] = mem
                self._nbytes[i] = nbytes
                self._nbytes_tree.set(i, nbytes)
                self._red[i] = red
                self._sync[i] = sync
            elif kind == "edge":
                _, e, old = entry
                new = self._contrib[e]
                self._contrib[e] = old
                self._reshard[self._edges[e].dst] += old - new
            else:  # "lat"
                _, i, lat = entry
                self._lat[i] = lat
                self._lat_tree.set(i, lat)
        self._undo = None

    def apply(self, name: str, axis_map: dict[str, tuple[str, ...]],
              unroll: dict[str, int] | None = None) -> None:
        """``propose`` + ``commit`` in one step."""
        self.propose(name, axis_map, unroll)
        self.commit()

    # -- read-only scoring ---------------------------------------------------

    def score(self, name: str, axis_map: dict[str, tuple[str, ...]],
              unroll: dict[str, int] | None = None) -> ProposalScore:
        """Evaluate a single-node proposal **without mutating anything**.

        Bit-identical to ``propose(name, ...)`` followed by reading
        ``total_s`` / ``hbm_bytes_per_device`` and rolling back — the same
        term functions run in the same order — but the caches, the node
        objects and the undo log are untouched, so:

        * it is legal while a proposal is outstanding, and
        * concurrent ``score()`` calls from several threads are safe
          (pure reads of the shared cached state), which is what the
          parallelizer's graph-colored sweeps exploit.
        """
        i = self._idx[name]
        if unroll is None:
            unroll = {
                d: _axes_product(self.mesh, axes)
                for d, axes in axis_map.items()}
        comp, mem, nbytes, red, sync = self._local_terms(i, unroll, axis_map)

        # Incident-edge reshard deltas, accumulated per destination node.
        resh_ov: dict[int, int] = {}
        for e in self._edges_of[i]:
            edge = self._edges[e]
            new = self._edge_contrib(edge, i, axis_map, unroll)
            if new != self._contrib[e]:
                dst = edge.dst
                resh_ov[dst] = (resh_ov.get(dst, self._reshard[dst])
                                + new - self._contrib[e])

        # Latencies of the touched nodes, everything else from the cache.
        lat_ov: dict[int, float] = {}
        for j in {i} | set(resh_ov):
            if j == i:
                c, m, r, s = comp, mem, red, sync
            else:
                c, m, r, s = (self._comp[j], self._mem[j], self._red[j],
                              self._sync[j])
            coll = (resh_ov.get(j, self._reshard[j]) + s + r) / ICI_BW
            lat_ov[j] = max(c, m, coll) + FIXED_NODE_OVERHEAD_S

        # O(deg · log n): evaluate the aggregate trees under the sparse
        # leaf overrides instead of re-summing every node.  The override
        # key sets equal the leaves propose() would rewrite, so the
        # results stay bit-identical to propose → read → rollback.
        total = self._lat_tree.root_with(lat_ov)
        hbm = self._nbytes_tree.root_with({i: nbytes})
        pf = 1
        for v in unroll.values():
            pf *= v
        return ProposalScore(total, int(hbm), comp, max(pf, 1))

    # -- whole-schedule states (beam search) ---------------------------------

    def snapshot(self) -> Snapshot:
        """Copy the current whole-schedule assignment (a beam state)."""
        return {n.name: (dict(n.axis_map), dict(n.unroll))
                for n in self._nodes}

    def restore(self, snap: Snapshot) -> int:
        """Re-apply ``snap``, touching only the nodes whose assignment
        differs from the current one (O(diff × deg)).  Returns the number
        of nodes changed."""
        changed = 0
        for n in self._nodes:
            axis_map, unroll = snap[n.name]
            if n.axis_map != axis_map or n.unroll != unroll:
                self.apply(n.name, dict(axis_map), dict(unroll))
                changed += 1
        return changed

    def region_view(self, names) -> "RegionView":
        """A :class:`RegionView` scoped to ``names`` — the hierarchical
        DSE's window onto this estimator for one dispatch region."""
        return RegionView(self, names)

    # -- queries -------------------------------------------------------------

    @property
    def total_s(self) -> float:
        return self._lat_tree.root

    @property
    def critical_s(self) -> float:
        return max(self._lat, default=0.0)

    @property
    def hbm_bytes_per_device(self) -> int:
        return int(self._nbytes_tree.root)

    def node_compute_s(self, name: str) -> float:
        return self._comp[self._idx[name]]

    def node_parallel_factor(self, name: str) -> int:
        node = self._nodes[self._idx[name]]
        f = 1
        for v in node.unroll.values():
            f *= v
        return max(f, 1)

    def node_latency_s(self, name: str) -> float:
        """Cached roofline latency of one node under the current state."""
        return self._lat[self._idx[name]]

    def mismatched_nodes(self) -> set[str]:
        """Names of the endpoints of every edge currently paying a reshard
        — the natural origins for the beam search's joint moves."""
        out: set[str] = set()
        for e, edge in enumerate(self._edges):
            if self._contrib[e]:
                out.add(self._nodes[edge.src].name)
                out.add(self._nodes[edge.dst].name)
        return out

    def schedule_cost(self) -> ScheduleCost:
        """Materialize the full :class:`ScheduleCost` (bit-identical to
        ``estimate(sched, mesh, training)`` on the current state)."""
        cost = ScheduleCost()
        for i, node in enumerate(self._nodes):
            coll = self._reshard[i] + self._sync[i] + self._red[i]
            cost.nodes[node.name] = NodeCost(
                compute_s=self._comp[i],
                memory_s=self._mem[i],
                collective_s=coll / ICI_BW,
            )
        cost.reshard_bytes = sum(self._contrib)
        cost.sync_bytes = sum(self._sync)
        cost.hbm_bytes_per_device = self.hbm_bytes_per_device
        return cost


class RegionView:
    """Region-scoped window onto a shared :class:`IncrementalEstimator`.

    The hierarchical DSE solves each dispatch region on the *whole*
    estimator (so totals stay bit-identical to the batch reference) but
    snapshots, restores and rolls up only its own node subset — the
    complement is frozen by protocol while a region is being searched.
    All rollups are O(region) reads of the estimator's cached terms.
    """

    def __init__(self, est: IncrementalEstimator, names):
        self.est = est
        # Schedule order, so float re-summation matches the batch walk
        # restricted to the region.
        self._ids = sorted(est._idx[nm] for nm in names)
        self.names = tuple(est._nodes[i].name for i in self._ids)
        inside = set(self._ids)
        #: edge indices with exactly one endpoint inside the region —
        #: the only reshard terms the outer composition level re-scores.
        self._boundary_edges = tuple(
            e for e, edge in enumerate(est._edges)
            if (edge.src in inside) != (edge.dst in inside))

    def snapshot(self) -> Snapshot:
        """Region-restricted assignment fragment (keys ⊆ region names)."""
        return {self.est._nodes[i].name:
                (dict(self.est._nodes[i].axis_map),
                 dict(self.est._nodes[i].unroll))
                for i in self._ids}

    def restore(self, frag: Snapshot) -> int:
        """Re-apply a region fragment, touching only differing nodes
        (O(diff × deg)); nodes outside the region are never written."""
        changed = 0
        for i in self._ids:
            n = self.est._nodes[i]
            axis_map, unroll = frag[n.name]
            if n.axis_map != axis_map or n.unroll != unroll:
                self.est.apply(n.name, dict(axis_map), dict(unroll))
                changed += 1
        return changed

    @property
    def latency_s(self) -> float:
        """Sum of the region nodes' cached roofline latencies."""
        return sum(self.est._lat[i] for i in self._ids)

    @property
    def hbm_bytes(self) -> int:
        """Region HBM footprint (per device), from the cached terms."""
        hbm = 0.0
        for i in self._ids:
            hbm += self.est._nbytes[i]
        return int(hbm)

    @property
    def boundary_reshard_bytes(self) -> int:
        """Reshard bytes currently paid on the region's border edges."""
        return sum(self.est._contrib[e] for e in self._boundary_edges)


def _axes_product(mesh: MeshSpec, axes: tuple[str, ...]) -> int:
    f = 1
    for a in axes:
        f *= mesh.size(a)
    return f
