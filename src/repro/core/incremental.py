"""Incremental QoR estimation engine: O(Δ) re-scoring for the DSE.

``estimator.estimate()`` is the *batch reference*: one call walks every
node's ops, every shared-buffer edge and every weight buffer, which makes
it O(nodes × ops) per call.  The IA+CA parallelizer (Alg. 4) scores
thousands of single-node proposals per schedule, so the batch path makes
``optimize()`` super-linear in design size — 20s+ on deepseek-v3-671b
(43 nodes, ~4.2k proposals), the exact "design grows → DSE collapses"
failure mode HIDA's QoR-driven transform ordering exists to avoid.

``IncrementalEstimator`` splits the roofline model along its dependence
structure:

* **Static (built once per schedule)** — everything that does not depend
  on ``unroll`` / ``axis_map``: per-node FLOPs and repeat factors, the
  per-buffer access pairs behind ``buffer_shard_factor``, per-op
  reduction-dim sets and output-shard descriptors, the shared-buffer edge
  topology, and the weight→first-consumer sync map.
* **Cached state (per node / per edge)** — the compute / memory /
  reduction terms of each node, each edge's reshard contribution, each
  node's weight-sync bytes, and the resulting per-node latency.

Re-scoring a proposal for one node then touches only that node's local
terms plus its incident edges — O(deg) instead of O(nodes × ops) — via a
``propose() / commit() / rollback()`` API.  Aggregates (``total_s``,
``hbm_bytes_per_device``) are re-summed over the per-node caches in
schedule order so every float add happens in exactly the order the batch
path uses: the engine is **bit-identical** to ``estimate()``, not merely
approximately equal (per-edge and per-sync terms are integers, so their
delta maintenance is exact; float terms are never delta-maintained).

Equivalence is enforced by ``tests/test_incremental.py`` across every
model config and the PolyBench graphs, including after arbitrary
propose/rollback sequences.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .estimator import (FIXED_NODE_OVERHEAD_S, HBM_BW, ICI_BW, PEAK_FLOPS,
                        MeshSpec, NodeCost, ScheduleCost)
from .ir import Node, Schedule

#: sentinel for "no access map" (shard factor 1) in output-shard descriptors
_NO_ACCESS = None


def _shard_factor(pairs: tuple[tuple[str, int], ...],
                  unroll: dict[str, int]) -> int:
    """``estimator.buffer_shard_factor`` over precomputed (dim, axis_size)
    pairs (entries whose loop dim is None are dropped at build time)."""
    f = 1
    for dim, size in pairs:
        if dim in unroll:
            f *= min(unroll[dim], size)
    return max(f, 1)


def _out_shard(dims: tuple[str, ...] | None, unroll: dict[str, int]) -> int:
    """``estimator._op_out_shard`` over a precomputed non-None dim tuple."""
    if dims is _NO_ACCESS:
        return 1
    f = 1
    for d in dims:
        f *= unroll.get(d, 1)
    return max(f, 1)


@dataclass
class _NodeStatic:
    """Unroll-independent structure of one node."""

    flops: float
    repeat: float
    #: (buffer bytes, ((dim, axis_size), ...)) per buffer arg, in args order
    mem_terms: list[tuple[int, tuple[tuple[str, int], ...]]]
    #: (reduction dims, ((value bytes, out dims), ...), op repeat) per body op
    red_ops: list[tuple[tuple[str, ...],
                        tuple[tuple[int, tuple[str, ...] | None], ...],
                        float]]
    #: (weight bytes, shard pairs, weight dims) per weight buffer whose
    #: first consumer is this node
    sync_terms: list[tuple[int, tuple[tuple[str, int], ...],
                           frozenset[str]]] = field(default_factory=list)


@dataclass
class _EdgeStatic:
    """One producer→consumer shared-buffer edge."""

    src: int
    dst: int
    #: (producer dim, consumer dim) per buffer axis (None when unmapped)
    axes: tuple[tuple[str | None, str | None], ...]
    buf_bytes: int
    #: shard pairs of (buffer, producer) for the payload size
    src_pairs: tuple[tuple[str, int], ...]


class IncrementalEstimator:
    """Stateful roofline scorer over a Structural schedule.

    The estimator owns the schedule's parallelization state: mutations go
    through :meth:`propose` / :meth:`commit` / :meth:`rollback` (or the
    one-shot :meth:`apply`), which write ``node.unroll`` / ``node.axis_map``
    on the underlying :class:`Node` objects and incrementally refresh the
    cached cost terms.  At most one proposal may be outstanding.
    """

    def __init__(self, sched: Schedule, mesh: MeshSpec,
                 training: bool = True):
        self.sched = sched
        self.mesh = mesh
        self.training = training
        self._nodes: list[Node] = list(sched.nodes)
        self._idx = {n.name: i for i, n in enumerate(self._nodes)}
        self._build_static()
        n = len(self._nodes)
        self._comp = [0.0] * n        # compute_s
        self._mem = [0.0] * n         # memory_s
        self._nbytes = [0.0] * n      # HBM bytes (pre-division by BW)
        self._red = [0.0] * n         # intra-node reduction bytes
        self._sync = [0] * n          # weight-sync bytes (int)
        self._reshard = [0] * n       # Σ incident in-edge contributions (int)
        self._contrib = [0] * len(self._edges)
        self._lat = [0.0] * n         # latency_s
        self._undo: list | None = None
        self.refresh()

    # -- static structure ---------------------------------------------------

    def _build_static(self) -> None:
        sched = self.sched
        statics: list[_NodeStatic] = []
        for node in self._nodes:
            mem_terms = []
            for v in node.args:
                buf = sched.buffers.get(v)
                if buf is None:
                    continue
                am = node.access_for(v)
                pairs = () if am is None else tuple(
                    (dim, buf.shape[axis])
                    for axis, (dim, _stride) in enumerate(am.entries)
                    if dim is not None)
                mem_terms.append((buf.bytes, pairs))
            red_ops = []
            for op in node.body:
                out_dims: set[str] = set()
                for v in op.outs:
                    am = op.access.get(v)
                    if am:
                        out_dims.update(d for d, _ in am.entries if d)
                in_dims: set[str] = set()
                for v in op.ins:
                    am = op.access.get(v)
                    if am:
                        in_dims.update(d for d, _ in am.entries if d)
                red = (in_dims - out_dims) | set(op.attrs.get("reduce", ()))
                if not red:
                    continue
                outs = tuple(
                    (sched.value_bytes.get(v, 0),
                     _NO_ACCESS if op.access.get(v) is None else tuple(
                         d for d, _ in op.access[v].entries
                         if d is not None))
                    for v in op.outs)
                red_ops.append((tuple(red), outs, op.repeat))
            statics.append(_NodeStatic(
                flops=node.intensity(), repeat=node.repeat,
                mem_terms=mem_terms, red_ops=red_ops))
        self._static = statics

        edges: list[_EdgeStatic] = []
        for src, dst, bname in sched.edges():
            p, c = sched.node(src), sched.node(dst)
            buf = sched.buffers[bname]
            pam, cam = p.access_for(bname), c.access_for(bname)
            if pam is None or cam is None:
                continue
            axes = tuple(
                (pam.entries[axis][0] or None, cam.entries[axis][0] or None)
                for axis in range(len(buf.shape)))
            src_pairs = tuple(
                (dim, buf.shape[axis])
                for axis, (dim, _stride) in enumerate(pam.entries)
                if dim is not None)
            edges.append(_EdgeStatic(
                src=self._idx[src], dst=self._idx[dst], axes=axes,
                buf_bytes=buf.bytes, src_pairs=src_pairs))
        self._edges = edges
        self._edges_of: list[list[int]] = [[] for _ in self._nodes]
        for e, edge in enumerate(edges):
            self._edges_of[edge.src].append(e)
            if edge.dst != edge.src:
                self._edges_of[edge.dst].append(e)

        if self.training:
            for bname, buf in sched.buffers.items():
                if not buf.is_weight:
                    continue
                consumers = sched.consumers_of(bname)
                if not consumers:
                    continue
                n = consumers[0]
                am = n.access_for(bname)
                pairs = () if am is None else tuple(
                    (dim, buf.shape[axis])
                    for axis, (dim, _stride) in enumerate(am.entries)
                    if dim is not None)
                w_dims = frozenset(
                    d for d, _ in am.entries if d) if am else frozenset()
                self._static[self._idx[n.name]].sync_terms.append(
                    (buf.bytes, pairs, w_dims))

    # -- per-node term recomputation ----------------------------------------

    def _node_local(self, i: int) -> None:
        """Recompute the unroll/axis-dependent local terms of node ``i``
        (same arithmetic, in the same order, as the batch estimator)."""
        node = self._nodes[i]
        st = self._static[i]
        unroll = node.unroll
        pf = 1
        for v in unroll.values():
            pf *= v
        pf = max(pf, 1)
        self._comp[i] = st.flops / pf / PEAK_FLOPS

        total = 0.0
        for buf_bytes, pairs in st.mem_terms:
            total += buf_bytes / _shard_factor(pairs, unroll)
        nbytes = total * st.repeat
        self._nbytes[i] = nbytes
        self._mem[i] = nbytes / HBM_BW

        red = 0.0
        for red_dims, outs, op_repeat in st.red_ops:
            k = 1
            for d in red_dims:
                k *= unroll.get(d, 1)
            if k <= 1:
                continue
            out_bytes = sum(vbytes / _out_shard(dims, unroll)
                            for vbytes, dims in outs)
            red += 2.0 * out_bytes * (k - 1) / k * op_repeat
        self._red[i] = red

        sync = 0
        axis_map = node.axis_map
        for buf_bytes, pairs, w_dims in st.sync_terms:
            shard = buf_bytes // max(_shard_factor(pairs, unroll), 1)
            w_axes = {a for d in w_dims for a in axis_map.get(d, ())}
            sync_ways = 1
            for a, s in self.mesh.axes:
                if a not in w_axes:
                    sync_ways *= s
            if sync_ways > 1:
                sync += int(2 * shard * (sync_ways - 1) / sync_ways
                            * st.repeat)
        self._sync[i] = sync

    def _edge_contrib(self, edge: _EdgeStatic) -> int:
        p = self._nodes[edge.src]
        c = self._nodes[edge.dst]
        mismatch = False
        for pdim, cdim in edge.axes:
            paxes = tuple(p.axis_map.get(pdim, ())) if pdim else ()
            caxes = tuple(c.axis_map.get(cdim, ())) if cdim else ()
            if paxes != caxes:
                mismatch = True
        if not mismatch:
            return 0
        return edge.buf_bytes // max(
            _shard_factor(edge.src_pairs, p.unroll), 1)

    def _latency(self, i: int) -> float:
        coll = (self._reshard[i] + self._sync[i] + self._red[i]) / ICI_BW
        return max(self._comp[i], self._mem[i], coll) + FIXED_NODE_OVERHEAD_S

    # -- state maintenance ---------------------------------------------------

    def refresh(self) -> None:
        """Full resync from the nodes' current ``unroll`` / ``axis_map``
        (used at construction and after bulk external mutation)."""
        self._undo = None
        for i in range(len(self._nodes)):
            self._node_local(i)
        for i in range(len(self._nodes)):
            self._reshard[i] = 0
        for e, edge in enumerate(self._edges):
            v = self._edge_contrib(edge)
            self._contrib[e] = v
            self._reshard[edge.dst] += v
        for i in range(len(self._nodes)):
            self._lat[i] = self._latency(i)

    def _update_node(self, i: int, record: list | None) -> None:
        """Refresh node ``i``'s local terms and incident edges; ``record``
        collects (slot-restorer) undo entries when proposing."""
        if record is not None:
            record.append(("local", i, self._comp[i], self._mem[i],
                           self._nbytes[i], self._red[i], self._sync[i]))
        self._node_local(i)
        touched = {i}
        for e in self._edges_of[i]:
            edge = self._edges[e]
            new = self._edge_contrib(edge)
            old = self._contrib[e]
            if new != old:
                if record is not None:
                    record.append(("edge", e, old))
                self._contrib[e] = new
                self._reshard[edge.dst] += new - old
                touched.add(edge.dst)
        for j in touched:
            if record is not None:
                record.append(("lat", j, self._lat[j]))
            self._lat[j] = self._latency(j)

    # -- mutation API --------------------------------------------------------

    def propose(self, name: str, axis_map: dict[str, tuple[str, ...]],
                unroll: dict[str, int] | None = None) -> "IncrementalEstimator":
        """Tentatively assign ``axis_map`` (and its ``unroll`` factors) to
        node ``name``; must be resolved by :meth:`commit` or
        :meth:`rollback` before the next proposal."""
        if self._undo is not None:
            raise RuntimeError("a proposal is already outstanding")
        i = self._idx[name]
        node = self._nodes[i]
        if unroll is None:
            unroll = {
                d: _axes_product(self.mesh, axes)
                for d, axes in axis_map.items()}
        record: list = [("node", i, node.unroll, node.axis_map)]
        node.axis_map = dict(axis_map)
        node.unroll = dict(unroll)
        self._update_node(i, record)
        self._undo = record
        return self

    def commit(self) -> None:
        self._undo = None

    def rollback(self) -> None:
        if self._undo is None:
            raise RuntimeError("no outstanding proposal")
        for entry in reversed(self._undo):
            kind = entry[0]
            if kind == "node":
                _, i, unroll, axis_map = entry
                self._nodes[i].unroll = unroll
                self._nodes[i].axis_map = axis_map
            elif kind == "local":
                (_, i, self._comp[i], self._mem[i], self._nbytes[i],
                 self._red[i], self._sync[i]) = entry
            elif kind == "edge":
                _, e, old = entry
                new = self._contrib[e]
                self._contrib[e] = old
                self._reshard[self._edges[e].dst] += old - new
            else:  # "lat"
                _, i, self._lat[i] = entry
        self._undo = None

    def apply(self, name: str, axis_map: dict[str, tuple[str, ...]],
              unroll: dict[str, int] | None = None) -> None:
        """``propose`` + ``commit`` in one step."""
        self.propose(name, axis_map, unroll)
        self.commit()

    # -- queries -------------------------------------------------------------

    @property
    def total_s(self) -> float:
        return sum(self._lat)

    @property
    def critical_s(self) -> float:
        return max(self._lat, default=0.0)

    @property
    def hbm_bytes_per_device(self) -> int:
        hbm = 0.0
        for v in self._nbytes:
            hbm += v
        return int(hbm)

    def node_compute_s(self, name: str) -> float:
        return self._comp[self._idx[name]]

    def node_parallel_factor(self, name: str) -> int:
        node = self._nodes[self._idx[name]]
        f = 1
        for v in node.unroll.values():
            f *= v
        return max(f, 1)

    def schedule_cost(self) -> ScheduleCost:
        """Materialize the full :class:`ScheduleCost` (bit-identical to
        ``estimate(sched, mesh, training)`` on the current state)."""
        cost = ScheduleCost()
        for i, node in enumerate(self._nodes):
            coll = self._reshard[i] + self._sync[i] + self._red[i]
            cost.nodes[node.name] = NodeCost(
                compute_s=self._comp[i],
                memory_s=self._mem[i],
                collective_s=coll / ICI_BW,
            )
        cost.reshard_bytes = sum(self._contrib)
        cost.sync_bytes = sum(self._sync)
        cost.hbm_bytes_per_device = self.hbm_bytes_per_device
        return cost


def _axes_product(mesh: MeshSpec, axes: tuple[str, ...]) -> int:
    f = 1
    for a in axes:
        f *= mesh.size(a)
    return f
