"""HIDA core: hierarchical dataflow IR + optimizer (the paper's
contribution, re-targeted to TPU meshes)."""
from .balance import balance_paths
from .construct import construct_functional
from .estimator import (MULTI_POD, SINGLE_POD, MeshSpec, estimate,
                        roofline_terms)
from .fusion import fuse_tasks
from .graph import build_lm_graph
from .incremental import IncrementalEstimator
from .ir import (AccessMap, Buffer, Graph, GraphTopology, MemoryEffect, Node,
                 Op, Schedule, ScheduleTopology, Stream, TensorValue)
from .lower import lower_to_structural
from .multi_producer import eliminate_multi_producers
from .optimize import OptimizeReport, optimize
from .parallelize import parallelize
from .plan import ShardingPlan, build_plan, project_rules, replicated_plan
from .rewrite import GraphRewriteSession, RewriteError, ScheduleRewriteSession

__all__ = [
    "AccessMap", "Buffer", "Graph", "GraphTopology", "MemoryEffect", "Node",
    "Op", "Schedule", "ScheduleTopology", "Stream", "TensorValue", "MeshSpec",
    "SINGLE_POD",
    "MULTI_POD", "estimate", "IncrementalEstimator", "roofline_terms",
    "construct_functional",
    "fuse_tasks", "lower_to_structural", "eliminate_multi_producers",
    "balance_paths", "parallelize", "ShardingPlan", "build_plan",
    "project_rules", "replicated_plan", "optimize", "OptimizeReport",
    "build_lm_graph",
    "GraphRewriteSession", "ScheduleRewriteSession", "RewriteError",
]
