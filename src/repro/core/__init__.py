"""HIDA core: hierarchical dataflow IR + optimizer (the paper's
contribution, re-targeted to TPU meshes)."""
from .analyze import (AnalysisIssue, AnalysisRule, AnalyzeReport, analyze,
                      analyze_plan, register_rule, registered_rules)
from .balance import balance_paths
from .construct import construct_functional
from .estimator import (MULTI_POD, SINGLE_POD, MeshSpec, estimate,
                        roofline_terms)
from .faults import (FaultInjector, InjectedFault, active_injector,
                     fault_point, inject_faults)
from .fusion import fuse_tasks
from .generate import SYNTH_CONFIGS, SynthSpec, build_synth_graph, get_synth
from .graph import build_lm_graph
from .incremental import IncrementalEstimator
from .ir import (AccessMap, Buffer, Graph, GraphTopology, MemoryEffect, Node,
                 Op, Schedule, ScheduleTopology, Stream, TensorValue)
from .lower import fallback_schedule, lower_to_structural
from .multi_producer import eliminate_multi_producers
from .optimize import Degradation, OptimizeReport, optimize
from .parallelize import (RegionEntry, RegionSummary, best_uniform,
                          canonical_snapshot, parallelize)
from .plan import (PLAN_FORMAT_VERSION, ShardingPlan, build_plan,
                   project_rules, replicated_plan)
from .plan_cache import (CachedPlan, PlanCache, PlanKey, config_fingerprint,
                         fetch_or_optimize, shape_bucket)
from .rewrite import (GraphRewriteSession, RegionSpec, RewriteError,
                      ScheduleRewriteSession, default_region_bounds,
                      dse_regions, region_index_bytes)
from .verify import (VerifyError, VerifyIssue, VerifyReport, verify,
                     verify_static)

__all__ = [
    "AccessMap", "Buffer", "Graph", "GraphTopology", "MemoryEffect", "Node",
    "Op", "Schedule", "ScheduleTopology", "Stream", "TensorValue", "MeshSpec",
    "SINGLE_POD",
    "MULTI_POD", "estimate", "IncrementalEstimator", "roofline_terms",
    "construct_functional",
    "fuse_tasks", "lower_to_structural", "eliminate_multi_producers",
    "balance_paths", "parallelize", "best_uniform", "ShardingPlan",
    "build_plan",
    "project_rules", "replicated_plan", "optimize", "OptimizeReport",
    "Degradation", "fallback_schedule",
    "build_lm_graph",
    "GraphRewriteSession", "ScheduleRewriteSession", "RewriteError",
    "RegionSpec", "dse_regions", "RegionSummary", "RegionEntry",
    "default_region_bounds", "region_index_bytes",
    "SYNTH_CONFIGS", "SynthSpec", "build_synth_graph", "get_synth",
    "verify", "verify_static", "VerifyReport", "VerifyIssue", "VerifyError",
    "analyze", "analyze_plan", "AnalyzeReport", "AnalysisIssue",
    "AnalysisRule", "register_rule", "registered_rules",
    "inject_faults", "fault_point", "active_injector", "FaultInjector",
    "InjectedFault",
    "PlanKey", "PlanCache", "CachedPlan", "config_fingerprint",
    "shape_bucket", "fetch_or_optimize", "canonical_snapshot",
    "PLAN_FORMAT_VERSION",
]
