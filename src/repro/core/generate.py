"""Seeded synthetic dataflow-graph generator — the scale-stress suite.

Every real config in the repo tops out at 43 schedule nodes; the indexing
layers (the blocked closure rows of ``core.rewrite._RegionIndex``, the
Schedule-level topo/depth memos, ``dse_regions`` partitioning) exist to
scale two orders of magnitude past that.  This module generates the
graphs that prove it: deterministic, seeded, *structured* synthetic
pipelines in the 1k–10k-op range, exposed as named specs
(``synth_1k`` / ``synth_5k`` / ``synth_10k``) consumed by
``benchmarks/bench_compile_time`` arms and the tier-1 smoke tests.

Determinism contract
--------------------
``build_synth_graph(spec)`` is a pure function of the spec.  The only
randomness source is ``random.Random`` seeded from ``spec.seed`` (an
explicit field — there is deliberately no wall-clock or global-RNG
default), so the same spec yields a bit-identical graph on every call,
machine and Python run.  The golden tests in ``tests/test_generate.py``
pin this with a structure fingerprint.

Generated structure
-------------------
A spec describes ``n_chains`` parallel transformer-ish pipelines built
**chain-major** (all of chain 0, then chain 1, …).  Chain-major layout
matters: the closure rows of ``_RegionIndex`` index tasks by program
position, so keeping each chain's ops contiguous keeps every
reachability row a handful of dense 64-bit blocks instead of one bit
per block — the blocked representation's best case, and the layout real
unrolled pipelines exhibit anyway.

Each chain is a non-uniform stack of layer blocks drawn by the seeded
RNG:

* ``mlp`` — norm → matmul → activation → matmul → residual (the fusion
  patterns collapse it to ~2 tasks, like a real FFN);
* ``glu`` — norm → gate/up matmuls → elementwise gate → down matmul →
  residual (a diamond);
* ``composite`` — a PolyBench-style 3mm diamond (two independent
  matmuls feeding a combine and a third matmul);
* ``moe`` — router → ``moe_dispatch`` fanning out to ``n_experts``
  *separate* expert matmuls → ``moe_combine`` fan-in (the widest
  structural fan-out in the suite).

Chains cross-link sparsely: every ``cross_every`` layers a chain's
residual additionally reads the *previous* chain's trunk at the same
depth — but only within groups of ``group_size`` chains, so the links
never compose transitively across the whole graph.  The result is a
band-limited closure (a task's reachable cone spreads sideways at most
``group_size - 1`` chains) while still denying the partitioner a
trivial per-chain cut.  A final elementwise join over all chain trunks
makes the graph single-output.
"""
from __future__ import annotations

import random
from dataclasses import dataclass

from .ir import AccessMap, Graph

BF = "bf16"

__all__ = ["SynthSpec", "SYNTH_CONFIGS", "build_synth_graph",
           "get_synth", "list_synths"]


@dataclass(frozen=True)
class SynthSpec:
    """One synthetic scale-stress configuration (pure data, hashable)."""

    name: str
    #: explicit RNG seed — the *only* randomness source of the builder.
    seed: int
    #: target op count; the generated graph lands within ~15% of it
    #: (chains are non-uniform by design, so the total is approximate).
    n_ops: int
    #: parallel pipeline chains (graph width).
    n_chains: int = 32
    #: a chain's residual reads its left neighbour every this many
    #: layers (0 disables cross-links entirely).
    cross_every: int = 8
    #: chains are cross-linked only within groups of this many: chain k
    #: reads chain k-1 unless k opens a new group.  Without the bound the
    #: links compose transitively (0→1→…→n_chains) and every early
    #: chain's reachability cone spans the whole graph — closure rows,
    #: fuse folds and region crossings all go superlinear.  Grouping
    #: keeps cones band-limited (the realistic shape: real models share
    #: within a block, not across the entire network) while still
    #: denying the partitioner a trivial per-chain cut.
    group_size: int = 4
    #: every this many layers a chain emits an MoE fan-out block
    #: (0 disables).
    moe_every: int = 0
    #: every this many layers a chain emits a PolyBench-style composite
    #: (0 disables).
    composite_every: int = 0
    #: expert fan-out width of the MoE blocks.
    n_experts: int = 8
    batch: int = 8
    seq: int = 1024
    d_model: int = 1024


#: Named presets — the scale ladder the bench arms and tests consume.
#: 1k is the tier-1 smoke (fast lane), 5k carries the <20 s / <2 MB
#: acceptance gate, 10k is the headroom arm (slow lane only).
SYNTH_CONFIGS: dict[str, SynthSpec] = {
    "synth_1k": SynthSpec("synth_1k", seed=11, n_ops=1000, n_chains=12,
                          cross_every=6, moe_every=7, composite_every=5,
                          n_experts=8),
    "synth_5k": SynthSpec("synth_5k", seed=13, n_ops=5000, n_chains=48,
                          cross_every=8, moe_every=9, composite_every=6,
                          n_experts=8),
    "synth_10k": SynthSpec("synth_10k", seed=17, n_ops=10000, n_chains=80,
                           cross_every=8, moe_every=9, composite_every=6,
                           n_experts=8),
}


def list_synths() -> list[str]:
    return list(SYNTH_CONFIGS)


def get_synth(name: str) -> Graph:
    """Build the named preset (``synth_1k`` / ``synth_5k`` / ``synth_10k``)."""
    if name not in SYNTH_CONFIGS:
        raise KeyError(f"unknown synth config {name!r}; "
                       f"known: {list_synths()}")
    return build_synth_graph(SYNTH_CONFIGS[name])


# -- layer-block emitters ----------------------------------------------------
# Each emitter appends the block's ops to ``g`` and returns the new trunk
# value name.  ``extra`` carries the optional cross-link input into the
# residual.  Hidden dims are named by size (``d_ff2048`` …) so equal
# sizes share one plan rule and unequal sizes never collide.

def _mlp(g: Graph, pre: str, trunk: str, B: int, S: int, D: int,
         F: int, extra: list[str]) -> str:
    fd = f"d_ff{F}"
    g.tensor(f"{pre}_xn", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("norm", [trunk], [f"{pre}_xn"], {"batch": B, "seq": S,
         "d_model": D}, flops=5 * B * S * D, name=f"{pre}_norm",
         reduce=("d_model",))
    g.tensor(f"{pre}_w1", (D, F), BF, ("d_model", fd), is_weight=True)
    g.tensor(f"{pre}_h", (B, S, F), BF, ("batch", "seq", fd))
    g.op("matmul", [f"{pre}_xn", f"{pre}_w1"], [f"{pre}_h"],
         {"batch": B, "seq": S, "d_model": D, fd: F},
         flops=2 * B * S * D * F, name=f"{pre}_mm1")
    g.tensor(f"{pre}_ha", (B, S, F), BF, ("batch", "seq", fd))
    g.op("activation", [f"{pre}_h"], [f"{pre}_ha"],
         {"batch": B, "seq": S, fd: F}, flops=B * S * F,
         name=f"{pre}_act")
    g.tensor(f"{pre}_w2", (F, D), BF, (fd, "d_model"), is_weight=True)
    g.tensor(f"{pre}_o", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("matmul", [f"{pre}_ha", f"{pre}_w2"], [f"{pre}_o"],
         {"batch": B, "seq": S, fd: F, "d_model": D},
         flops=2 * B * S * F * D, name=f"{pre}_mm2")
    g.tensor(f"{pre}_r", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("residual", [f"{pre}_o", trunk] + extra, [f"{pre}_r"],
         {"batch": B, "seq": S, "d_model": D}, flops=B * S * D,
         name=f"{pre}_res")
    return f"{pre}_r"


def _glu(g: Graph, pre: str, trunk: str, B: int, S: int, D: int,
         F: int, extra: list[str]) -> str:
    fd = f"d_ff{F}"
    g.tensor(f"{pre}_xn", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("norm", [trunk], [f"{pre}_xn"], {"batch": B, "seq": S,
         "d_model": D}, flops=5 * B * S * D, name=f"{pre}_norm",
         reduce=("d_model",))
    for arm in ("gate", "up"):
        g.tensor(f"{pre}_w_{arm}", (D, F), BF, ("d_model", fd),
                 is_weight=True)
        g.tensor(f"{pre}_{arm}", (B, S, F), BF, ("batch", "seq", fd))
        g.op("matmul", [f"{pre}_xn", f"{pre}_w_{arm}"], [f"{pre}_{arm}"],
             {"batch": B, "seq": S, "d_model": D, fd: F},
             flops=2 * B * S * D * F, name=f"{pre}_mm_{arm}")
    g.tensor(f"{pre}_h", (B, S, F), BF, ("batch", "seq", fd))
    g.op("elementwise", [f"{pre}_gate", f"{pre}_up"], [f"{pre}_h"],
         {"batch": B, "seq": S, fd: F}, flops=2 * B * S * F,
         name=f"{pre}_glu")
    g.tensor(f"{pre}_w2", (F, D), BF, (fd, "d_model"), is_weight=True)
    g.tensor(f"{pre}_o", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("matmul", [f"{pre}_h", f"{pre}_w2"], [f"{pre}_o"],
         {"batch": B, "seq": S, fd: F, "d_model": D},
         flops=2 * B * S * F * D, name=f"{pre}_mm2")
    g.tensor(f"{pre}_r", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("residual", [f"{pre}_o", trunk] + extra, [f"{pre}_r"],
         {"batch": B, "seq": S, "d_model": D}, flops=B * S * D,
         name=f"{pre}_res")
    return f"{pre}_r"


def _composite(g: Graph, pre: str, trunk: str, B: int, S: int, D: int,
               F: int, extra: list[str]) -> str:
    """PolyBench 3mm-style diamond: two independent matmuls from the
    trunk, an elementwise combine, a third matmul back to d_model."""
    cd = f"d_cmp{F}"
    for arm in ("a", "b"):
        g.tensor(f"{pre}_w_{arm}", (D, F), BF, ("d_model", cd),
                 is_weight=True)
        g.tensor(f"{pre}_{arm}", (B, S, F), BF, ("batch", "seq", cd))
        g.op("matmul", [trunk, f"{pre}_w_{arm}"], [f"{pre}_{arm}"],
             {"batch": B, "seq": S, "d_model": D, cd: F},
             flops=2 * B * S * D * F, name=f"{pre}_mm_{arm}")
    g.tensor(f"{pre}_c", (B, S, F), BF, ("batch", "seq", cd))
    g.op("elementwise", [f"{pre}_a", f"{pre}_b"], [f"{pre}_c"],
         {"batch": B, "seq": S, cd: F}, flops=B * S * F,
         name=f"{pre}_combine")
    g.tensor(f"{pre}_w_c", (F, D), BF, (cd, "d_model"), is_weight=True)
    g.tensor(f"{pre}_o", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("matmul", [f"{pre}_c", f"{pre}_w_c"], [f"{pre}_o"],
         {"batch": B, "seq": S, cd: F, "d_model": D},
         flops=2 * B * S * F * D, name=f"{pre}_mm_c")
    g.tensor(f"{pre}_r", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("residual", [f"{pre}_o", trunk] + extra, [f"{pre}_r"],
         {"batch": B, "seq": S, "d_model": D}, flops=B * S * D,
         name=f"{pre}_res")
    return f"{pre}_r"


def _moe(g: Graph, pre: str, trunk: str, B: int, S: int, D: int,
         E: int, extra: list[str]) -> str:
    """Structural MoE fan-out: the dispatch writes one buffer *per
    expert* and each expert is its own matmul op — unlike the batched
    expert dim of the real LM builder, this stresses graph width (fan-out
    E, fan-in E) rather than a single fat op."""
    cap = max(1, (B * S * 2) // E)
    g.tensor(f"{pre}_xn", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("norm", [trunk], [f"{pre}_xn"], {"batch": B, "seq": S,
         "d_model": D}, flops=5 * B * S * D, name=f"{pre}_norm",
         reduce=("d_model",))
    g.tensor(f"{pre}_w_r", (D, E), "f32", ("d_model", "experts"),
             is_weight=True)
    g.tensor(f"{pre}_logits", (B, S, E), "f32",
             ("batch", "seq", "experts"))
    g.op("matmul", [f"{pre}_xn", f"{pre}_w_r"], [f"{pre}_logits"],
         {"batch": B, "seq": S, "d_model": D, "experts": E},
         flops=2 * B * S * D * E, name=f"{pre}_router")
    disp = []
    for e in range(E):
        g.tensor(f"{pre}_d{e}", (cap, D), BF, ("cap", "d_model"))
        disp.append(f"{pre}_d{e}")
    g.op("moe_dispatch", [f"{pre}_xn", f"{pre}_logits"], disp,
         {"cap": cap, "d_model": D}, flops=B * S * D,
         name=f"{pre}_dispatch",
         access={f"{pre}_xn": AccessMap.of(("batch", 1), (None, 1),
                                           ("d_model", 1)),
                 f"{pre}_logits": AccessMap.of(("batch", 1), (None, 1),
                                               (None, 1))})
    outs = []
    for e in range(E):
        g.tensor(f"{pre}_we{e}", (D, D), BF, ("d_model", "d_model"),
                 is_weight=True)
        g.tensor(f"{pre}_eo{e}", (cap, D), BF, ("cap", "d_model"))
        g.op("matmul", [f"{pre}_d{e}", f"{pre}_we{e}"], [f"{pre}_eo{e}"],
             {"cap": cap, "d_model": D}, flops=2 * cap * D * D,
             name=f"{pre}_exp{e}")
        outs.append(f"{pre}_eo{e}")
    g.tensor(f"{pre}_comb", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("moe_combine", outs + [f"{pre}_logits"], [f"{pre}_comb"],
         {"batch": B, "seq": S, "d_model": D}, flops=B * S * D,
         name=f"{pre}_combine",
         access={f"{pre}_logits": AccessMap.of(("batch", 1), ("seq", 1),
                                               (None, 1))})
    g.tensor(f"{pre}_r", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("residual", [f"{pre}_comb", trunk] + extra, [f"{pre}_r"],
         {"batch": B, "seq": S, "d_model": D}, flops=B * S * D,
         name=f"{pre}_res")
    return f"{pre}_r"


#: mean ops per layer block across the kind mix — used only to size the
#: per-chain layer budget from ``n_ops``.
_OPS_PER_LAYER = 5.6


def build_synth_graph(spec: SynthSpec) -> Graph:
    """Deterministically build the synthetic graph described by ``spec``.

    Pure function of the spec (see the module docstring's determinism
    contract); the op/value orders are generation order, so the structure
    fingerprint is stable across calls."""
    g = Graph(spec.name)
    B, S, D = spec.batch, spec.seq, spec.d_model
    ff_sizes = (2 * D, 3 * D, 4 * D)

    base_layers = max(2.0, spec.n_ops / spec.n_chains / _OPS_PER_LAYER)
    finals: list[str] = []
    # trunk value of (chain, layer) — the cross-link source; only the
    # previous chain's entries are ever read, but keeping all of them is
    # simpler and the dict dies with this call.
    trunk_at: dict[tuple[int, int], str] = {}
    ops_left = spec.n_ops
    for k in range(spec.n_chains):
        rng = random.Random(spec.seed * 1_000_003 + k)
        n_layers = max(2, round(base_layers * rng.uniform(0.7, 1.3)))
        g.tensor(f"c{k}_x", (B, S, D), BF, ("batch", "seq", "d_model"),
                 is_input=True)
        trunk = f"c{k}_x"
        for j in range(n_layers):
            if ops_left <= 0 and j >= 2:
                break  # global budget hit; keep the 2-layer minimum
            extra: list[str] = []
            if (spec.cross_every and k > 0
                    and (spec.group_size <= 0
                         or k % spec.group_size != 0)
                    and j % spec.cross_every == k % spec.cross_every
                    and (k - 1, j) in trunk_at):
                extra = [trunk_at[(k - 1, j)]]
            pre = f"c{k}_l{j}"
            n_before = len(g.ops)
            if spec.moe_every and j % spec.moe_every == spec.moe_every - 1:
                trunk = _moe(g, pre, trunk, B, S, D, spec.n_experts,
                             extra)
            elif (spec.composite_every
                    and j % spec.composite_every
                    == spec.composite_every - 1):
                trunk = _composite(g, pre, trunk, B, S, D,
                                   rng.choice(ff_sizes) // 2, extra)
            elif rng.random() < 0.35:
                trunk = _glu(g, pre, trunk, B, S, D,
                             rng.choice(ff_sizes), extra)
            else:
                trunk = _mlp(g, pre, trunk, B, S, D,
                             rng.choice(ff_sizes), extra)
            trunk_at[(k, j)] = trunk
            ops_left -= len(g.ops) - n_before
        finals.append(trunk)

    g.tensor("synth_out", (B, S, D), BF, ("batch", "seq", "d_model"))
    g.op("elementwise", finals, ["synth_out"],
         {"batch": B, "seq": S, "d_model": D},
         flops=B * S * D * len(finals), name="join")
    g.outputs = ["synth_out"]
    return g
