"""Functional dataflow task fusion — paper Algorithm 2.

Two phases per ``dispatch`` region, processed top-down (pre-order):

1. *Pattern-driven worklist fusion*: pre-defined profitable fusion patterns
   (e.g. matmul + element-wise epilogue, norm into the next matmul,
   element-wise chains) are applied until no pattern matches.

2. *Least-critical re-balancing*: repeatedly fuse the two least-critical
   adjacent tasks while the fusion does not create a new critical task —
   i.e. while ``intensity(t0)+intensity(t1) <= max_task_intensity``.  This
   balances the dataflow (the critical task bounds pipeline throughput).

Finally the dispatch/task hierarchy is canonicalised (a task owning a
single sub-task collapses, empty dispatches disappear).

Every structural mutation flows through
:class:`~repro.core.rewrite.GraphRewriteSession`: adjacency / cycle
queries are lookups against the session's per-dispatch region index
(direct edges + an incrementally-maintained reachability closure — no
DFS per query), pattern matching reads the shared
:class:`~repro.core.ir.GraphTopology` leaf-kind rollups, the balance
phase runs a Δ-maintained candidate-pair heap (seeded once from the
region's edges, extended only with pairs incident to the last fusion —
the former per-step all-pairs re-enumeration with a DFS per pair was the
dominant pre-DSE compile cost), and the final hierarchy canonicalisation
is a single transactional
:meth:`~repro.core.rewrite.GraphRewriteSession.canonicalize`.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field

from .faults import fault_point
from .ir import Graph, Op, make_task
from .rewrite import GraphRewriteSession


# --------------------------------------------------------------------------
# Fusion patterns
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FusionPattern:
    """Profitable producer→consumer fusion pattern.

    Matches when a task whose *last* leaf op has kind ``producer`` feeds a
    task whose leaf ops all have kinds in ``consumer`` (epilogue-style
    fusion).  ``name`` is for logging only.
    """

    name: str
    producer: str
    consumer: frozenset[str]

    def matches(self, p: Op, c: Op) -> bool:
        p_leaves = [o for o in p.walk() if not o.has_region]
        c_leaves = [o for o in c.walk() if not o.has_region]
        if not p_leaves or not c_leaves:
            return False
        return (p_leaves[-1].kind == self.producer
                and all(o.kind in self.consumer for o in c_leaves))

    def matches_meta(self, p_meta: tuple, c_meta: tuple) -> bool:
        """:meth:`matches` over memoized ``GraphTopology.leaf_meta``
        rollups ``(last leaf kind, frozenset of leaf kinds)`` — no region
        re-walk per candidate pair."""
        return (bool(c_meta[1]) and p_meta[0] == self.producer
                and c_meta[1] <= self.consumer)


def default_patterns() -> list[FusionPattern]:
    """Patterns mirroring the paper's "profitable fusion patterns" plus the
    DNN-compiler classics (element-wise epilogues, norm folding)."""
    ew = frozenset({"elementwise", "activation", "bias", "residual",
                    "scale", "mask", "cast"})
    return [
        FusionPattern("ew-chain", "elementwise", ew),
        FusionPattern("matmul-epilogue", "matmul", ew),
        FusionPattern("conv-epilogue", "conv", ew),
        FusionPattern("scan-epilogue", "scan", ew),
        FusionPattern("attn-epilogue", "attention", ew),
        FusionPattern("norm-into-matmul", "norm", frozenset({"matmul"})),
        FusionPattern("router-dispatch", "router",
                      frozenset({"moe_dispatch"})),
        FusionPattern("gate-combine", "moe_combine", ew),
    ]


# --------------------------------------------------------------------------
# Connectivity helpers (transparent regions: values flow by name)
# --------------------------------------------------------------------------

def _produces(t: Op) -> set[str]:
    return set(t.all_outs())


def _consumes(t: Op) -> set[str]:
    return set(t.all_ins())


def adjacent(a: Op, b: Op) -> bool:
    """True when a feeds b or b feeds a through any value (standalone
    form; the fusion phases use the session's maintained region index)."""
    return bool(_produces(a) & _consumes(b)) or bool(_produces(b) & _consumes(a))


# --------------------------------------------------------------------------
# Algorithm 2
# --------------------------------------------------------------------------

@dataclass
class FusionStats:
    pattern_fusions: int = 0
    balance_fusions: int = 0
    #: peak bytes held by the rewrite session's region indexes (closure
    #: rows, rank maps, consumer buckets) over the whole fusion run —
    #: sampled at every structural commit point, reported per arm by
    #: ``bench_compile_time`` and gated by its memory comparison.
    index_peak_bytes: int = 0
    log: list[str] = field(default_factory=list)


def _pattern_phase(d: Op, patterns: list[FusionPattern],
                   stats: FusionStats, rs: GraphRewriteSession) -> None:
    worklist = deque(d.region)
    while worklist:
        t = worklist.popleft()
        if not rs.alive(d, t):
            continue    # already fused away
        # Candidates must be adjacent, so scanning t's neighbours (in
        # region order — the order a full `d.region` scan would visit)
        # is equivalent to the old O(region) sweep per worklist item.
        for u in rs.neighbors_in_order(d, t):
            if rs.creates_cycle(d, t, u):
                continue
            p, c = rs.order(d, t, u)
            pm, cm = rs.leaf_meta(p), rs.leaf_meta(c)
            if any(pat.matches_meta(pm, cm) for pat in patterns):
                fault_point("fusion.pattern")
                merged = rs.fuse(d, p, c)
                stats.pattern_fusions += 1
                stats.log.append(f"pattern: {p.name}+{c.name}->{merged.name}")
                worklist.append(merged)
                break


#: tasks below this fraction of the critical intensity are "light" — the
#: re-balancing phase only absorbs light tasks into neighbours.  Fusing two
#: heavy tasks with different parallel dims would collapse the
#: parallelization granularity (one unroll set per node), which on TPU
#: means replicating one of the two matmul families — never profitable.
LIGHT_FRACTION = 0.05


def _balance_phase(d: Op, stats: FusionStats, rs: GraphRewriteSession,
                   max_tasks: int | None = None) -> None:
    """Least-critical re-balancing over a Δ-maintained candidate heap.

    Candidate pairs are seeded once from the region's edge set and
    extended only with pairs incident to each fusion's merged task; the
    heap key is ``(combined intensity, rank(a), rank(b))`` with the
    session's program-order ranks as the **explicit tie-break** (the old
    all-pairs ``min()`` resolved ties by enumeration order — the same
    order, but implicitly; ranks are static per task, so entries never
    go stale as the region list shifts).  Lazy invalidation keeps the
    heap honest:

    * entries whose endpoint was fused away are dropped on pop;
    * cycle-creating pairs are dropped *permanently* on pop — fusing
      other pairs only contracts the region graph, which can add paths
      between two live tasks but never remove one.  The exception is the
      session's vanished-edge fallback (a fuse over a multi-produced
      value can sever an edge): it bumps ``region_epoch``, on which the
      heap reseeds from the full edge set so a discarded pair that
      became legal is reconsidered — matching the old per-step
      re-enumeration on such graphs;
    * pairs failing the light-task guard are parked in a side heap keyed
      by min-intensity and promoted when the critical intensity (which
      is non-decreasing) grows enough — or wholesale while ``max_tasks``
      forces fusion past the guard.
    """
    region = d.region
    if len(region) <= 1:
        return
    crit = max(rs.intensity(t) for t in region)
    seq = itertools.count()

    def entry(a: Op, b: Op) -> tuple:
        a, b = rs.order(d, a, b)
        ia, ib = rs.intensity(a), rs.intensity(b)
        # (sum, rank, rank) is unique among *live* pairs (ranks are unique
        # per live task), so the sequence number never influences which
        # candidate is selected — it only keeps comparisons away from the
        # Op payload when a dead entry collides with a live one (e.g. a
        # zero-intensity fusion leaves sum and inherited rank unchanged).
        return (ia + ib, rs.rank(d, a), rs.rank(d, b), next(seq),
                min(ia, ib), a, b)

    active = [entry(a, b) for a, b in rs.adjacent_pairs(d)]
    heapq.heapify(active)
    deferred: list[tuple] = []   # (min_int, sum, rank, rank, seq, a, b)
    epoch = rs.region_epoch(d)

    while len(region) > 1:
        forced = max_tasks is not None and len(region) > max_tasks
        limit = LIGHT_FRACTION * crit
        while deferred and (forced or deferred[0][0] <= limit):
            mn, s, ra, rb, sq, a, b = heapq.heappop(deferred)
            heapq.heappush(active, (s, ra, rb, sq, mn, a, b))
        cand = None
        while active:
            s, ra, rb, sq, mn, a, b = heapq.heappop(active)
            if not (rs.alive(d, a) and rs.alive(d, b)):
                continue
            if not forced and mn > limit:
                heapq.heappush(deferred, (mn, s, ra, rb, sq, a, b))
                continue
            if rs.creates_cycle(d, a, b):
                continue
            cand = (s, a, b)
            break
        if cand is None:
            break
        s, a, b = cand
        # Paper line 9: stop when fusing would create a new critical task.
        if s > crit and not forced:
            break
        fault_point("fusion.balance")
        merged = rs.fuse(d, a, b)
        crit = max(crit, rs.intensity(merged))
        if rs.region_epoch(d) != epoch:
            # Reachability shrank (vanished-edge fallback): permanently-
            # discarded cycle pairs may be legal now — reseed from the
            # live edge set.  Duplicate entries are harmless: identical
            # keys up to seq, and dead copies drop at pop.
            epoch = rs.region_epoch(d)
            for pa, pb in rs.adjacent_pairs(d):
                heapq.heappush(active, entry(pa, pb))
        else:
            for t in rs.neighbors(d, merged):
                heapq.heappush(active, entry(merged, t))
        stats.balance_fusions += 1
        stats.log.append(f"balance: {a.name}+{b.name}->{merged.name}")


def simplify_hierarchy(op: Op) -> Op:
    """Canonicalise dispatch/task nesting (paper Alg. 2 line 10)."""
    op.region = [simplify_hierarchy(c) for c in op.region]
    # task{ task{...} } -> task{...};  dispatch{ task } -> that task's body
    if op.kind in ("task", "dispatch") and len(op.region) == 1:
        child = op.region[0]
        if child.kind in ("task", "dispatch"):
            return child
        if op.kind == "dispatch":
            return make_task([child], name=op.name)
    return op


def fuse_tasks(graph: Graph, patterns: list[FusionPattern] | None = None,
               max_tasks: int | None = None,
               selfcheck: bool = False) -> FusionStats:
    """Paper Algorithm 2 over every dispatch in pre-order (in place).

    Fewer, better-balanced tasks is what keeps the downstream DSE
    tractable: the parallelizer's proposal enumeration and the beam
    search's joint-move neighbourhoods both scale with the node count of
    the lowered schedule, so fusion here is the first half of the
    "hierarchy makes the DSE scale" claim.

    The whole worklist runs inside one
    :class:`~repro.core.rewrite.GraphRewriteSession` — on an exception the
    graph rolls back to its pre-fusion structure, and on success the
    maintained topology is committed so no downstream pass pays a
    re-index.

    Args:
        graph: Functional graph whose dispatch regions get fused.
        patterns: profitable producer→consumer patterns (defaults to
            :func:`default_patterns`).
        max_tasks: when set, the balance phase keeps fusing (ignoring the
            light-task guard) until each dispatch has at most this many
            tasks — the escape valve for pathologically wide frontends.
        selfcheck: assert the session's maintained topology against a
            from-scratch rebuild after every rewrite (tests only).

    Returns:
        :class:`FusionStats` with per-phase fusion counts and a log.
    """
    patterns = patterns if patterns is not None else default_patterns()
    stats = FusionStats()
    with GraphRewriteSession(graph, selfcheck=selfcheck) as rs:
        for op in list(graph.walk(pre=True)):
            if op.kind == "dispatch":
                _pattern_phase(op, patterns, stats, rs)
                _balance_phase(op, stats, rs, max_tasks)
        rs.canonicalize(simplify_hierarchy)
    stats.index_peak_bytes = rs.index_peak_bytes
    return stats
