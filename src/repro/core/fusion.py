"""Functional dataflow task fusion — paper Algorithm 2.

Two phases per ``dispatch`` region, processed top-down (pre-order):

1. *Pattern-driven worklist fusion*: pre-defined profitable fusion patterns
   (e.g. matmul + element-wise epilogue, norm into the next matmul,
   element-wise chains) are applied until no pattern matches.

2. *Least-critical re-balancing*: repeatedly fuse the two least-critical
   adjacent tasks while the fusion does not create a new critical task —
   i.e. while ``intensity(t0)+intensity(t1) <= max_task_intensity``.  This
   balances the dataflow (the critical task bounds pipeline throughput).

Finally the dispatch/task hierarchy is canonicalised (a task owning a
single sub-task collapses, empty dispatches disappear).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from .ir import Graph, Op, make_task


# --------------------------------------------------------------------------
# Fusion patterns
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FusionPattern:
    """Profitable producer→consumer fusion pattern.

    Matches when a task whose *last* leaf op has kind ``producer`` feeds a
    task whose leaf ops all have kinds in ``consumer`` (epilogue-style
    fusion).  ``name`` is for logging only.
    """

    name: str
    producer: str
    consumer: frozenset[str]

    def matches(self, p: Op, c: Op) -> bool:
        p_leaves = [o for o in p.walk() if not o.has_region]
        c_leaves = [o for o in c.walk() if not o.has_region]
        if not p_leaves or not c_leaves:
            return False
        return (p_leaves[-1].kind == self.producer
                and all(o.kind in self.consumer for o in c_leaves))


def default_patterns() -> list[FusionPattern]:
    """Patterns mirroring the paper's "profitable fusion patterns" plus the
    DNN-compiler classics (element-wise epilogues, norm folding)."""
    ew = frozenset({"elementwise", "activation", "bias", "residual",
                    "scale", "mask", "cast"})
    return [
        FusionPattern("ew-chain", "elementwise", ew),
        FusionPattern("matmul-epilogue", "matmul", ew),
        FusionPattern("conv-epilogue", "conv", ew),
        FusionPattern("scan-epilogue", "scan", ew),
        FusionPattern("attn-epilogue", "attention", ew),
        FusionPattern("norm-into-matmul", "norm", frozenset({"matmul"})),
        FusionPattern("router-dispatch", "router",
                      frozenset({"moe_dispatch"})),
        FusionPattern("gate-combine", "moe_combine", ew),
    ]


# --------------------------------------------------------------------------
# Connectivity helpers (transparent regions: values flow by name)
# --------------------------------------------------------------------------

def _produces(t: Op) -> set[str]:
    return set(t.all_outs())


def _consumes(t: Op) -> set[str]:
    return set(t.all_ins())


def adjacent(a: Op, b: Op) -> bool:
    """True when a feeds b or b feeds a through any value."""
    return bool(_produces(a) & _consumes(b)) or bool(_produces(b) & _consumes(a))


def _ordered(a: Op, b: Op, tasks: list[Op]) -> tuple[Op, Op]:
    ia, ib = tasks.index(a), tasks.index(b)
    return (a, b) if ia <= ib else (b, a)


class _RegionIndex:
    """Memoized connectivity over one dispatch region.

    A task's region is never mutated after creation (``_fuse_pair`` builds
    a *new* merged task), so produces/consumes/intensity are cached per
    task object.  The successor graph over the current task list is built
    once per fusion step and shared by every adjacency / cycle query —
    previously each ``_creates_cycle`` call rebuilt it from scratch, the
    O(steps × pairs × n²) term that dominated ``optimize()`` wall time on
    large graphs."""

    def __init__(self) -> None:
        self._prods: dict[int, set[str]] = {}
        self._cons: dict[int, set[str]] = {}
        self._intensity: dict[int, float] = {}
        self._pins: list[Op] = []   # keep refs so id() keys stay unique
        self._tasks: list[Op] = []
        self._succ: list[set[int]] = []
        self._pos: dict[int, int] = {}

    def prods(self, t: Op) -> set[str]:
        s = self._prods.get(id(t))
        if s is None:
            s = _produces(t)
            self._prods[id(t)] = s
            self._pins.append(t)
        return s

    def cons(self, t: Op) -> set[str]:
        s = self._cons.get(id(t))
        if s is None:
            s = _consumes(t)
            self._cons[id(t)] = s
            self._pins.append(t)
        return s

    def intensity(self, t: Op) -> float:
        v = self._intensity.get(id(t))
        if v is None:
            v = t.intensity()
            self._intensity[id(t)] = v
            self._pins.append(t)
        return v

    def rebuild(self, tasks: list[Op]) -> None:
        """Recompute the successor graph for the current task list."""
        self._tasks = list(tasks)
        self._pos = {id(t): i for i, t in enumerate(self._tasks)}
        prods = [self.prods(t) for t in self._tasks]
        cons = [self.cons(t) for t in self._tasks]
        n = len(self._tasks)
        self._succ = [set() for _ in range(n)]
        for i in range(n):
            pi = prods[i]
            for j in range(n):
                if i != j and pi & cons[j]:
                    self._succ[i].add(j)

    def adjacent(self, a: Op, b: Op) -> bool:
        ia, ib = self._pos[id(a)], self._pos[id(b)]
        return ib in self._succ[ia] or ia in self._succ[ib]

    def creates_cycle(self, a: Op, b: Op) -> bool:
        """Fusing a and b is illegal when a third task sits on a dataflow
        path between them (the merged task would both feed and consume it).
        This matters for decode graphs: qkv → cache-update → attention must
        not fuse qkv with attention around the cache-update node."""
        ia, ib = self._pos[id(a)], self._pos[id(b)]
        succ = self._succ
        for src, dst in ((ia, ib), (ib, ia)):
            seen: set[int] = set()
            stack = [n for n in succ[src] if n != dst]
            while stack:
                n = stack.pop()
                if n in seen:
                    continue
                seen.add(n)
                if dst in succ[n]:
                    return True
                stack.extend(m for m in succ[n] if m != dst)
        return False


def _creates_cycle(tasks: list[Op], a: Op, b: Op) -> bool:
    """Standalone form of :meth:`_RegionIndex.creates_cycle` (kept for
    direct callers/tests; the fusion phases use the shared index)."""
    idx = _RegionIndex()
    idx.rebuild(tasks)
    return idx.creates_cycle(a, b)


# --------------------------------------------------------------------------
# Algorithm 2
# --------------------------------------------------------------------------

@dataclass
class FusionStats:
    pattern_fusions: int = 0
    balance_fusions: int = 0
    log: list[str] = field(default_factory=list)


def _fuse_pair(tasks: list[Op], a: Op, b: Op) -> Op:
    """Fuse two tasks of one dispatch region into a new task, preserving
    program order (transparent regions make this a pure re-wrap)."""
    first, second = _ordered(a, b, tasks)
    i = tasks.index(first)
    merged = make_task(list(first.region) + list(second.region))
    tasks[i] = merged
    tasks.remove(second)
    return merged


def _pattern_phase(d: Op, patterns: list[FusionPattern],
                   stats: FusionStats, idx: _RegionIndex) -> None:
    worklist = list(d.region)
    idx.rebuild(d.region)
    while worklist:
        t = worklist.pop(0)
        if t not in d.region:
            continue
        for u in list(d.region):
            if u is t or not idx.adjacent(t, u) or idx.creates_cycle(t, u):
                continue
            p, c = _ordered(t, u, d.region)
            if any(pat.matches(p, c) for pat in patterns):
                merged = _fuse_pair(d.region, p, c)
                stats.pattern_fusions += 1
                stats.log.append(f"pattern: {p.name}+{c.name}->{merged.name}")
                worklist.append(merged)
                idx.rebuild(d.region)
                break


#: tasks below this fraction of the critical intensity are "light" — the
#: re-balancing phase only absorbs light tasks into neighbours.  Fusing two
#: heavy tasks with different parallel dims would collapse the
#: parallelization granularity (one unroll set per node), which on TPU
#: means replicating one of the two matmul families — never profitable.
LIGHT_FRACTION = 0.05


def _balance_phase(d: Op, stats: FusionStats, idx: _RegionIndex,
                   max_tasks: int | None = None) -> None:
    while len(d.region) > 1:
        idx.rebuild(d.region)
        crit = max(idx.intensity(t) for t in d.region)
        pairs = [(a, b) for i, a in enumerate(d.region)
                 for b in d.region[i + 1:]
                 if idx.adjacent(a, b) and not idx.creates_cycle(a, b)]
        forced = max_tasks is not None and len(d.region) > max_tasks
        if not forced:
            pairs = [(a, b) for a, b in pairs
                     if min(idx.intensity(a), idx.intensity(b))
                     <= LIGHT_FRACTION * crit]
        if not pairs:
            break
        a, b = min(pairs,
                   key=lambda p: idx.intensity(p[0]) + idx.intensity(p[1]))
        fused_intensity = idx.intensity(a) + idx.intensity(b)
        # Paper line 9: stop when fusing would create a new critical task.
        if fused_intensity > crit and not forced:
            break
        merged = _fuse_pair(d.region, a, b)
        stats.balance_fusions += 1
        stats.log.append(f"balance: {a.name}+{b.name}->{merged.name}")


def simplify_hierarchy(op: Op) -> Op:
    """Canonicalise dispatch/task nesting (paper Alg. 2 line 10)."""
    op.region = [simplify_hierarchy(c) for c in op.region]
    # task{ task{...} } -> task{...};  dispatch{ task } -> that task's body
    if op.kind in ("task", "dispatch") and len(op.region) == 1:
        child = op.region[0]
        if child.kind in ("task", "dispatch"):
            return child
        if op.kind == "dispatch":
            return make_task([child], name=op.name)
    return op


def fuse_tasks(graph: Graph, patterns: list[FusionPattern] | None = None,
               max_tasks: int | None = None) -> FusionStats:
    """Paper Algorithm 2 over every dispatch in pre-order (in place).

    Fewer, better-balanced tasks is what keeps the downstream DSE
    tractable: the parallelizer's proposal enumeration and the beam
    search's joint-move neighbourhoods both scale with the node count of
    the lowered schedule, so fusion here is the first half of the
    "hierarchy makes the DSE scale" claim.

    Args:
        graph: Functional graph whose dispatch regions get fused.
        patterns: profitable producer→consumer patterns (defaults to
            :func:`default_patterns`).
        max_tasks: when set, the balance phase keeps fusing (ignoring the
            light-task guard) until each dispatch has at most this many
            tasks — the escape valve for pathologically wide frontends.

    Returns:
        :class:`FusionStats` with per-phase fusion counts and a log.
    """
    patterns = patterns if patterns is not None else default_patterns()
    stats = FusionStats()
    idx = _RegionIndex()
    for op in list(graph.walk(pre=True)):
        if op.kind == "dispatch":
            _pattern_phase(op, patterns, stats, idx)
            _balance_phase(op, stats, idx, max_tasks)
    graph.ops = [simplify_hierarchy(o) for o in graph.ops]
    return stats
