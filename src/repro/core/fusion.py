"""Functional dataflow task fusion — paper Algorithm 2.

Two phases per ``dispatch`` region, processed top-down (pre-order):

1. *Pattern-driven worklist fusion*: pre-defined profitable fusion patterns
   (e.g. matmul + element-wise epilogue, norm into the next matmul,
   element-wise chains) are applied until no pattern matches.

2. *Least-critical re-balancing*: repeatedly fuse the two least-critical
   adjacent tasks while the fusion does not create a new critical task —
   i.e. while ``intensity(t0)+intensity(t1) <= max_task_intensity``.  This
   balances the dataflow (the critical task bounds pipeline throughput).

Finally the dispatch/task hierarchy is canonicalised (a task owning a
single sub-task collapses, empty dispatches disappear).

Every structural mutation flows through
:class:`~repro.core.rewrite.GraphRewriteSession`: adjacency / cycle
queries run against the session's per-dispatch successor graph (built
once, maintained in O(Δ) per fusion), pattern matching reads the shared
:class:`~repro.core.ir.GraphTopology` leaf-kind rollups, and the final
hierarchy canonicalisation is a single transactional
:meth:`~repro.core.rewrite.GraphRewriteSession.canonicalize`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .ir import Graph, Op, make_task
from .rewrite import GraphRewriteSession


# --------------------------------------------------------------------------
# Fusion patterns
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FusionPattern:
    """Profitable producer→consumer fusion pattern.

    Matches when a task whose *last* leaf op has kind ``producer`` feeds a
    task whose leaf ops all have kinds in ``consumer`` (epilogue-style
    fusion).  ``name`` is for logging only.
    """

    name: str
    producer: str
    consumer: frozenset[str]

    def matches(self, p: Op, c: Op) -> bool:
        p_leaves = [o for o in p.walk() if not o.has_region]
        c_leaves = [o for o in c.walk() if not o.has_region]
        if not p_leaves or not c_leaves:
            return False
        return (p_leaves[-1].kind == self.producer
                and all(o.kind in self.consumer for o in c_leaves))

    def matches_meta(self, p_meta: tuple, c_meta: tuple) -> bool:
        """:meth:`matches` over memoized ``GraphTopology.leaf_meta``
        rollups ``(last leaf kind, frozenset of leaf kinds)`` — no region
        re-walk per candidate pair."""
        return (bool(c_meta[1]) and p_meta[0] == self.producer
                and c_meta[1] <= self.consumer)


def default_patterns() -> list[FusionPattern]:
    """Patterns mirroring the paper's "profitable fusion patterns" plus the
    DNN-compiler classics (element-wise epilogues, norm folding)."""
    ew = frozenset({"elementwise", "activation", "bias", "residual",
                    "scale", "mask", "cast"})
    return [
        FusionPattern("ew-chain", "elementwise", ew),
        FusionPattern("matmul-epilogue", "matmul", ew),
        FusionPattern("conv-epilogue", "conv", ew),
        FusionPattern("scan-epilogue", "scan", ew),
        FusionPattern("attn-epilogue", "attention", ew),
        FusionPattern("norm-into-matmul", "norm", frozenset({"matmul"})),
        FusionPattern("router-dispatch", "router",
                      frozenset({"moe_dispatch"})),
        FusionPattern("gate-combine", "moe_combine", ew),
    ]


# --------------------------------------------------------------------------
# Connectivity helpers (transparent regions: values flow by name)
# --------------------------------------------------------------------------

def _produces(t: Op) -> set[str]:
    return set(t.all_outs())


def _consumes(t: Op) -> set[str]:
    return set(t.all_ins())


def adjacent(a: Op, b: Op) -> bool:
    """True when a feeds b or b feeds a through any value (standalone
    form; the fusion phases use the session's maintained successor
    graph)."""
    return bool(_produces(a) & _consumes(b)) or bool(_produces(b) & _consumes(a))


def _ordered(a: Op, b: Op, tasks: list[Op]) -> tuple[Op, Op]:
    ia, ib = tasks.index(a), tasks.index(b)
    return (a, b) if ia <= ib else (b, a)


# --------------------------------------------------------------------------
# Algorithm 2
# --------------------------------------------------------------------------

@dataclass
class FusionStats:
    pattern_fusions: int = 0
    balance_fusions: int = 0
    log: list[str] = field(default_factory=list)


def _pattern_phase(d: Op, patterns: list[FusionPattern],
                   stats: FusionStats, rs: GraphRewriteSession) -> None:
    worklist = list(d.region)
    while worklist:
        t = worklist.pop(0)
        if not any(x is t for x in d.region):
            continue    # already fused away
        for u in list(d.region):
            if u is t or not rs.adjacent(d, t, u) or rs.creates_cycle(d, t, u):
                continue
            p, c = _ordered(t, u, d.region)
            pm, cm = rs.leaf_meta(p), rs.leaf_meta(c)
            if any(pat.matches_meta(pm, cm) for pat in patterns):
                merged = rs.fuse(d, p, c)
                stats.pattern_fusions += 1
                stats.log.append(f"pattern: {p.name}+{c.name}->{merged.name}")
                worklist.append(merged)
                break


#: tasks below this fraction of the critical intensity are "light" — the
#: re-balancing phase only absorbs light tasks into neighbours.  Fusing two
#: heavy tasks with different parallel dims would collapse the
#: parallelization granularity (one unroll set per node), which on TPU
#: means replicating one of the two matmul families — never profitable.
LIGHT_FRACTION = 0.05


def _balance_phase(d: Op, stats: FusionStats, rs: GraphRewriteSession,
                   max_tasks: int | None = None) -> None:
    while len(d.region) > 1:
        crit = max(rs.intensity(t) for t in d.region)
        pairs = [(a, b) for i, a in enumerate(d.region)
                 for b in d.region[i + 1:]
                 if rs.adjacent(d, a, b) and not rs.creates_cycle(d, a, b)]
        forced = max_tasks is not None and len(d.region) > max_tasks
        if not forced:
            pairs = [(a, b) for a, b in pairs
                     if min(rs.intensity(a), rs.intensity(b))
                     <= LIGHT_FRACTION * crit]
        if not pairs:
            break
        a, b = min(pairs,
                   key=lambda p: rs.intensity(p[0]) + rs.intensity(p[1]))
        fused_intensity = rs.intensity(a) + rs.intensity(b)
        # Paper line 9: stop when fusing would create a new critical task.
        if fused_intensity > crit and not forced:
            break
        merged = rs.fuse(d, a, b)
        stats.balance_fusions += 1
        stats.log.append(f"balance: {a.name}+{b.name}->{merged.name}")


def simplify_hierarchy(op: Op) -> Op:
    """Canonicalise dispatch/task nesting (paper Alg. 2 line 10)."""
    op.region = [simplify_hierarchy(c) for c in op.region]
    # task{ task{...} } -> task{...};  dispatch{ task } -> that task's body
    if op.kind in ("task", "dispatch") and len(op.region) == 1:
        child = op.region[0]
        if child.kind in ("task", "dispatch"):
            return child
        if op.kind == "dispatch":
            return make_task([child], name=op.name)
    return op


def fuse_tasks(graph: Graph, patterns: list[FusionPattern] | None = None,
               max_tasks: int | None = None,
               selfcheck: bool = False) -> FusionStats:
    """Paper Algorithm 2 over every dispatch in pre-order (in place).

    Fewer, better-balanced tasks is what keeps the downstream DSE
    tractable: the parallelizer's proposal enumeration and the beam
    search's joint-move neighbourhoods both scale with the node count of
    the lowered schedule, so fusion here is the first half of the
    "hierarchy makes the DSE scale" claim.

    The whole worklist runs inside one
    :class:`~repro.core.rewrite.GraphRewriteSession` — on an exception the
    graph rolls back to its pre-fusion structure, and on success the
    maintained topology is committed so no downstream pass pays a
    re-index.

    Args:
        graph: Functional graph whose dispatch regions get fused.
        patterns: profitable producer→consumer patterns (defaults to
            :func:`default_patterns`).
        max_tasks: when set, the balance phase keeps fusing (ignoring the
            light-task guard) until each dispatch has at most this many
            tasks — the escape valve for pathologically wide frontends.
        selfcheck: assert the session's maintained topology against a
            from-scratch rebuild after every rewrite (tests only).

    Returns:
        :class:`FusionStats` with per-phase fusion counts and a log.
    """
    patterns = patterns if patterns is not None else default_patterns()
    stats = FusionStats()
    with GraphRewriteSession(graph, selfcheck=selfcheck) as rs:
        for op in list(graph.walk(pre=True)):
            if op.kind == "dispatch":
                _pattern_phase(op, patterns, stats, rs)
                _balance_phase(op, stats, rs, max_tasks)
        rs.canonicalize(simplify_hierarchy)
    return stats
