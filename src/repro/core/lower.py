"""Functional → Structural dataflow lowering — paper Section 6.3.

Three procedures:

1. *Buffer generation*: every tensor crossing a task boundary becomes a
   ``Buffer`` with default partition / tiling / placement attributes
   (tensor semantics → memory semantics, Fig. 6).
2. *dispatch → schedule* mapping.
3. *task → node* mapping: because Functional ops are transparent while
   Structural ops are isolated, live-ins and memory effects are analysed
   here and recorded explicitly on each ``Node``'s argument list.

Values produced *and* consumed entirely inside one task stay node-internal
(they never materialise as schedule buffers — on TPU they live in registers
/ VMEM inside the fused XLA computation).

The schedule is assembled through a
:class:`~repro.core.rewrite.ScheduleRewriteSession` (``add_node`` /
``add_buffer`` / ``drop_arg`` / ``set_outputs``), whose commit installs
the Δ-maintained :class:`~repro.core.ir.ScheduleTopology` — the
downstream passes and the DSE start on a warm topology cache instead of
paying the first full index build.
"""
from __future__ import annotations

from .faults import fault_point
from .ir import Buffer, Graph, MemoryEffect, Node, Op, Schedule
from .rewrite import ScheduleRewriteSession


def _node_effects(task: Op) -> dict[str, str]:
    """Explicit memory-effect analysis for one task (paper Fig. 4)."""
    reads: list[str] = []
    writes: list[str] = []
    produced: set[str] = set()
    for o in task.walk():
        if o.has_region:
            continue
        for v in o.ins:
            if v not in produced and v not in reads:
                reads.append(v)
        for v in o.outs:
            produced.add(v)
            if v not in writes:
                writes.append(v)
    effects: dict[str, str] = {}
    for v in reads:
        effects[v] = MemoryEffect.READ
    for v in writes:
        # A value both read and written by the task (in-place update, e.g.
        # a KV-cache slot or gradient accumulator) carries RW.
        effects[v] = (MemoryEffect.READ_WRITE if v in effects
                      else MemoryEffect.WRITE)
    return effects


def _leaf_body(task: Op) -> list[Op]:
    return [o for o in task.walk() if not o.has_region]


def fallback_schedule(graph: Graph, name: str | None = None) -> Schedule:
    """Bottom rung of the degradation ladder for lowering failures: the
    whole graph as ONE Structural node (every leaf op in one body, every
    graph input/output/weight as an external buffer).

    Always legal — no internal edges, so acyclicity, stage order and
    multi-producer invariants hold trivially — and the DSE can still
    shard the single node, so a broken lowering degrades to a fused
    whole-model computation instead of a failed compile.  Deliberately
    assembled *without* a rewrite session: this path must stay
    serviceable when the transactional machinery (or a fault injected
    into it) is what took the primary lowering down."""
    leaves = [o for top in graph.ops for o in top.walk()
              if not o.has_region]
    effects = _node_effects(Op(name="__fallback__", kind="task",
                               region=leaves))
    graph_io = set(graph.inputs) | set(graph.outputs)
    crossing = {v: e for v, e in effects.items()
                if v in graph_io or graph.values[v].is_weight}
    sched = Schedule(name=name or f"{graph.name}_sched_fallback")
    sched.nodes.append(Node(name=f"{graph.name}_all", args=dict(crossing),
                            body=leaves))
    for v in crossing:
        sched.buffers[v] = Buffer.from_tensor(graph.values[v],
                                              placement="hbm")
        sched.args.append(v)
    sched.outputs = [v for v in graph.outputs if v in sched.buffers]
    sched.value_bytes = {v: t.bytes for v, t in graph.values.items()}
    return sched


def lower_to_structural(graph: Graph, name: str | None = None,
                        selfcheck: bool = False) -> Schedule:
    """Lower the (fused) Functional dataflow to a Structural schedule.

    ``selfcheck`` asserts the session's maintained topology against a
    from-scratch build after every rewrite (tests only); it propagates
    to recursively-lowered sub-schedules."""
    # The top level is a single dispatch after construction+fusion; tolerate
    # a bare op list for tiny graphs (no dataflow opportunity).
    if len(graph.ops) == 1 and graph.ops[0].kind == "dispatch":
        tasks = graph.ops[0].region
    else:
        tasks = graph.ops

    sched = Schedule(name=name or f"{graph.name}_sched")
    with ScheduleRewriteSession(sched, selfcheck=selfcheck) as rs:
        for t in tasks:
            fault_point("lower.node")
            effects = _node_effects(t)
            sub = None
            inner_dispatches = [c for c in t.region if c.kind == "dispatch"]
            if inner_dispatches:
                # Recursive nesting: lower the inner dispatch to a
                # sub-schedule (with its own session).
                inner_graph = Graph(name=f"{t.name}_inner",
                                    values=graph.values,
                                    ops=[inner_dispatches[0]])
                sub = lower_to_structural(inner_graph, name=f"{t.name}_sub",
                                          selfcheck=selfcheck)
            rs.add_node(Node(name=t.name, args=effects, body=_leaf_body(t),
                             sub_schedule=sub))
        nodes = sched.nodes

        # -- buffer generation: values crossing node boundaries ------------
        touched_by: dict[str, set[str]] = {}
        for n in nodes:
            for v in n.args:
                touched_by.setdefault(v, set()).add(n.name)

        graph_io = set(graph.inputs) | set(graph.outputs)
        for vname, users in touched_by.items():
            crossing = len(users) > 1 or vname in graph_io
            if not crossing:
                # Node-internal temporary: drop from the node arg list.
                for n in nodes:
                    if vname in n.args:
                        rs.drop_arg(n, vname)
                continue
            fault_point("lower.buffer")
            t = graph.values[vname]
            external = vname in graph_io or t.is_weight
            rs.add_buffer(Buffer.from_tensor(t, placement="hbm"),
                          external=external)
        rs.set_outputs([v for v in graph.outputs if v in sched.buffers])
        rs.set_value_bytes({v: t.bytes for v, t in graph.values.items()})
    return sched
