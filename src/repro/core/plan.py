"""ShardingPlan: the artifact HIDA-OPT hands to pjit.

``build_plan`` converts a parallelized Structural schedule into:

* ``buffer_specs`` — per Structural buffer, the mesh axes sharding each
  tensor dimension (derived from the owning node's ``axis_map`` through its
  access map).  Model code applies these at the corresponding
  ``with_sharding_constraint`` sites (the TPU realisation of HIDA's buffer
  partition attributes).
* ``rules`` — logical-dim-name → mesh axes, the majority assignment across
  nodes; used for tensors that are not first-class Structural buffers
  (optimizer state, RNG keys, …).
* ``fsdp`` — optional ZeRO-3-style extra sharding of weight buffers over
  the unused data axes (beyond-paper feature required to fit the 100B+
  configs in HBM; recorded separately in EXPERIMENTS.md).

The plan is pure data (JSON-serialisable via ``to_json``) so dry-run
artifacts can be diffed across perf iterations.
"""
from __future__ import annotations

import json
import logging
from collections import Counter
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .estimator import MeshSpec
from .faults import fault_point
from .ir import Schedule, ScheduleTopology

logger = logging.getLogger(__name__)

Axes = tuple[str, ...]

#: Serialization format version written by :meth:`ShardingPlan.to_json`
#: and required (exactly) by :meth:`ShardingPlan.from_json`.  Bump it
#: whenever the JSON schema or the semantics of any field change — the
#: persistent plan cache (:mod:`repro.core.plan_cache`) rejects entries
#: whose version differs instead of misapplying a stale layout.
PLAN_FORMAT_VERSION = 1


@dataclass
class ShardingPlan:
    mesh_spec: MeshSpec
    buffer_specs: dict[str, tuple[Axes, ...]] = field(default_factory=dict)
    rules: dict[str, Axes] = field(default_factory=dict)
    fsdp: bool = False
    meta: dict = field(default_factory=dict)
    #: role alias -> source buffer site (e.g. ``"qkv" -> "L0__qkv"``); the
    #: alias's spec in ``buffer_specs`` mirrors the source's and is kept in
    #: step by :meth:`apply_rule_change`.  Derivable from the names, so it
    #: is not serialized.
    role_sources: dict[str, str] = field(default_factory=dict)
    #: site -> count of overrides dropped by :meth:`spec_for_dims` because
    #: the stored per-dim rank mismatched the queried dims.  A diagnostic
    #: populated on the query path, so kept out of ``meta`` / ``to_json``
    #: — the serialized plan stays pure data, independent of query history.
    spec_rank_mismatches: dict[str, int] = field(default_factory=dict)

    # -- spec construction ---------------------------------------------------
    def _dedupe(self, axes_per_dim: Sequence[Axes]) -> tuple:
        """PartitionSpec axes must be unique; first use (leftmost dim) wins,
        later dims drop the duplicate axis (replicate instead)."""
        used: set[str] = set()
        out = []
        for axes in axes_per_dim:
            keep = tuple(a for a in axes if a not in used)
            used.update(keep)
            if not keep:
                out.append(None)
            elif len(keep) == 1:
                out.append(keep[0])
            else:
                out.append(keep)
        while out and out[-1] is None:
            out.pop()
        return tuple(out)

    def spec_for_dims(self, dims: Sequence[str],
                      site: str | None = None) -> P:
        """PartitionSpec for a tensor described by logical dim names,
        honouring a buffer-site override when given.  A site override
        whose stored rank mismatches ``dims`` (common for role aliases
        stripped from layer-prefixed names) falls back to the rules — the
        drop is counted in :attr:`spec_rank_mismatches` (and debug-logged)
        so silently replicated tensors are diagnosable."""
        if site is not None and site in self.buffer_specs:
            per_dim = self.buffer_specs[site]
            if len(per_dim) == len(dims):
                return P(*self._dedupe(per_dim))
            mm = self.spec_rank_mismatches
            mm[site] = mm.get(site, 0) + 1
            logger.debug(
                "spec_for_dims: site %r override rank %d != dims %r; "
                "falling back to rules", site, len(per_dim), tuple(dims))
        per_dim = [self.rules.get(d, ()) for d in dims]
        return P(*self._dedupe(per_dim))

    def param_spec(self, dims: Sequence[str], site: str | None = None,
                   shape: Sequence[int] | None = None) -> P:
        """Weight spec; with ``fsdp`` the unused data axes additionally
        shard a remaining dim (ZeRO-3), preferring evenly divisible dims
        when the shape is known (avoids GSPMD padding waste)."""
        base = self.spec_for_dims(dims, site)
        # Expert weights are fully sharded by expert (EP widened over the
        # data axis for big expert counts) — extra FSDP axes on their
        # other dims would force per-layer gathers that XLA hoists out of
        # the layer scan into a stacked multi-hundred-GiB temp.
        if not self.fsdp or "experts" in dims:
            return base
        spec = list(base) + [None] * (len(dims) - len(base))
        used = {a for entry in spec if entry
                for a in ((entry,) if isinstance(entry, str) else entry)}

        def place(axis_name: str, i: int) -> None:
            entry = spec[i]
            if entry is None:
                spec[i] = axis_name
            else:
                cur = (entry,) if isinstance(entry, str) else tuple(entry)
                spec[i] = cur + (axis_name,)
            used.add(axis_name)

        for axis_name in ("data", "pod"):
            try:
                size = self.mesh_spec.size(axis_name)
            except KeyError:
                continue
            if axis_name in used:
                continue
            candidates = [i for i, e in enumerate(spec) if e is None]
            candidates += [i for i in range(len(spec))
                           if i not in candidates]
            if shape is not None:
                # The composed factor (existing axes × fsdp axis) must
                # divide the dim — jit argument shardings reject padding.
                def factor(i):
                    e = spec[i]
                    f = size
                    for a in ((e,) if isinstance(e, str) else (e or ())):
                        f *= self.mesh_spec.size(a)
                    return f
                candidates = [i for i in candidates
                              if shape[i] % factor(i) == 0]
            if candidates:
                place(axis_name, candidates[0])
        while spec and spec[-1] is None:
            spec.pop()
        return P(*spec)

    # -- application ----------------------------------------------------------
    def constrain(self, x, dims: Sequence[str], site: str | None = None):
        """Apply a sharding constraint at a Structural buffer site.  Outside
        a mesh context (pure-CPU smoke tests) this is a no-op."""
        try:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is None or mesh.empty:
                return x
        except Exception:
            return x
        spec = self.spec_for_dims(dims, site)
        return jax.lax.with_sharding_constraint(x, spec)

    def named_sharding(self, mesh: Mesh, dims: Sequence[str],
                       site: str | None = None, weight: bool = False,
                       shape: Sequence[int] | None = None) -> NamedSharding:
        spec = (self.param_spec(dims, site, shape) if weight
                else self.spec_for_dims(dims, site))
        return NamedSharding(mesh, spec)

    # -- incremental re-projection --------------------------------------------
    def add_role_alias(self, role: str, source: str) -> None:
        """Expose ``source``'s spec under the stripped role name (first
        writer wins, matching ``setdefault``); the alias tracks its source
        through later :meth:`apply_rule_change` re-projections."""
        if role in self.buffer_specs or source not in self.buffer_specs:
            return
        self.buffer_specs[role] = self.buffer_specs[source]
        self.role_sources[role] = source

    def apply_rule_change(self, dim: str, axes: Axes,
                          sched: Schedule,
                          topology: ScheduleTopology | None = None
                          ) -> list[str]:
        """Delta re-projection: set ``rules[dim] = axes`` (empty ``axes``
        deletes the rule) and re-project **only** the buffer sites whose
        coherent access maps reference ``dim`` — plus their role aliases —
        instead of rebuilding every spec like :func:`project_rules`.

        Requires the plan to be coherent (every site already the
        projection of the current rules, i.e. built with
        ``coherent=True`` and mutated only through this method); then the
        result is bit-identical to a full :func:`project_rules` rebuild
        under the new rules.  Returns the re-projected site names."""
        fault_point("plan.delta")
        if axes:
            self.rules[dim] = tuple(axes)
        else:
            self.rules.pop(dim, None)
        topo = topology or sched.topology()
        changed: list[str] = []
        for bname in topo.buffers_of_dim.get(dim, ()):
            if bname not in self.buffer_specs:
                continue
            per_dim = _projected_spec(self.rules, topo.axis_dims[bname])
            self.buffer_specs[bname] = per_dim
            buf = sched.buffers.get(bname)
            if buf is not None:
                buf.spec = per_dim
            changed.append(bname)
        touched = set(changed)
        for role, source in self.role_sources.items():
            if source in touched:
                self.buffer_specs[role] = self.buffer_specs[source]
                changed.append(role)
        return changed

    # -- serialisation ----------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "version": PLAN_FORMAT_VERSION,
            "mesh": [[a, int(s)] for a, s in self.mesh_spec.axes],
            "buffer_specs": {k: [list(a) for a in v]
                             for k, v in self.buffer_specs.items()},
            "rules": {k: list(v) for k, v in self.rules.items()},
            "fsdp": self.fsdp,
            "meta": self.meta,
            # Role aliases are derivable from the "__"-prefixed names on a
            # live plan, but a deserialized plan must re-project aliases
            # through apply_rule_change without re-deriving, so the map is
            # carried explicitly (round-trip exactness > redundancy).
            "role_sources": dict(self.role_sources),
            # sort_keys makes the serialization canonical: two plans with
            # equal content serialize to the same bytes regardless of the
            # insertion order their dicts were built in — the round trip
            # from_json(to_json(p)).to_json() is bit-identical, and plan
            # JSON is directly comparable/hashable by the cache layer.
        }, indent=2, sort_keys=True, default=str)

    @classmethod
    def from_json(cls, text: str) -> "ShardingPlan":
        """Exact inverse of :meth:`to_json`: the round trip
        ``ShardingPlan.from_json(p.to_json()).to_json() == p.to_json()``
        is bit-identical, including role aliases and ``meta``.

        Raises ``ValueError`` when the serialized ``version`` is not
        :data:`PLAN_FORMAT_VERSION` — a stale persisted plan must be
        rejected (and re-derived), never silently misapplied."""
        d = json.loads(text)
        version = d.get("version")
        if version != PLAN_FORMAT_VERSION:
            raise ValueError(
                f"plan format version {version!r} != supported "
                f"{PLAN_FORMAT_VERSION}; stale entry must be re-derived")
        return cls(
            mesh_spec=MeshSpec(tuple((a, int(s)) for a, s in d["mesh"])),
            buffer_specs={k: tuple(tuple(a) for a in v)
                          for k, v in d["buffer_specs"].items()},
            rules={k: tuple(v) for k, v in d["rules"].items()},
            fsdp=bool(d["fsdp"]),
            meta=d["meta"],
            role_sources=dict(d.get("role_sources", {})))


def replicated_plan(mesh_spec: MeshSpec, data_axes: Axes = ("pod", "data"),
                    fsdp: bool = False) -> ShardingPlan:
    """The naive baseline: batch over data axes, everything else
    replicated (what you get without the paper's technique)."""
    rules = {"batch": tuple(a for a in data_axes
                            if a in mesh_spec.names)}
    return ShardingPlan(mesh_spec=mesh_spec, rules=rules, fsdp=fsdp,
                        meta={"strategy": "naive-dp"})


def _projected_spec(rules: dict[str, Axes],
                    axis_dims: Sequence[Optional[str]]) -> tuple[Axes, ...]:
    """THE projection routine: per-buffer spec as the consensus rules seen
    through the buffer's coherent per-axis loop dims (first non-None dim
    any owner's access map names at each axis — see
    ``ScheduleTopology.axis_dims``).  Both the full rebuild
    (:func:`project_rules`) and the delta path
    (:meth:`ShardingPlan.apply_rule_change`) go through here, so they
    cannot diverge.  Scanning *all* owners per axis (not just the first
    owner with any access map) is what fixes the silent-unshard hazard:
    a producer whose access map has ``None`` at an axis no longer hides a
    consumer's loop dim there."""
    return tuple(rules.get(d, ()) if d else () for d in axis_dims)


def build_plan(sched: Schedule, mesh_spec: MeshSpec,
               fsdp: bool = False, meta: dict | None = None,
               coherent: bool = True,
               topology: ScheduleTopology | None = None) -> ShardingPlan:
    """Derive the :class:`ShardingPlan` from a parallelized schedule.

    Runs after the DSE (greedy + beam search, see
    :func:`repro.core.parallelize.parallelize`) has written ``unroll`` /
    ``axis_map`` onto every node: per-buffer specs come from the owning
    nodes' axis maps projected through their access maps; per-logical-dim
    ``rules`` are the intensity-weighted majority vote across nodes.

    Args:
        sched: parallelized Structural schedule (read-only here).
        mesh_spec: target mesh (recorded in the plan for ``specs()``).
        fsdp: ZeRO-3-style extra weight sharding over unused data axes
            (beyond-paper; needed to fit the 100B+ configs in HBM).
        meta: free-form provenance recorded in the plan (JSON-serialised
            with it).
        coherent: ``True`` (the CA-on product) projects one
            intensity-weighted consensus rule per logical dim onto every
            buffer site — constraint sites never disagree, so GSPMD
            resharding stays incremental.  ``False`` keeps raw per-node
            layouts (the CA-off ablation arm); measured on deepseek-v3
            train_4k this triggers GSPMD "involuntary full
            rematerialization" and ~2.3 TiB/device of temp — the TPU
            incarnation of the paper's Fig. 11 'flawed designs'.
        topology: the shared :class:`ScheduleTopology`; defaults to the
            schedule's cached one (the same structure the incremental
            estimator's DSE ran on).
    """
    fault_point("plan.build")
    plan = ShardingPlan(mesh_spec=mesh_spec, fsdp=fsdp, meta=meta or {})
    topo = topology or sched.topology()

    votes: dict[str, Counter] = {}
    for bname, buf in sched.buffers.items():
        if not topo.owners(bname):
            continue
        per_dim: list[Axes] = []
        for pairs in topo.axis_owner_dims[bname]:
            axes: Axes = ()
            # Producer's layout wins; an unparallelized producer (e.g. the
            # amortized embed node, pf=1) defers to its consumers so the
            # buffer does not force a reshard at every layer boundary.
            for node, d in pairs:
                a = tuple(node.axis_map.get(d, ()))
                if a:
                    axes = a
                    break
            per_dim.append(axes)
            if pairs:
                votes.setdefault(pairs[0][1], Counter())[axes] += 1
        plan.buffer_specs[bname] = tuple(per_dim)
        buf.spec = tuple(per_dim)

    for node in sched.nodes:
        # Intensity-weighted votes: the critical nodes decide the rules.
        w = max(int(node.intensity() ** 0.5), 1)
        for dim, axes in node.axis_map.items():
            votes.setdefault(dim, Counter())[tuple(axes)] += w

    for dim, counter in votes.items():
        winner, _ = counter.most_common(1)[0]
        if winner:
            plan.rules[dim] = winner

    if coherent:
        project_rules(plan, sched, topology=topo)
    return plan


def project_rules(plan: ShardingPlan, sched: Schedule,
                  topology: ScheduleTopology | None = None) -> None:
    """Rewrite every buffer site as the projection of the consensus rules
    — one layout basin across the whole dataflow.  This is the full
    rebuild; :meth:`ShardingPlan.apply_rule_change` is the O(Δ) path for
    a single-rule update.  Both run the same projection
    (:func:`_projected_spec`) over the same cached per-axis dims, so a
    delta-maintained plan and a from-scratch rebuild are bit-identical."""
    fault_point("plan.project")
    topo = topology or sched.topology()
    for bname, buf in sched.buffers.items():
        if bname not in plan.buffer_specs:
            continue
        per_dim = _projected_spec(plan.rules, topo.axis_dims[bname])
        plan.buffer_specs[bname] = per_dim
        buf.spec = per_dim
    for role, source in plan.role_sources.items():
        if source in plan.buffer_specs:
            plan.buffer_specs[role] = plan.buffer_specs[source]
