"""HIDA-IR: hierarchical dataflow intermediate representation.

This module ports the paper's two-level IR (Section 5) to a JAX-oriented
setting:

* **Functional dataflow** — ``Dispatch`` / ``Task`` operations with
  *transparent* regions and tensor (immutable-value) semantics.  Used by the
  algorithmic passes: dataflow construction (Alg. 1) and task fusion
  (Alg. 2).

* **Structural dataflow** — ``Schedule`` / ``Node`` operations with
  *isolated* regions, explicit per-argument memory effects, plus ``Buffer``
  (memory-mapped, ping-pong, carrying partition / tiling / placement
  attributes) and ``Stream`` (FIFO) values.  Used by the
  micro-architectural passes: multi-producer elimination (Alg. 3),
  data-path balancing (Section 6.4.2) and IA+CA parallelization (Alg. 4).

On TPU, a Structural ``Node`` becomes a region of the XLA program delimited
by sharding-constraint sites, a ``Buffer`` becomes an activation / weight
tensor whose ``partition`` attribute is realised as a ``PartitionSpec``,
and a ``Stream`` becomes a pipeline staging slot.  See DESIGN.md Section 2
for the full correspondence table.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field, replace
from fractions import Fraction
from typing import Callable, Iterable, Iterator, Optional, Sequence

import numpy as np

# --------------------------------------------------------------------------
# Dtypes
# --------------------------------------------------------------------------

_DTYPE_BYTES = {
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "i8": 1, "u8": 1, "i16": 2, "i32": 4, "i64": 8, "bool": 1,
    "f8_e4m3": 1, "f8_e5m2": 1,
}


def dtype_bytes(dtype: str) -> int:
    return _DTYPE_BYTES[dtype]


# --------------------------------------------------------------------------
# Values: tensors (Functional) and buffers / streams (Structural)
# --------------------------------------------------------------------------

@dataclass
class TensorValue:
    """An immutable SSA tensor in the Functional dataflow.

    ``dims`` names each axis with the *logical* loop dimension that produces
    it (e.g. ``("batch", "seq", "d_model")``).  These names are what the
    connection analysis (Section 6.5 step 1) aligns across nodes.
    """

    name: str
    shape: tuple[int, ...]
    dtype: str = "bf16"
    dims: tuple[str, ...] = ()
    is_weight: bool = False

    def __post_init__(self) -> None:
        if self.dims and len(self.dims) != len(self.shape):
            raise ValueError(
                f"tensor {self.name}: dims {self.dims} rank != shape {self.shape}")
        if not self.dims:
            self.dims = tuple(f"d{i}" for i in range(len(self.shape)))

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1

    @property
    def bytes(self) -> int:
        return self.size * dtype_bytes(self.dtype)


class MemoryEffect:
    """Per-argument memory effect carried by a Structural ``Node``."""

    READ = "ro"
    WRITE = "wo"
    READ_WRITE = "rw"


@dataclass
class Buffer:
    """Memory-mapped buffer (Structural dataflow).

    ``stages`` is the ping-pong depth (paper Fig. 4 ``depth``); on TPU it is
    the number of staging slots the pipeline runtime rotates through (the
    "soft FIFO" of Section 6.4.2 uses ``stages > 2``).  ``partition`` holds
    per-dimension ``(kind, factor)`` pairs where kind is ``cyclic`` or
    ``block`` — realised as tiled HLO shardings.  ``tiling`` holds per-dim
    tile sizes consumed by the Pallas kernels' BlockSpecs.  ``placement`` is
    one of ``"onchip"`` (VMEM-resident working set), ``"hbm"`` or
    ``"external"`` (host / DCN staged).
    """

    name: str
    shape: tuple[int, ...]
    dtype: str = "bf16"
    dims: tuple[str, ...] = ()
    stages: int = 2
    partition: tuple[tuple[str, int], ...] = ()
    tiling: tuple[int, ...] = ()
    placement: str = "hbm"
    is_weight: bool = False
    # Set by plan.py: mesh-axis assignment per dim, e.g. (("data",), (), ("model",)).
    spec: tuple[tuple[str, ...], ...] | None = None

    def __post_init__(self) -> None:
        if not self.dims:
            self.dims = tuple(f"d{i}" for i in range(len(self.shape)))
        if not self.partition:
            self.partition = tuple(("block", 1) for _ in self.shape)
        if not self.tiling:
            self.tiling = tuple(self.shape)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1

    @property
    def bytes(self) -> int:
        return self.size * dtype_bytes(self.dtype)

    @classmethod
    def from_tensor(cls, t: TensorValue, **kw) -> "Buffer":
        return cls(name=t.name, shape=t.shape, dtype=t.dtype, dims=t.dims,
                   is_weight=t.is_weight, **kw)


@dataclass
class Stream:
    """FIFO stream channel (Structural dataflow)."""

    name: str
    elem_shape: tuple[int, ...]
    dtype: str = "bf16"
    entries: int = 2        # FIFO depth
    is_token: bool = False  # 1-bit token stream for elastic ordering


# --------------------------------------------------------------------------
# Access maps — basis of permutation / scaling maps (Section 6.5 step 1)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class AccessMap:
    """How an op's loop nest touches one tensor/buffer.

    For each tensor dimension, records ``(loop_dim_name | None, stride)``:
    ``loop_dim_name`` is the iteration dimension indexing that axis (None if
    the access broadcasts / reduces over it) and ``stride`` is the access
    stride as a Fraction (paper's scaling map; ``A[i*2][k]`` gives
    stride 2 on that axis).
    """

    entries: tuple[tuple[Optional[str], Fraction], ...]

    @classmethod
    def identity(cls, dims: Sequence[str]) -> "AccessMap":
        return cls(tuple((d, Fraction(1)) for d in dims))

    @classmethod
    def of(cls, *pairs: tuple[Optional[str], int | Fraction]) -> "AccessMap":
        return cls(tuple((d, Fraction(s)) for d, s in pairs))

    def loop_dim_for_axis(self, axis: int) -> Optional[str]:
        return self.entries[axis][0]

    def axes_for_loop_dim(self, dim: str) -> list[int]:
        return [i for i, (d, _) in enumerate(self.entries) if d == dim]


# --------------------------------------------------------------------------
# Operations
# --------------------------------------------------------------------------

_uid = itertools.count()


def fresh_name(prefix: str) -> str:
    return f"{prefix}_{next(_uid)}"


def reset_fresh_names(start: int = 0) -> None:
    """Reseed the global fresh-name counter (golden capture / tests only).

    Generated names (``task_N``, ``copy_N``, ``x_dup_N`` …) embed a global
    counter, so two runs of the same pipeline only produce identical IR
    when both start from the same counter value.  The golden-invariance
    sweep (``tests/test_rewrite.py``) resets before every build so the
    serialized schedules and plans are reproducible bit-for-bit."""
    global _uid
    _uid = itertools.count(start)


@dataclass
class Op:
    """A primitive computation in the dataflow graph.

    ``loop_dims`` is the iteration space (name → trip count); ``flops`` is
    the op intensity (Section 6.5: "number of operations contained by a
    node"); ``access`` maps each input/output value name to an AccessMap
    over ``loop_dims``.
    """

    name: str
    kind: str
    ins: list[str] = field(default_factory=list)
    outs: list[str] = field(default_factory=list)
    loop_dims: dict[str, int] = field(default_factory=dict)
    flops: int = 0
    access: dict[str, AccessMap] = field(default_factory=dict)
    attrs: dict = field(default_factory=dict)
    #: executions per super-block iteration — ops outside the repeated
    #: block (embed / lm-head / loss) amortize as 1/repeat_factor so the
    #: balancing and pipeline II reason about steady-state intensity.
    repeat: float = 1.0

    # --- region support (Tasks / Dispatches own regions) ------------------
    region: list["Op"] = field(default_factory=list)

    @property
    def has_region(self) -> bool:
        return bool(self.region)

    def walk(self, pre: bool = True) -> Iterator["Op"]:
        if pre:
            yield self
        for child in self.region:
            yield from child.walk(pre)
        if not pre:
            yield self

    def intensity(self) -> float:
        """Steady-state flops (amortized by ``repeat``) incl. nested ops."""
        own = self.flops * self.repeat
        if not self.region:
            return own
        return own + sum(c.intensity() for c in self.region)

    def all_ins(self) -> list[str]:
        """Region-transitive inputs (values read, not produced inside)."""
        if not self.region:
            return list(self.ins)
        produced: set[str] = set(self.outs)
        used: list[str] = list(self.ins)
        for c in self.region:
            for v in c.all_ins():
                if v not in produced and v not in used:
                    used.append(v)
            produced.update(c.all_outs())
        return used

    def all_outs(self) -> list[str]:
        if not self.region:
            return list(self.outs)
        outs = list(self.outs)
        for c in self.region:
            for v in c.all_outs():
                if v not in outs:
                    outs.append(v)
        return outs


def make_task(ops: Sequence[Op], name: str | None = None) -> Op:
    """Wrap ``ops`` into a Functional ``task`` (transparent region)."""
    ops = list(ops)
    return Op(name=name or fresh_name("task"), kind="task", region=ops)


def make_dispatch(tasks: Sequence[Op], name: str | None = None) -> Op:
    return Op(name=name or fresh_name("dispatch"), kind="dispatch",
              region=list(tasks))


# --------------------------------------------------------------------------
# Graph: a module holding values + a top-level region
# --------------------------------------------------------------------------

@dataclass
class GraphTopology:
    """Value/hierarchy topology of a :class:`Graph` — the Functional-level
    analogue of :class:`ScheduleTopology`, and the analysis substrate of
    the pre-lowering passes (task fusion above all).

    Holds the value→op indices (which leaf ops produce / consume each
    tensor, in pre-order walk position), the task/dispatch hierarchy
    (parent map), and lazily-memoized per-op rollups (transitive
    produces/consumes sets, steady-state intensity, leaf-kind summaries
    for pattern matching).  Everything depends only on the graph's
    *structure* (op identities, region nesting, ins/outs, flops), so the
    instance is cached on the graph (:meth:`Graph.topology`) against a
    structure signature and survives until a pass restructures the
    region tree.

    Ops are keyed by ``id()``; every keyed op is pinned in ``_pins`` so
    the ids stay unique for the topology's lifetime.  Mutation flows
    exclusively through :class:`repro.core.rewrite.GraphRewriteSession`,
    which maintains the indices in O(Δ) per rewrite and installs the
    updated topology at commit."""

    #: value name -> leaf ops producing / consuming it, in walk order
    producers: dict[str, list[Op]]
    consumers: dict[str, list[Op]]
    #: id(op) -> enclosing region op (None for top-level ops)
    parent: dict[int, Optional[Op]]
    #: structure fingerprint this topology was built against
    signature: tuple
    # Lazy rollup memos (id-keyed; ops pinned below).  Merged tasks get
    # their entries seeded by GraphRewriteSession.fuse in O(1) set ops.
    _produces: dict[int, frozenset] = field(default_factory=dict)
    _consumes: dict[int, frozenset] = field(default_factory=dict)
    _intensity: dict[int, float] = field(default_factory=dict)
    _leaf_meta: dict[int, tuple[Optional[str], frozenset]] = field(
        default_factory=dict)
    _pins: list = field(default_factory=list)

    def _pin(self, op: Op) -> None:
        self._pins.append(op)

    def produces(self, op: Op) -> frozenset:
        """Transitive outputs of ``op`` (region-aware), memoized."""
        s = self._produces.get(id(op))
        if s is None:
            s = frozenset(op.all_outs())
            self._produces[id(op)] = s
            self._pin(op)
        return s

    def consumes(self, op: Op) -> frozenset:
        """Transitive live-in values of ``op`` (region-aware), memoized."""
        s = self._consumes.get(id(op))
        if s is None:
            s = frozenset(op.all_ins())
            self._consumes[id(op)] = s
            self._pin(op)
        return s

    def intensity(self, op: Op) -> float:
        v = self._intensity.get(id(op))
        if v is None:
            v = op.intensity()
            self._intensity[id(op)] = v
            self._pin(op)
        return v

    def leaf_meta(self, op: Op) -> tuple[Optional[str], frozenset]:
        """``(last leaf kind, set of leaf kinds)`` — what the fusion
        patterns match on, without re-walking the region per candidate."""
        m = self._leaf_meta.get(id(op))
        if m is None:
            kinds = [o.kind for o in op.walk() if not o.has_region]
            m = (kinds[-1] if kinds else None, frozenset(kinds))
            self._leaf_meta[id(op)] = m
            self._pin(op)
        return m

    def parent_of(self, op: Op) -> Optional[Op]:
        return self.parent.get(id(op))

    def note_fusion(self, merged: Op, first: Op, second: Op) -> None:
        """Seed the rollup memos for a task fused from ``first`` +
        ``second`` (region order preserved) — O(1) set algebra instead of
        a region re-walk.  ``consumes`` excludes values ``second`` reads
        that ``first`` already produced (they became region-internal).
        Intensity is the one rollup recomputed by walking ``merged``:
        float addition is not associative, so summing the two memoized
        partials could drift an ulp from the sequential region walk and
        flip a tied least-critical fusion choice."""
        pf, ps = self.produces(first), self.produces(second)
        cf, cs = self.consumes(first), self.consumes(second)
        lf, ls = self.leaf_meta(first), self.leaf_meta(second)
        self._produces[id(merged)] = pf | ps
        self._consumes[id(merged)] = cf | (cs - pf)
        self._intensity[id(merged)] = merged.intensity()
        self._leaf_meta[id(merged)] = (ls[0] if ls[0] is not None else lf[0],
                                       lf[1] | ls[1])
        self._pin(merged)

    @classmethod
    def build(cls, graph: "Graph") -> "GraphTopology":
        producers: dict[str, list[Op]] = {}
        consumers: dict[str, list[Op]] = {}
        parent: dict[int, Optional[Op]] = {}
        pins: list = []

        def visit(op: Op, par: Optional[Op]) -> None:
            parent[id(op)] = par
            pins.append(op)
            if not op.has_region:
                for v in op.outs:
                    producers.setdefault(v, []).append(op)
                for v in op.ins:
                    consumers.setdefault(v, []).append(op)
            for c in op.region:
                visit(c, op)

        for top in graph.ops:
            visit(top, None)
        return cls(producers=producers, consumers=consumers, parent=parent,
                   signature=graph.structure_signature(), _pins=pins)


@dataclass
class Graph:
    """Top-level Functional dataflow module (transparent global context)."""

    name: str
    values: dict[str, TensorValue] = field(default_factory=dict)
    ops: list[Op] = field(default_factory=list)
    inputs: list[str] = field(default_factory=list)
    outputs: list[str] = field(default_factory=list)
    # Cached GraphTopology (see topology()); never compared/printed.
    _topology: Optional[GraphTopology] = field(
        default=None, repr=False, compare=False)

    # -- builder interface --------------------------------------------------
    def tensor(self, name: str, shape: Sequence[int], dtype: str = "bf16",
               dims: Sequence[str] = (), is_weight: bool = False,
               is_input: bool = False) -> TensorValue:
        if name in self.values:
            raise ValueError(f"duplicate value {name}")
        t = TensorValue(name, tuple(shape), dtype, tuple(dims), is_weight)
        self.values[name] = t
        if is_input or is_weight:
            self.inputs.append(name)
        return t

    def add(self, op: Op) -> Op:
        self.ops.append(op)
        return op

    def op(self, kind: str, ins: Sequence[str], outs: Sequence[str],
           loop_dims: dict[str, int] | None = None, flops: int = 0,
           access: dict[str, AccessMap] | None = None,
           name: str | None = None, **attrs) -> Op:
        """Create a primitive op; default access maps are identity over the
        value's logical dims restricted to this op's loop dims."""
        loop_dims = dict(loop_dims or {})
        access = dict(access or {})
        for v in list(ins) + list(outs):
            if v not in self.values:
                raise ValueError(f"unknown value {v}")
            if v not in access:
                t = self.values[v]
                access[v] = AccessMap(tuple(
                    (d if d in loop_dims else None, Fraction(1))
                    for d in t.dims))
        o = Op(name=name or fresh_name(kind), kind=kind, ins=list(ins),
               outs=list(outs), loop_dims=loop_dims, flops=flops,
               access=access, attrs=attrs)
        return self.add(o)

    # -- analysis ------------------------------------------------------------
    def walk(self, pre: bool = True) -> Iterator[Op]:
        for op in self.ops:
            yield from op.walk(pre)

    def leaf_ops(self) -> list[Op]:
        return [o for o in self.walk() if not o.has_region]

    def producers(self, value: str) -> list[Op]:
        return list(self.topology().producers.get(value, ()))

    def consumers(self, value: str) -> list[Op]:
        return list(self.topology().consumers.get(value, ()))

    def total_flops(self) -> int:
        return sum(o.flops for o in self.leaf_ops())

    # -- shared topology cache ------------------------------------------------
    def structure_signature(self) -> tuple:
        """Fingerprint of everything :class:`GraphTopology` depends on:
        op identities, region structure (a fused task changes region
        lengths), value ins/outs, and the intensity inputs (flops,
        repeat)."""
        return tuple(
            (o.name, o.kind, len(o.region), tuple(o.ins), tuple(o.outs),
             o.flops, o.repeat)
            for o in self.walk())

    def topology(self) -> GraphTopology:
        """The cached :class:`GraphTopology`, rebuilt transparently when
        the structure signature no longer matches (sessionless external
        surgery; the pass pipeline itself — construction included —
        commits maintained topologies, so its boundaries are cache
        hits)."""
        if (self._topology is None
                or self._topology.signature != self.structure_signature()):
            self._topology = GraphTopology.build(self)
        return self._topology

    def invalidate_topology(self) -> None:
        self._topology = None


# --------------------------------------------------------------------------
# Structural dataflow
# --------------------------------------------------------------------------

@dataclass
class Node:
    """Structural dataflow node: isolated region with explicit effects.

    ``args`` maps value name → MemoryEffect.  The body is the list of leaf
    ops that were fused into this node (kept for intensity / access-map
    queries during parallelization).  ``params`` mirrors the paper's
    constant-parameter list (compile-time attributes).
    """

    name: str
    args: dict[str, str] = field(default_factory=dict)
    body: list[Op] = field(default_factory=list)
    params: dict = field(default_factory=dict)
    # Filled by the parallelizer: loop dim -> sharding factor, and
    # loop dim -> mesh axes tuple.
    unroll: dict[str, int] = field(default_factory=dict)
    axis_map: dict[str, tuple[str, ...]] = field(default_factory=dict)
    # Filled by balance/schedule passes.
    stage: int = 0
    sub_schedule: Optional["Schedule"] = None

    def intensity(self) -> float:
        return sum(o.intensity() for o in self.body)

    @property
    def repeat(self) -> float:
        return max((o.repeat for o in self.body), default=1.0)

    def loop_dims(self) -> dict[str, int]:
        dims: dict[str, int] = {}
        for o in self.body:
            for d, n in o.loop_dims.items():
                dims[d] = max(dims.get(d, 0), n)
        return dims

    def reads(self) -> list[str]:
        return [v for v, e in self.args.items()
                if e in (MemoryEffect.READ, MemoryEffect.READ_WRITE)]

    def writes(self) -> list[str]:
        return [v for v, e in self.args.items()
                if e in (MemoryEffect.WRITE, MemoryEffect.READ_WRITE)]

    def access_for(self, value: str) -> Optional[AccessMap]:
        """Merged access map for ``value`` across body ops.

        Per tensor axis, the entry of the *earliest* body op whose map
        names a loop dim at that axis wins; axes no body op indexes stay
        ``(None, stride-of-first-map)``.  A node fused from several ops
        can touch the same buffer with complementary maps (e.g. a copy
        indexing axis 0 and a compute op indexing axis 1) — returning the
        first op's map wholesale would hide every later op's dims from
        plan projection and the connection analysis (the same first-owner
        hazard class ``project_rules`` had across *nodes*)."""
        maps = [o.access[value] for o in self.body if value in o.access]
        if not maps:
            return None
        first = maps[0]
        if len(maps) == 1:
            return first
        rank = max(len(m.entries) for m in maps)
        entries = []
        for axis in range(rank):
            chosen = None
            for m in maps:
                if axis < len(m.entries) and m.entries[axis][0] is not None:
                    chosen = m.entries[axis]
                    break
            if chosen is None:
                chosen = (first.entries[axis] if axis < len(first.entries)
                          else (None, Fraction(1)))
            entries.append(chosen)
        merged = tuple(entries)
        return first if merged == first.entries else AccessMap(merged)


def topo_order_over(nodes: Sequence["Node"],
                    edges: Iterable[tuple[str, str, str]],
                    name: str = "") -> list["Node"]:
    """Stable topological order of ``nodes`` over ``edges`` — the shared
    walk behind :meth:`Schedule.topo_order` and the rewrite session's
    in-flight queries (which run it over Δ-maintained edges instead of
    rebuilding the schedule topology).

    O(V + E log E): a name→node map and per-node successor lists sorted
    by node position replace the former all-nodes rescan per pop, while
    visiting successors in exactly the node-list order the rescan did —
    the emitted order is unchanged."""
    by_name = {n.name: n for n in nodes}
    pos = {n.name: i for i, n in enumerate(nodes)}
    succ: dict[str, set[str]] = {n.name: set() for n in nodes}
    indeg: dict[str, int] = {n.name: 0 for n in nodes}
    for s, d, _ in edges:
        if d not in succ[s]:
            succ[s].add(d)
            indeg[d] += 1
    order: list[Node] = []
    ready = deque(n for n in nodes if indeg[n.name] == 0)
    while ready:
        n = ready.popleft()
        order.append(n)
        for m in sorted(succ[n.name], key=pos.__getitem__):
            indeg[m] -= 1
            if indeg[m] == 0:
                ready.append(by_name[m])
    if len(order) != len(nodes):
        raise ValueError(f"schedule {name} has a dataflow cycle")
    return order


def depth_map_over(nodes: Sequence["Node"],
                   edges: Iterable[tuple[str, str, str]],
                   name: str = "") -> dict[str, int]:
    """Longest-path depth per node over ``edges`` (see
    :func:`topo_order_over`)."""
    edges = list(edges)
    depth = {n.name: 0 for n in nodes}
    succ: dict[str, list[str]] = {n.name: [] for n in nodes}
    for s, d, _ in edges:
        succ[s].append(d)
    for n in topo_order_over(nodes, edges, name):
        for d in succ[n.name]:
            depth[d] = max(depth[d], depth[n.name] + 1)
    return depth


@dataclass
class TokenEdge:
    """Elastic token-flow edge (Section 6.4.2): ``src`` must complete an
    iteration before ``dst`` may start; realised on TPU as a data dependency
    or an ``optimization_barrier`` for host-offload DMA ordering."""

    src: str
    dst: str


@dataclass
class ScheduleTopology:
    """Edge/access topology of a :class:`Schedule` — the shared analysis
    substrate of the QoR estimator and the plan-projection engine.

    Everything here depends only on the schedule's *structure* (nodes,
    args, buffers, body-op access maps), never on the parallelization
    state (``unroll`` / ``axis_map``), so one build serves the whole
    optimize() pipeline from the DSE through plan derivation and the
    incremental EP-widening re-projection.  Obtain it through
    :meth:`Schedule.topology`, which caches it against a structure
    signature and rebuilds transparently after structural mutation
    (multi-producer elimination, balancing copies, …).
    """

    #: per buffer: producing / consuming nodes, in node order (matching
    #: ``Schedule.producers_of`` / ``consumers_of``)
    producers: dict[str, list[Node]]
    consumers: dict[str, list[Node]]
    #: (src_node, dst_node, buffer) shared-buffer edges (``Schedule.edges``)
    edges: list[tuple[str, str, str]]
    #: per buffer axis: the (owner node, loop dim) pairs with a non-None
    #: access-map entry at that axis, in owner (producers + consumers) order
    axis_owner_dims: dict[str, tuple[tuple[tuple[Node, str], ...], ...]]
    #: per buffer axis: the coherent projection dim — the first non-None
    #: loop dim any owner's access map names at that axis (None if none)
    axis_dims: dict[str, tuple[Optional[str], ...]]
    #: loop dim -> buffers whose coherent projection references it
    buffers_of_dim: dict[str, tuple[str, ...]]
    #: (node name, value name) -> merged access map (``Node.access_for``)
    _access: dict[tuple[str, str], Optional[AccessMap]]
    #: structure fingerprint this topology was built against
    signature: tuple
    #: lazily memoized topo order / longest-path depth map.  Safe to cache
    #: here: the topology object itself is rebuilt (via the signature check
    #: in ``Schedule.topology``) whenever the structure changes, so these
    #: can never go stale independently of the object that owns them.
    _order_memo: Optional[list[Node]] = field(
        default=None, repr=False, compare=False)
    _depth_memo: Optional[dict[str, int]] = field(
        default=None, repr=False, compare=False)

    def topo_order(self, nodes: list[Node], name: str) -> list[Node]:
        """Memoized ``topo_order_over`` — the walk runs once per topology
        build, then every caller gets a fresh list copy."""
        if self._order_memo is None:
            self._order_memo = topo_order_over(nodes, self.edges, name)
        return list(self._order_memo)

    def depth_of(self, nodes: list[Node], name: str) -> dict[str, int]:
        """Memoized ``depth_map_over`` — one relaxation pass per topology
        build, fresh dict copies out."""
        if self._depth_memo is None:
            self._depth_memo = depth_map_over(nodes, self.edges, name)
        return dict(self._depth_memo)

    def access_for(self, node: Node, value: str) -> Optional[AccessMap]:
        """Cached ``node.access_for(value)``."""
        key = (node.name, value)
        if key not in self._access:
            self._access[key] = node.access_for(value)
        return self._access[key]

    def owners(self, buf: str) -> list[Node]:
        """Producers then consumers — the scan order of plan projection."""
        return self.producers.get(buf, []) + self.consumers.get(buf, [])

    @classmethod
    def build(cls, sched: "Schedule") -> "ScheduleTopology":
        producers: dict[str, list[Node]] = {}
        consumers: dict[str, list[Node]] = {}
        for n in sched.nodes:
            for b in n.writes():
                producers.setdefault(b, []).append(n)
            for b in n.reads():
                consumers.setdefault(b, []).append(n)
        edges = []
        for buf in sched.buffers:
            for p in producers.get(buf, ()):
                for c in consumers.get(buf, ()):
                    if p.name != c.name:
                        edges.append((p.name, c.name, buf))
        access: dict[tuple[str, str], Optional[AccessMap]] = {}
        axis_owner_dims: dict[str, tuple] = {}
        axis_dims: dict[str, tuple] = {}
        buffers_of_dim: dict[str, list[str]] = {}
        for bname, buf in sched.buffers.items():
            owners = producers.get(bname, []) + consumers.get(bname, [])
            per_axis: list[tuple[tuple[Node, str], ...]] = []
            dims: list[Optional[str]] = []
            for axis in range(len(buf.shape)):
                pairs = []
                for node in owners:
                    key = (node.name, bname)
                    if key not in access:
                        access[key] = node.access_for(bname)
                    am = access[key]
                    if am is None or axis >= len(am.entries):
                        continue
                    d = am.entries[axis][0]
                    if d is not None:
                        pairs.append((node, d))
                per_axis.append(tuple(pairs))
                dims.append(pairs[0][1] if pairs else None)
            axis_owner_dims[bname] = tuple(per_axis)
            axis_dims[bname] = tuple(dims)
            for d in dims:
                if d is not None and (d not in buffers_of_dim
                                      or buffers_of_dim[d][-1] != bname):
                    buffers_of_dim.setdefault(d, []).append(bname)
        return cls(
            producers=producers, consumers=consumers, edges=edges,
            axis_owner_dims=axis_owner_dims, axis_dims=axis_dims,
            buffers_of_dim={d: tuple(v) for d, v in buffers_of_dim.items()},
            _access=access, signature=sched.structure_signature())


def topology_index_bytes(topo: ScheduleTopology) -> int:
    """Logical footprint of a :class:`ScheduleTopology`'s caches, in bytes.

    Counts 8 bytes (one machine word) per stored reference/entry across
    the edge list, the per-buffer producer/consumer lists, the per-axis
    owner tables, the access-map memo and the order/depth memos.  This is
    a *representation-comparable* measure (like ``region_index_bytes`` in
    ``core.rewrite``), not an exact ``sys.getsizeof`` sum — it is what the
    ``bench_compile_time`` memory gate tracks so a regression in cache
    growth shows up as a number, independent of CPython object overhead.
    """
    total = 8 * 3 * len(topo.edges)
    for m in (topo.producers, topo.consumers):
        total += sum(8 * (1 + len(v)) for v in m.values())
    for per_axis in topo.axis_owner_dims.values():
        total += sum(8 * 2 * len(pairs) for pairs in per_axis)
    total += sum(8 * (1 + len(v)) for v in topo.axis_dims.values())
    total += sum(8 * (1 + len(v)) for v in topo.buffers_of_dim.values())
    total += 8 * 2 * len(topo._access)
    if topo._order_memo is not None:
        total += 8 * len(topo._order_memo)
    if topo._depth_memo is not None:
        total += 8 * 2 * len(topo._depth_memo)
    return total


@dataclass
class Schedule:
    """Structural dataflow schedule: isolated region of nodes + buffers."""

    name: str
    nodes: list[Node] = field(default_factory=list)
    buffers: dict[str, Buffer] = field(default_factory=dict)
    streams: dict[str, Stream] = field(default_factory=dict)
    # Values passed in from the enclosing context (external buffers).
    args: list[str] = field(default_factory=list)
    outputs: list[str] = field(default_factory=list)
    tokens: list[TokenEdge] = field(default_factory=list)
    # Byte size of every value (incl. node-internal temporaries) — used by
    # the estimator for intra-node reduction-collective costs.
    value_bytes: dict[str, int] = field(default_factory=dict)
    # Cached ScheduleTopology (see topology()); never compared/printed.
    _topology: Optional[ScheduleTopology] = field(
        default=None, repr=False, compare=False)
    # Lazy name→Node map behind node(); validated by list length (nodes
    # are only ever inserted, never replaced in place) and by re-checking
    # the hit's name (in-place renames).  Never compared/printed.
    _node_cache: Optional[dict] = field(
        default=None, repr=False, compare=False)
    _node_cache_len: int = field(default=-1, repr=False, compare=False)

    def node(self, name: str) -> Node:
        """Look up a node by name — O(1) amortized via a lazily rebuilt
        dict (the former linear scan was O(n²) aggregate at 1k+ nodes)."""
        cache = self._node_cache
        if cache is None or self._node_cache_len != len(self.nodes):
            cache = {n.name: n for n in self.nodes}
            self._node_cache = cache
            self._node_cache_len = len(self.nodes)
        hit = cache.get(name)
        if hit is None or hit.name != name:
            cache = {n.name: n for n in self.nodes}
            self._node_cache = cache
            self._node_cache_len = len(self.nodes)
            hit = cache.get(name)
            if hit is None:
                raise KeyError(name)
        return hit

    # -- shared topology cache ------------------------------------------------
    def structure_signature(self) -> tuple:
        """Cheap fingerprint of everything :class:`ScheduleTopology` depends
        on: node identities, their argument effects and body sizes (access
        maps live in body ops; structural passes that rewire them always
        rename args or insert ops too), buffer shapes/dims (axis_dims is
        per buffer axis), and the buffer/arg sets.  The parallelization
        state (``unroll`` / ``axis_map``) is deliberately excluded —
        topology is assignment-independent."""
        return (
            tuple((n.name, tuple(n.args.items()), len(n.body))
                  for n in self.nodes),
            tuple((b, buf.shape, buf.dims)
                  for b, buf in self.buffers.items()),
            tuple(self.args))

    def topology(self) -> ScheduleTopology:
        """The cached :class:`ScheduleTopology`, rebuilt transparently when
        the structure signature no longer matches (e.g. after
        multi-producer elimination or balancing inserted nodes)."""
        if (self._topology is None
                or self._topology.signature != self.structure_signature()):
            self._topology = ScheduleTopology.build(self)
        return self._topology

    def invalidate_topology(self) -> None:
        self._topology = None

    def is_internal(self, buf: str) -> bool:
        """A buffer allocated inside this schedule (not an argument).

        Internal buffers admit the duplication transform of Alg. 3 case (1);
        external buffers require producer fusion (case 2)."""
        return buf in self.buffers and buf not in self.args

    def producers_of(self, buf: str) -> list[Node]:
        """Nodes writing ``buf``, in node order (topology-served)."""
        return list(self.topology().producers.get(buf, ()))

    def consumers_of(self, buf: str) -> list[Node]:
        """Nodes reading ``buf``, in node order (topology-served)."""
        return list(self.topology().consumers.get(buf, ()))

    def internal_buffers(self) -> list[str]:
        return [b for b in self.buffers if self.is_internal(b)]

    def external_buffers(self) -> list[str]:
        return [b for b in self.args if b in self.buffers]

    # -- DAG helpers ---------------------------------------------------------
    def edges(self) -> list[tuple[str, str, str]]:
        """(src_node, dst_node, buffer) edges via shared buffers.

        Served from the cached :class:`ScheduleTopology` (one pass over
        the nodes builds the per-buffer producer/consumer lists in node
        order, matching ``producers_of``/``consumers_of``)."""
        return list(self.topology().edges)

    def happens_before_edges(self) -> list[tuple[str, str, str]]:
        """Dataflow edges plus token edges (buffer slot ``"<token>"``) —
        the happens-before relation the static hazard analyzer
        (:mod:`repro.core.analyze`) walks for write-ordering and cycle
        checks.  Token edges are ordering-only (Section 6.4.2), so they
        extend reachability without adding data traffic."""
        return self.edges() + [(t.src, t.dst, "<token>")
                               for t in self.tokens]

    def topo_order(self) -> list[Node]:
        """Topological order over buffer edges (stable; raises on cycles
        between distinct nodes, ignoring self-loops from RW args).

        Memoized on the cached topology: repeated calls between structural
        mutations cost one list copy, not a fresh Kahn walk — the balance
        and stage-assignment passes call this per candidate at scale."""
        return self.topology().topo_order(self.nodes, self.name)

    def depth_of(self) -> dict[str, int]:
        """Longest-path depth per node (for data-path balancing).

        Memoized on the cached topology (same contract as
        :meth:`topo_order`)."""
        return self.topology().depth_of(self.nodes, self.name)

    # -- serialisation --------------------------------------------------------
    def to_dict(self) -> dict:
        """Complete, deterministic structural dump — node order, buffer
        order, argument effects, body ops with access maps, balancing
        tokens and parallelization state all included.  Two schedules are
        structurally identical iff their dicts (and hence ``to_json``
        strings) are equal; the golden-invariance sweep in
        ``tests/test_rewrite.py`` pins the whole pre-DSE pipeline on it."""
        def am(m: AccessMap) -> list:
            return [[d, str(s)] for d, s in m.entries]

        def op_d(o: Op) -> dict:
            return {
                "name": o.name, "kind": o.kind, "ins": list(o.ins),
                "outs": list(o.outs), "loop_dims": dict(o.loop_dims),
                "flops": o.flops, "repeat": o.repeat,
                "access": {v: am(m) for v, m in o.access.items()},
                "attrs": {k: repr(v) for k, v in sorted(o.attrs.items())},
                "region": [op_d(c) for c in o.region],
            }

        def node_d(n: Node) -> dict:
            return {
                "name": n.name, "args": dict(n.args), "stage": n.stage,
                "params": {k: repr(v) for k, v in sorted(n.params.items())},
                "unroll": dict(n.unroll),
                "axis_map": {d: list(a) for d, a in n.axis_map.items()},
                "body": [op_d(o) for o in n.body],
                "sub_schedule": (n.sub_schedule.to_dict()
                                 if n.sub_schedule is not None else None),
            }

        def buf_d(b: Buffer) -> dict:
            return {
                "name": b.name, "shape": list(b.shape), "dtype": b.dtype,
                "dims": list(b.dims), "stages": b.stages,
                "partition": [[k, f] for k, f in b.partition],
                "tiling": list(b.tiling), "placement": b.placement,
                "is_weight": b.is_weight,
                "spec": ([list(a) for a in b.spec]
                         if b.spec is not None else None),
            }

        return {
            "name": self.name,
            "args": list(self.args),
            "outputs": list(self.outputs),
            "nodes": [node_d(n) for n in self.nodes],
            "buffers": {b: buf_d(buf) for b, buf in self.buffers.items()},
            "streams": {s: {"name": st.name,
                            "elem_shape": list(st.elem_shape),
                            "dtype": st.dtype, "entries": st.entries,
                            "is_token": st.is_token}
                        for s, st in self.streams.items()},
            "tokens": [[t.src, t.dst] for t in self.tokens],
            "value_bytes": dict(self.value_bytes),
        }

    def to_json(self) -> str:
        import json
        return json.dumps(self.to_dict(), indent=1)
