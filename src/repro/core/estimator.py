"""QoR estimator: TPU v5e roofline model (paper Section 6.5 uses the
ScaleHLS Vitis QoR model; the TPU port replaces DSP/BRAM/LUT with the
compute / HBM / ICI roofline triple).

The estimator scores a Structural schedule under a candidate
parallelization (per-node ``unroll`` factors + mesh-axis assignment):

* compute term   = node FLOPs / (parallel_factor · peak FLOP/s)
* memory term    = node HBM bytes touched / (parallel_factor · HBM BW)
* collective term = resharding + sync bytes / (chips · ICI BW)

Node latency is ``max`` of the three (roofline); schedule latency is the
sum over nodes (one XLA step) and the pipeline initiation interval is the
critical node (paper: "the critical task determines the overall achievable
performance").  The same constants drive EXPERIMENTS.md §Roofline, where
the estimate is cross-checked against ``compiled.cost_analysis()`` and
collective bytes parsed from post-SPMD HLO.

``estimate()`` here is the **batch reference**: a single full-schedule
pass, O(nodes × ops).  The parallelizer's DSE scores thousands of
single-node proposals and therefore runs on
:class:`repro.core.incremental.IncrementalEstimator`, which caches the
unroll-independent structure and re-scores one proposal in O(deg) —
bit-identical to this module by construction (asserted across every
config by ``tests/test_incremental.py``).  Changes to the cost model must
be made in *both* places; the equivalence tests will catch a drift.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .ir import Buffer, Node, Schedule

# TPU v5e per-chip constants (assignment-specified).
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
FIXED_NODE_OVERHEAD_S = 2e-6  # kernel launch / fusion boundary overhead


@dataclass(frozen=True)
class MeshSpec:
    """Ordered mesh axes, e.g. (("data", 16), ("model", 16))."""

    axes: tuple[tuple[str, int], ...]

    @property
    def chips(self) -> int:
        n = 1
        for _, s in self.axes:
            n *= s
        return n

    def size(self, axis: str) -> int:
        for a, s in self.axes:
            if a == axis:
                return s
        raise KeyError(axis)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(a for a, _ in self.axes)


SINGLE_POD = MeshSpec((("data", 16), ("model", 16)))
MULTI_POD = MeshSpec((("pod", 2), ("data", 16), ("model", 16)))


def node_parallel_factor(node: Node) -> int:
    f = 1
    for v in node.unroll.values():
        f *= v
    return max(f, 1)


def buffer_shard_factor(buf: Buffer, node: Node) -> int:
    """How many ways this node's factors shard the buffer, via its access
    map (a loop dim only shards the buffer axes it indexes)."""
    am = node.access_for(buf.name)
    if am is None:
        return 1
    f = 1
    for axis, (dim, _stride) in enumerate(am.entries):
        if dim is not None and dim in node.unroll:
            f *= min(node.unroll[dim], buf.shape[axis])
    return max(f, 1)


def tree_sum(values) -> float:
    """Sum floats in a fixed perfect-binary-tree order.

    The reduction shape depends only on ``len(values)`` (leaves padded
    with ``0.0`` to the next power of two, then summed pairwise level by
    level), never on the values.  Two properties make this the summation
    contract of the whole QoR layer:

    * a *point update* recomputes only the leaf-to-root path and lands on
      bit-exactly the same root a from-scratch reduction would produce —
      which is what lets :class:`~repro.core.incremental.IncrementalEstimator`
      maintain ``total_s`` / ``hbm_bytes_per_device`` as O(log n)
      segment trees while staying bit-identical to this batch path
      (sequential left-to-right ``sum()`` has no such property: a
      running total diverges from a re-sum after the first non-exact
      add);
    * the tree depth is O(log n), so the roundoff of a 10k-node total is
      bounded by ~14 adds instead of ~10k.

    Every totals consumer (batch ``estimate()``, the incremental engine,
    ``score()``) must reduce through this same shape — mixing orders
    breaks the engine-vs-batch bitwise equivalence pinned by
    ``tests/test_incremental.py``.
    """
    level = list(values)
    if not level:
        return 0.0
    size = 1
    while size < len(level):
        size *= 2
    level.extend([0.0] * (size - len(level)))
    while len(level) > 1:
        level = [level[i] + level[i + 1] for i in range(0, len(level), 2)]
    return level[0]


@dataclass
class NodeCost:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def latency_s(self) -> float:
        return max(self.compute_s, self.memory_s,
                   self.collective_s) + FIXED_NODE_OVERHEAD_S

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


@dataclass
class ScheduleCost:
    nodes: dict[str, NodeCost] = field(default_factory=dict)
    reshard_bytes: int = 0
    sync_bytes: int = 0
    hbm_bytes_per_device: int = 0

    @property
    def total_s(self) -> float:
        return tree_sum([c.latency_s for c in self.nodes.values()])

    @property
    def critical_s(self) -> float:
        """Pipeline initiation interval: the critical node."""
        return max((c.latency_s for c in self.nodes.values()), default=0.0)

    @property
    def dominant(self) -> str:
        agg = {"compute": 0.0, "memory": 0.0, "collective": 0.0}
        for c in self.nodes.values():
            agg["compute"] += c.compute_s
            agg["memory"] += c.memory_s
            agg["collective"] += c.collective_s
        return max(agg, key=agg.get)


def _bytes_touched(node: Node, sched: Schedule) -> float:
    """Per-device HBM traffic of the node: every argument buffer, sharded
    by this node's factors (weights stream once; activations read+write),
    amortized by the node's per-iteration repeat."""
    total = 0.0
    for v in node.args:
        buf = sched.buffers.get(v)
        if buf is None:
            continue
        total += buf.bytes / buffer_shard_factor(buf, node)
    return total * node.repeat


def _op_out_shard(op, out: str, unroll: dict[str, int]) -> int:
    am = op.access.get(out)
    if am is None:
        return 1
    f = 1
    for dim, _ in am.entries:
        if dim is not None:
            f *= unroll.get(dim, 1)
    return max(f, 1)


def _reduction_bytes(node: Node, sched: Schedule) -> float:
    """Intra-node collective cost: sharding a *reduction* loop dim (one
    that appears in an input's access but no output's — a matmul
    contraction, a norm reduction, a dispatch scatter axis) forces an
    all-reduce / all-to-all of the op's outputs across that axis.  This is
    the cost that makes contraction-dim sharding lose the DSE unless the
    dim is genuinely the only parallelism left."""
    total = 0.0
    for op in node.body:
        out_dims: set[str] = set()
        for v in op.outs:
            am = op.access.get(v)
            if am:
                out_dims.update(d for d, _ in am.entries if d)
        in_dims: set[str] = set()
        for v in op.ins:
            am = op.access.get(v)
            if am:
                in_dims.update(d for d, _ in am.entries if d)
        red = (in_dims - out_dims) | set(op.attrs.get("reduce", ()))
        k = 1
        for d in red:
            k *= node.unroll.get(d, 1)
        if k <= 1:
            continue
        out_bytes = sum(
            sched.value_bytes.get(v, 0) / _op_out_shard(op, v, node.unroll)
            for v in op.outs)
        total += 2.0 * out_bytes * (k - 1) / k * op.repeat
    return total


class EstimateContext:
    """Precomputed schedule topology — parallelize() evaluates hundreds of
    proposals per node, so the O(buffers·nodes²) edge scan is hoisted."""

    def __init__(self, sched: Schedule):
        # One topology() call for the whole build: consumers_of() would
        # re-validate the topology cache (an O(nodes) signature walk) per
        # buffer, turning this constructor O(buffers·nodes) at 1k+ nodes.
        topo = sched.topology()
        self.edges = list(topo.edges)
        self.consumers = {b: list(topo.consumers.get(b, ()))
                          for b in sched.buffers}
        self.weight_buffers = [b for b, buf in sched.buffers.items()
                               if buf.is_weight]
        self.by_name = {n.name: n for n in sched.nodes}


def _reshard_bytes(sched: Schedule, ctx: EstimateContext) -> dict[str, int]:
    """Per-consumer-node resharding bytes: when a shared buffer's effective
    sharding differs between producer and consumer, XLA inserts an
    all-to-all / all-gather whose per-device payload is roughly the local
    shard (CA's divisibility constraint is what avoids this)."""
    out: dict[str, int] = {}
    for src, dst, bname in ctx.edges:
        p = ctx.by_name[src]
        c = ctx.by_name[dst]
        buf = sched.buffers[bname]
        pam, cam = p.access_for(bname), c.access_for(bname)
        if pam is None or cam is None:
            continue
        mismatch = False
        for axis in range(len(buf.shape)):
            pdim = pam.entries[axis][0]
            cdim = cam.entries[axis][0]
            paxes = tuple(p.axis_map.get(pdim, ())) if pdim else ()
            caxes = tuple(c.axis_map.get(cdim, ())) if cdim else ()
            # Strict: any layout difference on a shared buffer pays a
            # reshard (GSPMD all-gathers / all-to-alls at the boundary);
            # this is what drives CA chains to align fully instead of
            # merely being divisible.
            if paxes != caxes:
                mismatch = True
        if mismatch:
            shard = buf.bytes // max(
                buffer_shard_factor(buf, p), 1)
            out[dst] = out.get(dst, 0) + shard
    return out


def _weight_sync_bytes(sched: Schedule, mesh: MeshSpec,
                       training: bool, ctx: EstimateContext
                       ) -> dict[str, int]:
    """Gradient reduce-scatter + all-gather bytes per producing node for
    weight buffers, over the mesh axes that do NOT shard the weight."""
    if not training:
        return {}
    out: dict[str, int] = {}
    for bname in ctx.weight_buffers:
        buf = sched.buffers[bname]
        consumers = ctx.consumers.get(bname, ())
        if not consumers:
            continue
        n = consumers[0]
        shard = buf.bytes // max(buffer_shard_factor(buf, n), 1)
        # The gradient must be summed over every mesh axis that does NOT
        # shard the weight itself (axes assigned to dims the weight's
        # access map does not touch — i.e. pure batch/seq parallelism).
        am = n.access_for(bname)
        w_dims = {d for d, _ in am.entries if d} if am else set()
        w_axes = {a for d in w_dims for a in n.axis_map.get(d, ())}
        sync_ways = 1
        for a, s in mesh.axes:
            if a not in w_axes:
                sync_ways *= s
        if sync_ways > 1:
            # reduce-scatter + all-gather ≈ 2·bytes·(k-1)/k per device,
            # amortized to per-iteration cost like everything else.
            out[n.name] = out.get(n.name, 0) + int(
                2 * shard * (sync_ways - 1) / sync_ways * n.repeat)
    return out


def estimate(sched: Schedule, mesh: MeshSpec, training: bool = True,
             ctx: EstimateContext | None = None) -> ScheduleCost:
    cost = ScheduleCost()
    ctx = ctx or EstimateContext(sched)
    reshard = _reshard_bytes(sched, ctx)
    sync = _weight_sync_bytes(sched, mesh, training, ctx)
    hbm: list[float] = []
    for node in sched.nodes:
        pf = node_parallel_factor(node)
        flops = node.intensity()
        nbytes = _bytes_touched(node, sched)
        coll = (reshard.get(node.name, 0) + sync.get(node.name, 0)
                + _reduction_bytes(node, sched))
        cost.nodes[node.name] = NodeCost(
            compute_s=flops / pf / PEAK_FLOPS,
            memory_s=nbytes / HBM_BW,
            collective_s=coll / ICI_BW,
        )
        hbm.append(nbytes)
    cost.reshard_bytes = sum(reshard.values())
    cost.sync_bytes = sum(sync.values())
    # Same tree shape as the incremental engine's nbytes segment tree —
    # see tree_sum's contract.
    cost.hbm_bytes_per_device = int(tree_sum(hbm))
    return cost


def roofline_terms(flops: float, bytes_hbm: float, bytes_coll: float,
                   chips: int) -> dict[str, float]:
    """The §Roofline triple for EXPERIMENTS.md, from dry-run totals."""
    return {
        "compute_s": flops / (chips * PEAK_FLOPS),
        "memory_s": bytes_hbm / (chips * HBM_BW),
        "collective_s": bytes_coll / (chips * ICI_BW),
    }
