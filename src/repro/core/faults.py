"""Deterministic fault injection for the compile pipeline.

The degradation ladder in :func:`repro.core.optimize.optimize` claims
that *any* failure inside a structural pass, the DSE, or plan
projection degrades to a verifier-clean plan instead of an exception.
That claim is only testable if failures can be manufactured on demand,
deterministically, at the exact boundaries the ladder defends.  This
module provides the harness:

* Every pass exposes named **injection sites** — cheap
  :func:`fault_point` calls at the top of each rewrite step
  (``"fusion.pattern"``, ``"mp.merge"``, …), plus
  :func:`corrupt_value` hooks where a *wrong number* is more damaging
  than an exception (DSE proposal scoring).
* :func:`inject_faults` activates a seeded :class:`FaultInjector` for
  the dynamic extent of a ``with`` block.  Each site visit draws from
  one ``random.Random(seed)`` stream in call order, so a fixed
  ``(seed, rate, sites)`` configuration reproduces the exact same
  failure pattern on every run — chaos tests are regular regression
  tests, not flaky ones.
* When no injector is active every hook is a single global-load +
  ``is None`` check, and **zero** RNG draws happen — the zero-fault
  path is bit-identical to a build without the harness (the golden
  tests in ``tests/test_faults.py`` pin this).

Registered sites (kept in sync with docs/ARCHITECTURE.md):

===================  =====================================================
site                 location
===================  =====================================================
``construct.wrap``   per dispatch-region wrap in ``construct_functional``
``fusion.pattern``   per pattern-phase fuse in ``fuse_tasks``
``fusion.balance``   per balance-phase fuse in ``fuse_tasks``
``lower.node``       per task lowered in ``lower_to_structural``
``mp.duplicate``     per internal-duplication rewrite in multi-producer
``mp.merge``         per producer-merge rewrite in multi-producer
``balance.edge``     per skewed edge rewritten in ``balance_paths``
``dse.node``         per per-node DSE in ``parallelize``
``dse.score``        proposal scoring (corruption site: perturbs QoR)
``dse.joint``        per joint beam move in ``parallelize``
``dse.inner``        per region inner search in the hierarchical DSE
``dse.outer``        outer composition entry + per combo swap move
``plan.build``       ``build_plan`` entry
``plan.project``     per-buffer projection in ``project_rules``
``plan.delta``       ``ShardingPlan.apply_rule_change`` entry
``cache.load``       per disk read in ``PlanCache.get`` (plan cache)
``cache.store``      per disk write in ``PlanCache.put`` (plan cache)
``analyze.rules``    per analysis rule in ``analyze()`` (hazard lint)
===================  =====================================================

Sites accept :mod:`fnmatch` patterns, so a sweep can target one pass
(``sites=("fusion.*",)``) or everything (the default ``("*",)``).
"""
from __future__ import annotations

import fnmatch
import random
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Iterator, Sequence

__all__ = ["InjectedFault", "FaultRecord", "FaultInjector", "inject_faults",
           "fault_point", "corrupt_value", "active_injector"]


class InjectedFault(RuntimeError):
    """Raised by :func:`fault_point` when the active injector fires.

    Deliberately a plain ``RuntimeError`` subclass: the degradation
    ladder must catch injected faults through the *same* ``except
    Exception`` boundaries that catch organic bugs — nothing in the
    production path is allowed to special-case this type."""

    def __init__(self, site: str):
        super().__init__(f"injected fault at site {site!r}")
        self.site = site


@dataclass(frozen=True)
class FaultRecord:
    """One fired injection, for post-hoc assertions in chaos tests."""
    site: str
    kind: str  # "raise" | "corrupt"


class FaultInjector:
    """Seeded probabilistic fault source.  Use via :func:`inject_faults`.

    Args:
        seed: seeds the single ``random.Random`` stream all sites share;
            same seed + same site-visit order ⇒ same failures.
        rate: probability that a :func:`fault_point` visit raises
            :class:`InjectedFault`.
        corrupt_rate: probability that a :func:`corrupt_value` visit
            perturbs the value instead of passing it through.
        sites: :mod:`fnmatch` patterns selecting which sites are armed.
            Visits to unarmed sites draw nothing, so each
            ``(seed, rate, sites)`` configuration is deterministic on
            its own terms (different ``sites`` filters are different
            draw streams — compare runs only within one config).
        corrupt_scale: relative half-width of the multiplicative
            perturbation applied by :func:`corrupt_value`.
    """

    def __init__(self, seed: int = 0, rate: float = 0.05,
                 corrupt_rate: float = 0.0,
                 sites: Sequence[str] = ("*",),
                 corrupt_scale: float = 0.5):
        self.seed = seed
        self.rate = rate
        self.corrupt_rate = corrupt_rate
        self.sites = tuple(sites)
        self.corrupt_scale = corrupt_scale
        self.records: list[FaultRecord] = []
        self._rng = random.Random(seed)

    # -- queries ---------------------------------------------------------
    def fired(self, pattern: str = "*") -> list[FaultRecord]:
        return [r for r in self.records
                if fnmatch.fnmatchcase(r.site, pattern)]

    def _armed(self, site: str) -> bool:
        return any(fnmatch.fnmatchcase(site, p) for p in self.sites)

    # -- hooks -----------------------------------------------------------
    def fire(self, site: str) -> None:
        if self.rate > 0 and self._armed(site) \
                and self._rng.random() < self.rate:
            self.records.append(FaultRecord(site, "raise"))
            raise InjectedFault(site)

    def corrupt(self, site: str, value: float) -> float:
        if self.corrupt_rate > 0 and self._armed(site) \
                and self._rng.random() < self.corrupt_rate:
            self.records.append(FaultRecord(site, "corrupt"))
            # Multiplicative perturbation in [1-s, 1+s): big enough to
            # reorder proposals, never NaN/negative for positive costs.
            f = 1.0 + self.corrupt_scale * (2.0 * self._rng.random() - 1.0)
            return value * f
        return value


#: The active injector.  A plain module global (not a thread-local):
#: the fault *arming* is process-wide on purpose — the DSE's optional
#: scoring pool must see the injector too, and chaos runs are
#: single-context by construction (``inject_faults`` refuses to nest).
_ACTIVE: FaultInjector | None = None


def active_injector() -> FaultInjector | None:
    """The injector currently armed by :func:`inject_faults`, if any.
    The degradation ladder uses this to decide whether belt-and-braces
    work (the uniform QoR floor) is warranted."""
    return _ACTIVE


def fault_point(site: str) -> None:
    """Injection site: raises :class:`InjectedFault` with probability
    ``rate`` when an injector is active and ``site`` is armed.  A single
    ``is None`` test otherwise — cheap enough for per-rewrite-step
    placement on the compile hot path."""
    if _ACTIVE is not None:
        _ACTIVE.fire(site)


def corrupt_value(site: str, value: float) -> float:
    """Corruption site: returns ``value``, possibly perturbed.  Used
    where a silently-wrong number exercises different defenses than an
    exception (the DSE's proposal scores feed ranking, not control
    flow)."""
    if _ACTIVE is not None:
        return _ACTIVE.corrupt(site, value)
    return value


@contextmanager
def inject_faults(seed: int = 0, rate: float = 0.05,
                  corrupt_rate: float = 0.0,
                  sites: Sequence[str] = ("*",),
                  corrupt_scale: float = 0.5
                  ) -> Iterator[FaultInjector]:
    """Arm a :class:`FaultInjector` for the ``with`` block.

    ::

        with inject_faults(seed=7, rate=0.05) as inj:
            sched, plan, report = optimize(graph, mesh)
        assert not inj.fired() or report.degradations

    Nesting is refused (two active injectors would interleave one
    site-visit stream unpredictably)."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("inject_faults contexts cannot nest")
    inj = FaultInjector(seed=seed, rate=rate, corrupt_rate=corrupt_rate,
                        sites=sites, corrupt_scale=corrupt_scale)
    _ACTIVE = inj
    try:
        yield inj
    finally:
        _ACTIVE = None
