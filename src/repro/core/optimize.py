"""HIDA-OPT driver: the paper's five-step pipeline (Section 6).

``optimize(graph, mesh)`` runs

    construct (Alg.1) → task fusion (Alg.2) → Functional→Structural
    lowering (§6.3) → multi-producer elimination (Alg.3) → data-path
    balancing (§6.4.2) → IA+CA parallelization (Alg.4/§6.5)

and returns the parallelized ``Schedule``, the derived ``ShardingPlan``
and a pass-by-pass report.  The ablation switches (``ia``, ``ca``,
``fuse``) reproduce the paper's Fig. 11 arms.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .balance import BalanceStats, balance_paths
from .construct import construct_functional
from .estimator import MeshSpec, ScheduleCost, estimate
from .fusion import FusionStats, fuse_tasks
from .ir import Graph, Schedule
from .lower import lower_to_structural
from .multi_producer import MultiProducerStats, eliminate_multi_producers
from .parallelize import ParallelizeResult, parallelize
from .plan import ShardingPlan, build_plan


@dataclass
class OptimizeReport:
    fusion: FusionStats | None = None
    multi_producer: MultiProducerStats | None = None
    balance: BalanceStats | None = None
    parallelize: ParallelizeResult | None = None
    cost: ScheduleCost | None = None
    compile_time_s: float = 0.0
    #: wall time of plan derivation (build_plan + EP widening + role
    #: aliasing) — tracked by benchmarks/bench_compile_time.py.
    plan_time_s: float = 0.0
    #: per-pass wall time of the pre-DSE pipeline (all five passes —
    #: construction included — run on the transactional rewrite
    #: substrate; benchmarks/bench_compile_time gates their total, and
    #: ``fuse_s`` specifically, so a topology- or reachability-index
    #: maintenance regression is caught the same way a DSE regression is).
    construct_s: float = 0.0
    fuse_s: float = 0.0
    lower_s: float = 0.0
    mp_s: float = 0.0
    balance_s: float = 0.0
    meta: dict = field(default_factory=dict)

    @property
    def pre_dse_s(self) -> float:
        """Total pre-DSE structural-pass wall time."""
        return (self.construct_s + self.fuse_s + self.lower_s + self.mp_s
                + self.balance_s)


def optimize(graph: Graph, mesh: MeshSpec, *,
             ia: bool = True, ca: bool = True, fuse: bool = True,
             max_parallel_factor: int | None = None,
             fsdp: bool = False, training: bool = True,
             beam_width: int = 8, joint_radius: int = 1,
             sweep_workers: int | None = None,
             seed_uniform: bool | None = None
             ) -> tuple[Schedule, ShardingPlan, OptimizeReport]:
    """Run the five-step HIDA-OPT pipeline and derive the sharding plan.

    Args:
        graph: Functional dataflow graph (mutated in place by the passes).
        mesh: target mesh axes, e.g. ``SINGLE_POD`` (16×16).
        ia / ca / fuse: paper Fig. 11 ablation switches (intensity-aware
            budgets, connection-aware scoring, task fusion).
        max_parallel_factor: global parallel-factor budget (defaults to
            the chip count).
        fsdp: emit FSDP-style weight sharding in the plan.
        training: include weight-gradient sync traffic in the QoR model.
        beam_width: width of the parallelizer's beam search over joint
            multi-node proposals; ``<= 1`` falls back to pure greedy
            coordinate descent (see :func:`repro.core.parallelize`).
        joint_radius: affected-set hops re-optimized around each joint
            move's origin.
        sweep_workers: thread-pool width for graph-colored sweep scoring
            (does not change the plan; ``None``/1 = serial).  Only useful
            on free-threaded Python — under the GIL it slows compiles
            slightly; leave ``None`` otherwise.
        seed_uniform: **deprecated, ignored** when the beam is enabled —
            the beam seeds itself with the uniform-assignment family.

    Returns:
        ``(schedule, plan, report)``: the parallelized Structural
        schedule, the derived :class:`~repro.core.plan.ShardingPlan`, and
        the pass-by-pass :class:`OptimizeReport`.
    """
    t0 = time.perf_counter()
    report = OptimizeReport()

    t = time.perf_counter()
    construct_functional(graph)
    report.construct_s = time.perf_counter() - t
    if fuse:
        t = time.perf_counter()
        report.fusion = fuse_tasks(graph)
        report.fuse_s = time.perf_counter() - t
    t = time.perf_counter()
    sched = lower_to_structural(graph)
    report.lower_s = time.perf_counter() - t
    t = time.perf_counter()
    report.multi_producer = eliminate_multi_producers(sched)
    report.mp_s = time.perf_counter() - t
    t = time.perf_counter()
    report.balance = balance_paths(sched)
    report.balance_s = time.perf_counter() - t
    report.parallelize = parallelize(
        sched, mesh, ia=ia, ca=ca, training=training,
        max_parallel_factor=max_parallel_factor,
        beam_width=beam_width, joint_radius=joint_radius,
        sweep_workers=sweep_workers,
        # Joint uniform moves are a CA concept: keep the legacy escape
        # hatch suppressed in the CA-off ablation arm, as before.
        seed_uniform=(seed_uniform if ca or seed_uniform is None
                      else False))
    # The parallelizer's incremental engine already holds the final QoR
    # (bit-identical to the batch reference — tests/test_incremental.py
    # asserts so); fall back to ``estimate()`` only if it is absent.
    report.cost = (report.parallelize.cost
                   if report.parallelize.cost is not None
                   else estimate(sched, mesh, training=training))

    # Plan derivation runs on the same cached topology the estimator's DSE
    # used (sched.topology()): build_plan projects through it, and the EP
    # widening below re-projects O(Δ) through ShardingPlan.apply_rule_change
    # instead of a full project_rules rebuild.
    t_plan = time.perf_counter()
    topo = sched.topology()
    plan = build_plan(sched, mesh, fsdp=fsdp, coherent=ca,
                      meta={"graph": graph.name, "ia": ia, "ca": ca},
                      topology=topo)

    # Strip per-layer prefixes so models can look up role sites
    # ("qkv", "attn_ctx", "ffn_hidden", …) regardless of block index.
    # Registered as aliases so later delta re-projections keep them fresh.
    for bname in list(plan.buffer_specs):
        if "__" in bname:
            plan.add_role_alias(bname.split("__", 1)[1], bname)

    # Capacity-driven EP widening (DeepSeek-scale expert counts): when the
    # expert weights at the chosen EP degree exceed the per-device HBM
    # budget, widen the expert sharding over the data axis — the
    # production EP>TP layout.  Expert weights then live fully sharded by
    # expert and never pass through the FSDP gather path.
    expert_bufs = [b for b in sched.buffers.values()
                   if b.is_weight and "experts" in b.dims]
    if expert_bufs and ca:
        repeats = getattr(getattr(graph, "meta", None), "repeat_factor", 1)
        total = sum(b.bytes for b in expert_bufs) * repeats
        n_exp = expert_bufs[0].shape[expert_bufs[0].dims.index("experts")]
        cur = tuple(plan.rules.get("experts", ()))
        shard = 1
        for a in cur:
            shard *= mesh.size(a)
        if total / max(shard, 1) > 6e9:
            widened = False
            for a in ("data",):
                if (a in mesh.names and a not in cur
                        and n_exp % (shard * mesh.size(a)) == 0):
                    cur = cur + (a,)
                    shard *= mesh.size(a)
                    plan.meta["ep_widened"] = list(cur)
                    widened = True
            if not widened and "data" in mesh.names \
                    and n_exp % mesh.size("data") == 0:
                # Expert count divides data but not data×model (e.g.
                # deepseek-v2's 160): EP over data + Megatron expert-TP
                # over model (d_ff column/row split + psum).
                cur = ("data",)
                plan.meta["moe_tp"] = "model"
                plan.meta["ep_widened"] = ["data", "+tp:model"]
                widened = True
            if widened:
                # Delta re-projection: only the buffer sites whose access
                # maps reference "experts" (plus their role aliases) are
                # rewritten — bit-identical to a full project_rules rebuild
                # (tests/test_plan.py sweeps every config × shape).
                plan.apply_rule_change("experts", cur, sched, topo)
    report.plan_time_s = time.perf_counter() - t_plan

    report.compile_time_s = time.perf_counter() - t0
    report.meta = {"nodes": len(sched.nodes),
                   "buffers": len(sched.buffers)}
    return sched, plan, report
