"""HIDA-OPT driver: the paper's five-step pipeline (Section 6).

``optimize(graph, mesh)`` runs

    construct (Alg.1) → task fusion (Alg.2) → Functional→Structural
    lowering (§6.3) → multi-producer elimination (Alg.3) → data-path
    balancing (§6.4.2) → IA+CA parallelization (Alg.4/§6.5)

and returns the parallelized ``Schedule``, the derived ``ShardingPlan``
and a pass-by-pass report.  The ablation switches (``ia``, ``ca``,
``fuse``) reproduce the paper's Fig. 11 arms.

``optimize()`` is **total**: every pass boundary is an error boundary.
The structural passes run inside transactional rewrite sessions
(:mod:`repro.core.rewrite`) that roll back on exception, so a failed
pass leaves its input IR intact and the pipeline continues on the
unrewritten graph/schedule; a failed lowering falls back to the
single-node :func:`~repro.core.lower.fallback_schedule`; a failed or
over-budget DSE falls back to its converged-greedy snapshot and then to
the uniform-assignment family
(:func:`~repro.core.parallelize.best_uniform`); a failed plan
derivation falls back to a full coherent rebuild and then to
:func:`~repro.core.plan.replicated_plan`.  Every fallback taken is
recorded in :attr:`OptimizeReport.degradations`, and the returned plan
is checked by the independent :func:`~repro.core.verify.verify` — with
its own repair rungs — before it leaves this function.  The chaos sweep
in ``tests/test_faults.py`` drives every rung via
:mod:`repro.core.faults`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .analyze import AnalyzeReport, analyze
from .balance import BalanceStats, balance_paths
from .construct import construct_functional
from .estimator import MeshSpec, ScheduleCost, estimate
from .faults import active_injector
from .fusion import FusionStats, fuse_tasks
from .incremental import Snapshot
from .ir import Graph, Schedule, topology_index_bytes
from .lower import fallback_schedule, lower_to_structural
from .multi_producer import MultiProducerStats, eliminate_multi_producers
from .parallelize import ParallelizeResult, best_uniform, parallelize
from .plan import (ShardingPlan, build_plan, project_rules,
                   replicated_plan)
from .rewrite import dse_regions
from .verify import VerifyReport, verify


def _exc(e: BaseException) -> str:
    return f"{type(e).__name__}: {e}"


def _floor_regions(sched: Schedule):
    """Region partition for the region-aware QoR floor — best-effort:
    the floor must stay serviceable even when the topology is the thing
    that broke, so any partition failure degrades to the whole-schedule
    floor (``regions=None``) instead of raising."""
    try:
        regs = dse_regions(sched)
        return regs if len(regs) > 1 else None
    except Exception:
        return None


@dataclass(frozen=True)
class Degradation:
    """One rung of the degradation ladder that actually fired."""
    stage: str    # construct | fuse | lower | mp | balance | dse |
    #               qor-floor | plan | verify
    action: str   # what the ladder did instead
    error: str = ""  # the triggering exception / verifier codes

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        tail = f" [{self.error}]" if self.error else ""
        return f"{self.stage}: {self.action}{tail}"


@dataclass
class OptimizeReport:
    fusion: FusionStats | None = None
    multi_producer: MultiProducerStats | None = None
    balance: BalanceStats | None = None
    parallelize: ParallelizeResult | None = None
    cost: ScheduleCost | None = None
    compile_time_s: float = 0.0
    #: wall time of plan derivation (build_plan + EP widening + role
    #: aliasing) — tracked by benchmarks/bench_compile_time.py.
    plan_time_s: float = 0.0
    #: per-pass wall time of the pre-DSE pipeline (all five passes —
    #: construction included — run on the transactional rewrite
    #: substrate; benchmarks/bench_compile_time gates their total, and
    #: ``fuse_s`` specifically, so a topology- or reachability-index
    #: maintenance regression is caught the same way a DSE regression is).
    construct_s: float = 0.0
    fuse_s: float = 0.0
    lower_s: float = 0.0
    mp_s: float = 0.0
    balance_s: float = 0.0
    #: wall time of the exit legality check (verify + any repair rungs);
    #: benchmarks/bench_compile_time gates it staying ≪ pre_dse_s.
    verify_s: float = 0.0
    #: wall time of the exit static hazard analysis
    #: (:func:`repro.core.analyze.analyze`) — gated by
    #: benchmarks/bench_compile_time like ``verify_s``.
    analyze_s: float = 0.0
    #: per-level DSE wall time (hierarchical mode: inner = per-region
    #: searches, outer = inter-region composition; both 0.0 on the flat
    #: path) and the number of regions the schedule was partitioned into
    #: — benchmarks/bench_compile_time reports all three per arm.
    inner_dse_s: float = 0.0
    outer_dse_s: float = 0.0
    regions: int = 1
    #: peak bytes held by the compile's indexing layers: the fusion
    #: session's region indexes (``FusionStats.index_peak_bytes``) plus
    #: the final schedule's cached :class:`~repro.core.ir.ScheduleTopology`
    #: (``topology_index_bytes``).  Representation-comparable, not
    #: ``sys.getsizeof``-exact; benchmarks/bench_compile_time reports it
    #: per arm and its ``--compare`` mode gates regressions.
    index_bytes: int = 0
    #: every degradation-ladder rung that fired, in pipeline order —
    #: empty on a clean compile.
    degradations: list[Degradation] = field(default_factory=list)
    #: the exit :class:`~repro.core.verify.VerifyReport` for the returned
    #: plan (post-repair; ``ok`` unless even the ladder's bottom rung
    #: could not produce a legal plan, e.g. a genuinely cyclic graph).
    verify: VerifyReport | None = None
    #: the exit :class:`~repro.core.analyze.AnalyzeReport` — static
    #: dataflow hazard findings (deadlock / shard-race / ordering /
    #: invariant families) for the *returned* schedule, whichever
    #: degradation rung produced it.  Clean compiles report zero
    #: findings; a rolled-back balance pass, for example, legitimately
    #: surfaces the reconvergent hazards it left behind.
    analyze: AnalyzeReport | None = None
    meta: dict = field(default_factory=dict)

    @property
    def pre_dse_s(self) -> float:
        """Total pre-DSE structural-pass wall time."""
        return (self.construct_s + self.fuse_s + self.lower_s + self.mp_s
                + self.balance_s)

    def degraded(self, stage: str | None = None) -> bool:
        return any(d.stage == stage for d in self.degradations) \
            if stage else bool(self.degradations)


def optimize(graph: Graph, mesh: MeshSpec, *,
             ia: bool = True, ca: bool = True, fuse: bool = True,
             max_parallel_factor: int | None = None,
             fsdp: bool = False, training: bool = True,
             beam_width: int = 8, joint_radius: int = 1,
             sweep_workers: int | None = None,
             seed_uniform: bool | None = None,
             budget_s: float | None = None,
             dse_mode: str = "hierarchical",
             warm_start: Snapshot | None = None,
             warm_entries: list[Snapshot] | None = None
             ) -> tuple[Schedule, ShardingPlan, OptimizeReport]:
    """Run the five-step HIDA-OPT pipeline and derive the sharding plan.

    Args:
        graph: Functional dataflow graph (mutated in place by the passes).
        mesh: target mesh axes, e.g. ``SINGLE_POD`` (16×16).
        ia / ca / fuse: paper Fig. 11 ablation switches (intensity-aware
            budgets, connection-aware scoring, task fusion).
        max_parallel_factor: global parallel-factor budget (defaults to
            the chip count).
        fsdp: emit FSDP-style weight sharding in the plan.
        training: include weight-gradient sync traffic in the QoR model.
        beam_width: width of the parallelizer's beam search over joint
            multi-node proposals; ``<= 1`` falls back to pure greedy
            coordinate descent (see :func:`repro.core.parallelize`).
        joint_radius: affected-set hops re-optimized around each joint
            move's origin.
        sweep_workers: thread-pool width for graph-colored sweep scoring
            (does not change the plan; ``None``/1 = serial).  Only useful
            on free-threaded Python — under the GIL it slows compiles
            slightly; leave ``None`` otherwise.
        seed_uniform: **deprecated, ignored** when the beam is enabled —
            the beam seeds itself with the uniform-assignment family.
        budget_s: wall-clock compile budget in seconds, measured from
            entry.  The DSE becomes *anytime*: once the budget expires,
            convergence sweeps and beam rounds stop at the next boundary
            and the best-so-far snapshot is returned (recorded as a
            ``dse`` degradation).  The pre-DSE passes and plan
            derivation always run — they are cheap and required for a
            legal result.  ``None`` (default) never interrupts.  In
            hierarchical mode the budget is split adaptively between the
            inner (per-region) and outer (composition) DSE levels.
        dse_mode: ``"hierarchical"`` (default) or ``"flat"`` — see
            :func:`repro.core.parallelize.parallelize`.  The flat beam
            is the differential-testing oracle; both modes share every
            rung of the degradation ladder.
        warm_start: cached whole-schedule assignment snapshot to seed
            the DSE from (plan-cache nearest-neighbour warm start); the
            beam phase is skipped — see
            :func:`repro.core.parallelize.parallelize`.  All degradation
            rungs still apply.
        warm_entries: extra assignment fragments (donor region
            summaries) tried as alternatives on the warm path.

    Returns:
        ``(schedule, plan, report)``: the parallelized Structural
        schedule, the derived :class:`~repro.core.plan.ShardingPlan`, and
        the pass-by-pass :class:`OptimizeReport`.  Never raises for
        failures inside the pipeline: every fallback taken is listed in
        ``report.degradations`` and the plan is verifier-clean whenever
        the schedule admits a legal plan at all (``report.verify``).
    """
    t0 = time.perf_counter()
    deadline = t0 + budget_s if budget_s is not None else None
    report = OptimizeReport()

    def degrade(stage: str, action: str, error: str = "") -> None:
        report.degradations.append(Degradation(stage, action, error))

    # ---- pre-DSE structural passes.  Each runs inside a transactional
    # rewrite session that rolls back on exception, so catching at the
    # boundary resumes on the pass's *input* IR.
    t = time.perf_counter()
    try:
        construct_functional(graph)
    except Exception as e:
        degrade("construct", "rolled back; continuing on the "
                "unconstructed graph", _exc(e))
    report.construct_s = time.perf_counter() - t
    if fuse:
        t = time.perf_counter()
        try:
            report.fusion = fuse_tasks(graph)
        except Exception as e:
            degrade("fuse", "rolled back; continuing unfused", _exc(e))
        report.fuse_s = time.perf_counter() - t
    t = time.perf_counter()
    try:
        sched = lower_to_structural(graph)
    except Exception as e:
        degrade("lower", "fell back to the single-node schedule", _exc(e))
        sched = fallback_schedule(graph)
    report.lower_s = time.perf_counter() - t
    t = time.perf_counter()
    try:
        report.multi_producer = eliminate_multi_producers(sched)
    except Exception as e:
        degrade("mp", "rolled back; multi-producer buffers remain",
                _exc(e))
    report.mp_s = time.perf_counter() - t
    t = time.perf_counter()
    try:
        report.balance = balance_paths(sched)
    except Exception as e:
        degrade("balance", "rolled back; unbalanced paths remain",
                _exc(e))
    report.balance_s = time.perf_counter() - t

    # ---- DSE ladder: beam (anytime under ``deadline``, internally
    # falling back to converged greedy) → uniform-assignment family →
    # all-replicated.
    dse_fell_back = False
    try:
        report.parallelize = parallelize(
            sched, mesh, ia=ia, ca=ca, training=training,
            max_parallel_factor=max_parallel_factor,
            beam_width=beam_width, joint_radius=joint_radius,
            sweep_workers=sweep_workers, deadline=deadline,
            dse_mode=dse_mode,
            warm_start=warm_start, warm_entries=warm_entries,
            # Joint uniform moves are a CA concept: keep the legacy escape
            # hatch suppressed in the CA-off ablation arm, as before.
            seed_uniform=(seed_uniform if ca or seed_uniform is None
                          else False))
        report.inner_dse_s = report.parallelize.inner_dse_s
        report.outer_dse_s = report.parallelize.outer_dse_s
        report.regions = report.parallelize.regions
        for msg in report.parallelize.degraded:
            degrade("dse", "DSE degradation; best pre-failure snapshot "
                    "kept", msg)
        if report.parallelize.budget_expired:
            degrade("dse", "wall-clock budget expired; best-so-far "
                    "snapshot returned")
        # The parallelizer's incremental engine already holds the final QoR
        # (bit-identical to the batch reference — tests/test_incremental.py
        # asserts so); fall back to ``estimate()`` only if it is absent.
        report.cost = (report.parallelize.cost
                       if report.parallelize.cost is not None
                       else estimate(sched, mesh, training=training))
    except Exception as e:
        dse_fell_back = True
        degrade("dse", "DSE failed; applied the best uniform assignment",
                _exc(e))
        try:
            _assign, report.cost = best_uniform(
                sched, mesh, max_parallel_factor=max_parallel_factor,
                ia=ia, training=training,
                regions=_floor_regions(sched))
        except Exception as e2:
            degrade("dse", "uniform fallback failed; cleared all "
                    "assignments (replicated)", _exc(e2))
            for n in sched.nodes:
                n.axis_map, n.unroll = {}, {}
            try:
                report.cost = estimate(sched, mesh, training=training)
            except Exception:
                report.cost = None

    # ---- QoR floor.  Corrupted proposal scores (fault injection) or a
    # budget-interrupted beam can leave an assignment the *true* model
    # rates worse than the uniform family; re-check on the clean batch
    # path and keep the better one.  The floor is **region-aware**
    # (per-region uniform refinement over the same partition the
    # hierarchical DSE searches), so one degraded region cannot drag the
    # composed plan below the whole-schedule floor.  Skipped on clean
    # compiles — the beam already seeds with the uniform family, so the
    # floor holds by construction and the zero-fault path stays
    # bit-identical.
    if not dse_fell_back and (report.degradations
                              or active_injector() is not None):
        saved = {n.name: (dict(n.axis_map), dict(n.unroll))
                 for n in sched.nodes}
        try:
            true_cost = estimate(sched, mesh, training=training)
            _assign, ucost = best_uniform(
                sched, mesh, max_parallel_factor=max_parallel_factor,
                ia=ia, training=training,
                regions=_floor_regions(sched))
            if ucost.total_s < true_cost.total_s:
                report.cost = ucost
                degrade("qor-floor",
                        f"uniform family ({ucost.total_s * 1e3:.3f}ms) "
                        f"beat the degraded DSE result "
                        f"({true_cost.total_s * 1e3:.3f}ms); applied")
            else:
                for n in sched.nodes:
                    n.axis_map, n.unroll = saved[n.name]
                report.cost = true_cost
        except Exception as e:
            for n in sched.nodes:
                if n.name in saved:
                    n.axis_map, n.unroll = saved[n.name]
            degrade("qor-floor", "floor check failed; keeping the DSE "
                    "result", _exc(e))

    # ---- plan derivation ladder: delta-maintained coherent plan → full
    # coherent rebuild → replicated plan.  Runs on the same cached
    # topology the estimator's DSE used (sched.topology()): build_plan
    # projects through it, and the EP widening below re-projects O(Δ)
    # through ShardingPlan.apply_rule_change instead of a full
    # project_rules rebuild.
    t_plan = time.perf_counter()
    plan_coherent = ca
    plan_meta = {"graph": graph.name, "ia": ia, "ca": ca}
    topo = None
    try:
        topo = sched.topology()
        plan = build_plan(sched, mesh, fsdp=fsdp, coherent=ca,
                          meta=dict(plan_meta), topology=topo)

        # Strip per-layer prefixes so models can look up role sites
        # ("qkv", "attn_ctx", "ffn_hidden", …) regardless of block index.
        # Registered as aliases so later delta re-projections keep them
        # fresh.
        for bname in list(plan.buffer_specs):
            if "__" in bname:
                plan.add_role_alias(bname.split("__", 1)[1], bname)
    except Exception as e:
        degrade("plan", "plan derivation failed; replicated-plan "
                "fallback", _exc(e))
        plan = replicated_plan(mesh, fsdp=fsdp)
        plan_coherent = False

    # Capacity-driven EP widening (DeepSeek-scale expert counts): when the
    # expert weights at the chosen EP degree exceed the per-device HBM
    # budget, widen the expert sharding over the data axis — the
    # production EP>TP layout.  Expert weights then live fully sharded by
    # expert and never pass through the FSDP gather path.
    expert_bufs = [b for b in sched.buffers.values()
                   if b.is_weight and "experts" in b.dims]
    if expert_bufs and ca and plan_coherent:
        try:
            repeats = getattr(getattr(graph, "meta", None),
                              "repeat_factor", 1)
            total = sum(b.bytes for b in expert_bufs) * repeats
            n_exp = expert_bufs[0].shape[
                expert_bufs[0].dims.index("experts")]
            cur = tuple(plan.rules.get("experts", ()))
            shard = 1
            for a in cur:
                shard *= mesh.size(a)
            if total / max(shard, 1) > 6e9:
                widened = False
                for a in ("data",):
                    if (a in mesh.names and a not in cur
                            and n_exp % (shard * mesh.size(a)) == 0):
                        cur = cur + (a,)
                        shard *= mesh.size(a)
                        plan.meta["ep_widened"] = list(cur)
                        widened = True
                if not widened and "data" in mesh.names \
                        and n_exp % mesh.size("data") == 0:
                    # Expert count divides data but not data×model (e.g.
                    # deepseek-v2's 160): EP over data + Megatron expert-TP
                    # over model (d_ff column/row split + psum).
                    cur = ("data",)
                    plan.meta["moe_tp"] = "model"
                    plan.meta["ep_widened"] = ["data", "+tp:model"]
                    widened = True
                if widened:
                    try:
                        # Delta re-projection: only the buffer sites whose
                        # access maps reference "experts" (plus their role
                        # aliases) are rewritten — bit-identical to a full
                        # project_rules rebuild (tests/test_plan.py sweeps
                        # every config × shape).
                        plan.apply_rule_change("experts", cur, sched, topo)
                    except Exception as e:
                        degrade("plan", "delta re-projection failed; "
                                "full coherent rebuild", _exc(e))
                        plan.rules["experts"] = tuple(cur)
                        project_rules(plan, sched, topology=topo)
        except Exception as e:
            degrade("plan", "EP widening failed; keeping the unwidened "
                    "plan", _exc(e))
    report.plan_time_s = time.perf_counter() - t_plan

    # ---- exit legality check + repair rungs.  The verifier is
    # independent of everything above; the returned plan must be clean.
    t_verify = time.perf_counter()
    vrep = verify(sched, plan, mesh, coherent=plan_coherent,
                  topology=topo)
    if not vrep.ok:
        degrade("verify", "plan failed verification; full coherent "
                "rebuild",
                "; ".join(sorted({i.code for i in vrep.errors()})))
        try:
            plan = build_plan(sched, mesh, fsdp=fsdp, coherent=True,
                              meta=dict(plan_meta, repaired=True),
                              topology=None)
            for bname in list(plan.buffer_specs):
                if "__" in bname:
                    plan.add_role_alias(bname.split("__", 1)[1], bname)
            plan_coherent = True
            vrep = verify(sched, plan, mesh, coherent=True)
        except Exception as e:
            degrade("verify", "coherent rebuild failed", _exc(e))
    if not vrep.ok:
        degrade("verify", "still illegal after rebuild; cleared node "
                "assignments + replicated plan",
                "; ".join(sorted({i.code for i in vrep.errors()})))
        for n in sched.nodes:
            n.axis_map, n.unroll = {}, {}
        plan = replicated_plan(mesh, fsdp=False)
        plan_coherent = False
        try:
            report.cost = estimate(sched, mesh, training=training)
        except Exception:
            pass
        vrep = verify(sched, plan, mesh, coherent=False)
    report.verify = vrep
    report.verify_s = time.perf_counter() - t_verify

    # ---- exit hazard analysis.  Runs on *every* return path — clean,
    # degraded, fallback_schedule — so no rung of the ladder ships an
    # unchecked dataflow hazard.  analyze() is total (a crashing rule
    # becomes an analyze-internal issue), but the belt-and-braces guard
    # keeps even a broken driver from failing the compile.
    t_analyze = time.perf_counter()
    try:
        report.analyze = analyze(sched, plan, mesh, topology=topo)
        crashed = report.analyze.crashed_rules()
        if crashed:
            degrade("analyze", "analysis rule(s) crashed; hazard report "
                    "incomplete", ", ".join(crashed))
    except Exception as e:
        degrade("analyze", "hazard analysis crashed; no hazard report",
                _exc(e))
    report.analyze_s = time.perf_counter() - t_analyze

    report.compile_time_s = time.perf_counter() - t0
    report.meta = {"nodes": len(sched.nodes),
                   "buffers": len(sched.buffers)}
    # Peak indexing-layer footprint: the fusion session's region indexes
    # plus the schedule's cached topology (edges, owner tables, memos).
    try:
        report.index_bytes = (
            (report.fusion.index_peak_bytes if report.fusion else 0)
            + topology_index_bytes(sched.topology()))
    except Exception:
        report.index_bytes = 0
    return sched, plan, report
