"""Functional dataflow construction — paper Algorithm 1.

Walk regions bottom-up; every *dispatchable* region (owned by an iterative
op — here: the module or a composite block — and containing at least two
iterative sub-ops) is wrapped in a ``dispatch`` whose children each become a
``task``.  The result is the hierarchical Functional dataflow of Fig. 3.

The pass runs inside a :class:`~repro.core.rewrite.GraphRewriteSession`
(one :meth:`~repro.core.rewrite.GraphRewriteSession.wrap_dispatch` per
dispatchable region), which makes the *entry* pass transactional like
every later one — an exception leaves the graph untouched — and commits
a maintained topology, so ``fuse_tasks`` starts on a warm cache instead
of paying a full rebuild at the construct/fuse boundary.  Wrapping never
touches leaf ops, so the value→op indices carry over verbatim; only the
parent map grows.
"""
from __future__ import annotations

from .faults import fault_point
from .ir import Graph, Op
from .rewrite import GraphRewriteSession

#: op kinds considered "iterative" (own a loop nest / region) — paper: an op
#: is iterative if it is a loop or a func.  For the tensor graphs we trace,
#: every compute op carries a loop nest, while bookkeeping ops do not.
_NON_ITERATIVE = {"const", "reshape_view", "token"}


def is_iterative(op: Op) -> bool:
    return op.has_region or (op.kind not in _NON_ITERATIVE
                             and bool(op.loop_dims))


def is_dispatchable(ops: list[Op]) -> bool:
    """A region is dispatchable when ≥2 of its ops are iterative."""
    return sum(1 for o in ops if is_iterative(o)) >= 2


def _construct_region(rs: GraphRewriteSession, owner: Op | None,
                      ops: list[Op]) -> None:
    # Bottom-up: recurse into nested regions first (post-order walk).
    for o in ops:
        if o.has_region:
            _construct_region(rs, o, o.region)
    if is_dispatchable(ops):
        fault_point("construct.wrap")
        rs.wrap_dispatch(owner)


def construct_functional(graph: Graph, selfcheck: bool = False) -> Graph:
    """Paper Algorithm 1: produce the initial (maximally split) Functional
    dataflow in-place and return the graph.

    ``selfcheck`` asserts the session's maintained topology against a
    from-scratch rebuild after every wrap (tests only)."""
    with GraphRewriteSession(graph, selfcheck=selfcheck) as rs:
        _construct_region(rs, None, graph.ops)
    return graph
