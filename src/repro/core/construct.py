"""Functional dataflow construction — paper Algorithm 1.

Walk regions bottom-up; every *dispatchable* region (owned by an iterative
op — here: the module or a composite block — and containing at least two
iterative sub-ops) is wrapped in a ``dispatch`` whose children each become a
``task``.  The result is the hierarchical Functional dataflow of Fig. 3.
"""
from __future__ import annotations

from .ir import Graph, Op, make_dispatch, make_task

#: op kinds considered "iterative" (own a loop nest / region) — paper: an op
#: is iterative if it is a loop or a func.  For the tensor graphs we trace,
#: every compute op carries a loop nest, while bookkeeping ops do not.
_NON_ITERATIVE = {"const", "reshape_view", "token"}


def is_iterative(op: Op) -> bool:
    return op.has_region or (op.kind not in _NON_ITERATIVE
                             and bool(op.loop_dims))


def is_dispatchable(ops: list[Op]) -> bool:
    """A region is dispatchable when ≥2 of its ops are iterative."""
    return sum(1 for o in ops if is_iterative(o)) >= 2


def _construct_region(ops: list[Op]) -> list[Op]:
    # Bottom-up: recurse into nested regions first (post-order walk).
    for o in ops:
        if o.has_region:
            o.region = _construct_region(o.region)
    if not is_dispatchable(ops):
        return ops
    # Wrap each op into its own task, then all tasks into one dispatch.
    tasks = [o if o.kind in ("task", "dispatch") else make_task([o])
             for o in ops]
    return [make_dispatch(tasks)]


def construct_functional(graph: Graph) -> Graph:
    """Paper Algorithm 1: produce the initial (maximally split) Functional
    dataflow in-place and return the graph."""
    graph.ops = _construct_region(graph.ops)
    return graph
