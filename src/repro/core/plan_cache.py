"""Persistent :class:`ShardingPlan` cache with warm-start seeding.

HIDA's premise is that the dataflow schedule is computed once and then
*streamed through* at steady state.  The serving analogue: a production
endpoint sees the same (config, mesh, shape-bucket) triples over and
over, so the ~0.65 s DSE should run at most once per triple per
deployment — afterwards the plan is a microsecond dictionary fetch.

Three tiers, fastest first:

1. **In-process LRU** — ``PlanCache.get`` on a resident key is a dict
   hit (sub-microsecond, no I/O, no verification re-run).
2. **Disk** — one JSON file per key under the cache root, written
   atomically (tmp + ``os.replace``), carrying the plan
   (``ShardingPlan.to_json`` payload, version-checked by
   ``from_json``), the DSE's canonical assignment snapshot, and the
   recorded QoR.  Loads are gated by
   :func:`~repro.core.verify.verify_static` and the plan-only hazard
   rules of :func:`~repro.core.analyze.analyze_plan` in
   :meth:`PlanCache.fetch` — a plan is only served against the mesh it
   was derived for, and never with stale/chained role aliases.  Any
   corruption (truncated file, bad JSON, stale format version, injected
   ``cache.load`` fault) degrades to a miss, never an exception.
3. **Warm-started re-DSE** — on a miss, :meth:`PlanCache.nearest` finds
   the closest stored entry (same config fingerprint first, then same
   mesh, then same bucket) and :func:`fetch_or_optimize` seeds
   ``optimize(warm_start=...)`` from its snapshot: the beam phase is
   skipped, covered nodes start from the donor assignment (sanitized
   onto the new mesh), and coordinate descent converges from there —
   warm wall is a fraction of cold wall at equal-or-better QoR (the
   ``bench_serve`` gate pins this on every config).

Cache keys (:class:`PlanKey`) are (config fingerprint, mesh axes, shape
bucket).  The fingerprint hashes every :class:`ArchConfig` field, so
*any* architectural change — silently different ``d_ff``, a new MoE
setting — is a different key; there is no way to mis-serve a plan to a
config it was not derived for.  Shape buckets are names
(``decode_32k``) or :func:`shape_bucket` strings for free-form serving
shapes, so nearby request shapes share one plan while far-apart ones do
not.

Chaos sites ``cache.load`` / ``cache.store`` (see
:mod:`repro.core.faults`) let tests assert the degrade-to-miss and
degrade-to-unstored contracts under injected I/O failure.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from collections import OrderedDict
from dataclasses import dataclass
from pathlib import Path
from typing import Callable

from .estimator import MeshSpec
from .faults import fault_point
from .incremental import Snapshot
from .plan import ShardingPlan
from .verify import VerifyReport, verify_static

__all__ = ["PlanKey", "CachedPlan", "PlanCache", "config_fingerprint",
           "shape_bucket", "fetch_or_optimize", "CACHE_FORMAT_VERSION"]

#: Bumped whenever the entry envelope (not the plan payload — that has
#: its own ``PLAN_FORMAT_VERSION``) changes incompatibly.
CACHE_FORMAT_VERSION = 1


def config_fingerprint(cfg) -> str:
    """Content hash of an :class:`ArchConfig` (or any dataclass).

    Every field participates — two configs differing in one number get
    different fingerprints, so a cached plan can never be served to an
    architecture it was not derived for."""
    if dataclasses.is_dataclass(cfg):
        payload = dataclasses.asdict(cfg)
    elif isinstance(cfg, dict):
        payload = cfg
    else:
        payload = {"repr": repr(cfg)}
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def shape_bucket(mode: str, seq_len: int, batch: int) -> str:
    """Quantize a free-form request shape onto a bucket name.

    Serving traffic has arbitrary prompt lengths; compiling per exact
    length would defeat the cache.  Lengths round up to the next power
    of two (min 128) — the same padding the scheduler's prefill side
    steps use — so nearby shapes share one plan."""
    b = 128
    while b < seq_len:
        b *= 2
    return f"{mode}_b{batch}_s{b}"


@dataclass(frozen=True)
class PlanKey:
    """(what model, what machine, what shapes) — the cache identity."""
    fingerprint: str
    mesh: tuple[tuple[str, int], ...]
    bucket: str

    @classmethod
    def make(cls, cfg, mesh: MeshSpec, bucket: str) -> "PlanKey":
        return cls(config_fingerprint(cfg),
                   tuple((a, int(s)) for a, s in mesh.axes), str(bucket))

    def digest(self) -> str:
        blob = json.dumps([self.fingerprint, list(map(list, self.mesh)),
                           self.bucket])
        return hashlib.sha256(blob.encode()).hexdigest()[:24]

    def to_dict(self) -> dict:
        return {"fingerprint": self.fingerprint,
                "mesh": [list(m) for m in self.mesh],
                "bucket": self.bucket}

    @classmethod
    def from_dict(cls, d: dict) -> "PlanKey":
        return cls(d["fingerprint"],
                   tuple((a, int(s)) for a, s in d["mesh"]), d["bucket"])


@dataclass
class CachedPlan:
    """One cache entry: the plan plus everything a warm start needs."""
    key: PlanKey
    plan: ShardingPlan
    #: canonical-keyed whole-schedule assignment
    #: (:func:`repro.core.parallelize.canonical_snapshot`) — the warm seed.
    snapshot: Snapshot
    #: ``cost.total_s`` recorded when the entry was stored.
    qor_total_s: float
    stored_unix: float = 0.0

    def to_json(self) -> str:
        snap = {name: [{d: list(axes) for d, axes in am.items()},
                       dict(ur)]
                for name, (am, ur) in self.snapshot.items()}
        return json.dumps({
            "cache_version": CACHE_FORMAT_VERSION,
            "key": self.key.to_dict(),
            "plan": json.loads(self.plan.to_json()),
            "snapshot": snap,
            "qor_total_s": self.qor_total_s,
            "stored_unix": self.stored_unix,
        }, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CachedPlan":
        d = json.loads(text)
        version = d.get("cache_version")
        if version != CACHE_FORMAT_VERSION:
            raise ValueError(
                f"cache entry version {version!r} != supported "
                f"{CACHE_FORMAT_VERSION}")
        snapshot: Snapshot = {
            name: ({dim: tuple(axes) for dim, axes in am.items()},
                   {dim: int(f) for dim, f in ur.items()})
            for name, (am, ur) in d["snapshot"].items()}
        return cls(key=PlanKey.from_dict(d["key"]),
                   plan=ShardingPlan.from_json(json.dumps(d["plan"])),
                   snapshot=snapshot,
                   qor_total_s=float(d["qor_total_s"]),
                   stored_unix=float(d.get("stored_unix", 0.0)))


class PlanCache:
    """LRU-fronted on-disk plan cache.  Load and store paths never
    raise: corruption, version skew, and I/O failure all degrade to a
    miss (load) or an unstored entry (store), counted in :attr:`stats`.

    Args:
        root: cache directory (created if missing).  ``None`` disables
            the disk tier — a pure in-process LRU.
        capacity: maximum resident entries; least-recently-used entries
            are dropped from memory (their disk files remain).
    """

    def __init__(self, root: str | os.PathLike | None,
                 capacity: int = 64):
        self.root = Path(root) if root is not None else None
        if self.root is not None:
            self.root.mkdir(parents=True, exist_ok=True)
        self.capacity = max(1, capacity)
        self._lru: OrderedDict[PlanKey, CachedPlan] = OrderedDict()
        self.stats = {"hits_mem": 0, "hits_disk": 0, "misses": 0,
                      "corrupt": 0, "stores": 0, "store_errors": 0,
                      "rejected": 0, "hazard_rejected": 0}

    # -- internals -------------------------------------------------------
    def _path(self, key: PlanKey) -> Path | None:
        return (self.root / f"{key.digest()}.json"
                if self.root is not None else None)

    def _remember(self, entry: CachedPlan) -> None:
        self._lru[entry.key] = entry
        self._lru.move_to_end(entry.key)
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)

    # -- load path -------------------------------------------------------
    def get(self, key: PlanKey) -> CachedPlan | None:
        """Fetch an entry by exact key.  Memory first, then disk; any
        disk-tier failure (bad JSON, stale version, injected
        ``cache.load`` fault) is a miss, never an exception."""
        entry = self._lru.get(key)
        if entry is not None:
            self._lru.move_to_end(key)
            self.stats["hits_mem"] += 1
            return entry
        path = self._path(key)
        if path is None or not path.exists():
            self.stats["misses"] += 1
            return None
        try:
            fault_point("cache.load")
            entry = CachedPlan.from_json(path.read_text())
            if entry.key != key:
                raise ValueError(f"entry at {path.name} carries key "
                                 f"{entry.key}, expected {key}")
        except Exception:
            self.stats["corrupt"] += 1
            self.stats["misses"] += 1
            return None
        self.stats["hits_disk"] += 1
        self._remember(entry)
        return entry

    def fetch(self, key: PlanKey, mesh: MeshSpec
              ) -> tuple[CachedPlan | None, VerifyReport | None]:
        """:meth:`get` gated by :func:`verify_static` against ``mesh``
        plus the plan-only hazard rules of
        :func:`repro.core.analyze.analyze_plan` (stale / chained role
        aliases — the memory tier mutates plans in place via
        ``apply_rule_change``, so an entry can rot between store and
        reuse).  A present-but-illegal or hazardous entry counts as a
        miss (and is dropped from the LRU so it is not re-tried every
        request)."""
        from .analyze import analyze_plan   # local: avoid import cycle
        entry = self.get(key)
        if entry is None:
            return None, None
        rep = verify_static(entry.plan, mesh)
        if not rep.ok:
            self.stats["rejected"] += 1
            self._lru.pop(key, None)
            return None, rep
        arep = analyze_plan(entry.plan, mesh)
        if not arep.ok:
            self.stats["hazard_rejected"] += 1
            self._lru.pop(key, None)
            return None, rep
        return entry, rep

    # -- store path ------------------------------------------------------
    def put(self, entry: CachedPlan) -> bool:
        """Store an entry (memory + atomic disk write).  Returns False —
        never raises — when the disk write fails (the entry still lands
        in the LRU: this process keeps its work either way)."""
        self._remember(entry)
        path = self._path(entry.key)
        if path is None:
            self.stats["stores"] += 1
            return True
        try:
            fault_point("cache.store")
            blob = entry.to_json()
            tmp = path.with_suffix(f".tmp.{os.getpid()}")
            tmp.write_text(blob)
            os.replace(tmp, path)  # atomic: readers see old or new, never half
        except Exception:
            self.stats["store_errors"] += 1
            return False
        self.stats["stores"] += 1
        return True

    # -- warm-start donor selection --------------------------------------
    def nearest(self, key: PlanKey) -> CachedPlan | None:
        """Closest stored entry to ``key`` (which itself missed): same
        config fingerprint outranks same mesh outranks same bucket —
        an identical architecture on a different mesh or shape bucket
        is a far better seed than a different architecture anywhere.
        Exact-key entries are excluded (that is :meth:`get`'s job)."""
        best: CachedPlan | None = None
        best_score = 0
        for cand in self._iter_entries():
            if cand.key == key:
                continue
            score = (4 * (cand.key.fingerprint == key.fingerprint)
                     + 2 * (cand.key.mesh == key.mesh)
                     + (cand.key.bucket == key.bucket))
            if score > best_score:
                best, best_score = cand, score
        return best

    def _iter_entries(self):
        seen: set[PlanKey] = set()
        for entry in reversed(self._lru.values()):  # most recent first
            seen.add(entry.key)
            yield entry
        if self.root is None:
            return
        try:
            paths = sorted(self.root.glob("*.json"))
        except OSError:
            return
        for path in paths:
            try:
                fault_point("cache.load")
                entry = CachedPlan.from_json(path.read_text())
            except Exception:
                self.stats["corrupt"] += 1
                continue
            if entry.key not in seen:
                seen.add(entry.key)
                yield entry


def fetch_or_optimize(cache: PlanCache, key: PlanKey, mesh: MeshSpec,
                      graph_factory: Callable[[], object], *,
                      optimize_kwargs: dict | None = None
                      ) -> tuple[ShardingPlan, str, object]:
    """The serving compile path: cache hit → warm re-DSE → cold DSE.

    Args:
        cache: the plan cache.
        key: identity of the requested (config, mesh, bucket).
        mesh: target mesh (must match ``key.mesh``; verified statically
            on every cache-served plan).
        graph_factory: zero-arg callable building a fresh Functional
            graph for the config+shape — only invoked on a miss, so a
            hit pays no graph construction.
        optimize_kwargs: forwarded to :func:`repro.core.optimize.optimize`
            (e.g. ``training=False``, ``budget_s``).

    Returns:
        ``(plan, source, report)`` where ``source`` is ``"hit"``,
        ``"warm"`` or ``"cold"`` and ``report`` is the
        :class:`OptimizeReport` (``None`` on a hit).
    """
    from .optimize import optimize          # local: avoid import cycle
    from .parallelize import canonical_snapshot

    entry, _rep = cache.fetch(key, mesh)
    if entry is not None:
        return entry.plan, "hit", None

    donor = cache.nearest(key)
    kw = dict(optimize_kwargs or {})
    if donor is not None:
        kw["warm_start"] = donor.snapshot
    sched, plan, report = optimize(graph_factory(), mesh, **kw)

    # Store only what the exit verifier passed clean — the load path's
    # static gate assumes store-time full verification.
    if report.verify is not None and report.verify.ok \
            and report.cost is not None:
        cache.put(CachedPlan(
            key=key, plan=plan, snapshot=canonical_snapshot(sched),
            qor_total_s=report.cost.total_s, stored_unix=time.time()))
    return plan, ("warm" if donor is not None else "cold"), report
