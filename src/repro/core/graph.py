"""Model → Functional dataflow graph extraction.

Builds the HIDA-IR Functional graph for one *representative super-block*
of an architecture (the smallest repeating layer pattern, e.g. Jamba's
period-8 Mamba/attention group) plus the embedding and LM-head stages.
Because every repetition of the super-block is isomorphic, HIDA-OPT's plan
for the representative block applies to all layers (the models scan over
stacked parameters); ``Graph`` carries ``repeat_factor`` so the estimator
reports absolute per-step numbers.

Buffer names follow ``L{j}__{role}`` so ``build_plan`` can expose
per-role sharding sites (``qkv``, ``attn_ctx``, ``ffn_hidden``,
``moe_dispatched``, ``residual`` …) that the JAX models reference at their
``with_sharding_constraint`` sites.

All loop-dim names are drawn from a fixed vocabulary (batch, seq, kv_seq,
heads, kv_heads, d_head, d_model, d_ff, experts, cap, vocab, d_state,
d_inner, img_seq, kv_lora, q_lora) — the connection analysis aligns them
across nodes exactly like the paper's permutation maps align loop levels.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.base import ArchConfig, ShapeSpec
from .ir import AccessMap, Graph, TensorValue

BF = "bf16"


@dataclass
class GraphMeta:
    repeat_factor: int = 1
    layer_counts: dict[str, int] | None = None


def _mm(g: Graph, name: str, x: str, w: str, out: str,
        loop_dims: dict[str, int], flops: int, **attrs):
    return g.op("matmul", [x, w], [out], loop_dims, flops=flops,
                name=name, **attrs)


def _ew(g: Graph, name: str, ins: list[str], out: str,
        loop_dims: dict[str, int], flops_per_elem: int = 1, kind: str =
        "elementwise", **attrs):
    n = 1
    for v in loop_dims.values():
        n *= v
    if kind == "norm":
        attrs.setdefault("reduce", ("d_model",))
    return g.op(kind, ins, [out], loop_dims, flops=n * flops_per_elem,
                name=name, **attrs)


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _attn_block(g: Graph, pre: str, cfg: ArchConfig, resid: str,
                B: int, S: int, KV: int, decode: bool,
                cross_kv: str | None = None) -> str:
    D, H, KVH = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    Dh = cfg.resolved_head_dim
    eff_kv = min(KV, cfg.attn_window) if cfg.attn_window else KV

    xn = g.tensor(f"{pre}__attn_norm", (B, S, D), BF,
                  ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_norm1", [resid], xn.name,
        {"batch": B, "seq": S, "d_model": D}, 5, kind="norm")

    wq = g.tensor(f"{pre}__w_q", (D, H, Dh), BF,
                  ("d_model", "heads", "d_head"), is_weight=True)
    q = g.tensor(f"{pre}__q", (B, S, H, Dh), BF,
                 ("batch", "seq", "heads", "d_head"))
    _mm(g, f"{pre}_q_proj", xn.name, wq.name, q.name,
        {"batch": B, "seq": S, "d_model": D, "heads": H, "d_head": Dh},
        2 * B * S * D * H * Dh)

    kv_src = cross_kv or xn.name
    kv_len = g.values[kv_src].shape[1] if cross_kv else S
    wkv = g.tensor(f"{pre}__w_kv", (D, 2, KVH, Dh), BF,
                   ("d_model", "two", "kv_heads", "d_head"), is_weight=True)
    k = g.tensor(f"{pre}__k", (B, kv_len, KVH, Dh), BF,
                 ("batch", "kv_seq", "kv_heads", "d_head"))
    v = g.tensor(f"{pre}__v", (B, kv_len, KVH, Dh), BF,
                 ("batch", "kv_seq", "kv_heads", "d_head"))
    g.op("matmul", [kv_src, wkv.name], [k.name, v.name],
         {"batch": B, "kv_seq": kv_len, "d_model": D, "kv_heads": KVH,
          "d_head": Dh},
         flops=2 * 2 * B * kv_len * D * KVH * Dh,
         name=f"{pre}_kv_proj",
         access={kv_src: AccessMap.of(("batch", 1), ("kv_seq", 1),
                                      (None, 1))})

    if decode and cross_kv is None:
        cache_k = g.tensor(f"{pre}__kv_cache_k", (B, KV, KVH, Dh), BF,
                           ("batch", "kv_seq", "kv_heads", "d_head"))
        cache_v = g.tensor(f"{pre}__kv_cache_v", (B, KV, KVH, Dh), BF,
                           ("batch", "kv_seq", "kv_heads", "d_head"))
        g.inputs += [cache_k.name, cache_v.name]
        # Two writers of the cache (k-update, v-update) → the
        # multi-producer pass legalises this (Alg. 3).
        g.op("cache_update", [k.name, cache_k.name], [cache_k.name],
             {"batch": B, "kv_heads": KVH, "d_head": Dh},
             name=f"{pre}_cache_k_upd")
        g.op("cache_update", [v.name, cache_v.name], [cache_v.name],
             {"batch": B, "kv_heads": KVH, "d_head": Dh},
             name=f"{pre}_cache_v_upd")
        k_use, v_use, att_kv = cache_k.name, cache_v.name, eff_kv
    else:
        k_use, v_use, att_kv = k.name, v.name, (eff_kv if not cross_kv
                                                else kv_len)

    ctx = g.tensor(f"{pre}__attn_ctx", (B, S, H, Dh), BF,
                   ("batch", "seq", "heads", "d_head"))
    g.op("attention", [q.name, k_use, v_use], [ctx.name],
         {"batch": B, "seq": S, "kv_seq": att_kv, "heads": H,
          "d_head": Dh},
         flops=4 * B * H * S * att_kv * Dh,
         name=f"{pre}_attention",
         window=cfg.attn_window,
         reduce=("d_head",),  # QK^T contracts d_head (kv_seq is inferred)
         access={
             q.name: AccessMap.of(("batch", 1), ("seq", 1), ("heads", 1),
                                  ("d_head", 1)),
             k_use: AccessMap.of(("batch", 1), ("kv_seq", 1),
                                 ("kv_heads", 1), ("d_head", 1)),
             v_use: AccessMap.of(("batch", 1), ("kv_seq", 1),
                                 ("kv_heads", 1), ("d_head", 1)),
         })

    wo = g.tensor(f"{pre}__w_o", (H, Dh, D), BF,
                  ("heads", "d_head", "d_model"), is_weight=True)
    attn_out = g.tensor(f"{pre}__attn_out", (B, S, D), BF,
                        ("batch", "seq", "d_model"))
    _mm(g, f"{pre}_o_proj", ctx.name, wo.name, attn_out.name,
        {"batch": B, "seq": S, "heads": H, "d_head": Dh, "d_model": D},
        2 * B * S * H * Dh * D)

    out = g.tensor(f"{pre}__residual", (B, S, D), BF,
                   ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_resid_add", [resid, attn_out.name], out.name,
        {"batch": B, "seq": S, "d_model": D}, 1, kind="residual")
    return out.name


def _mla_block(g: Graph, pre: str, cfg: ArchConfig, resid: str,
               B: int, S: int, KV: int, decode: bool) -> str:
    """DeepSeek MLA: low-rank Q and joint-KV compression; the decode cache
    holds only (kv_lora + rope_dim) per token."""
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    nope, rope, vdim = m.nope_dim, m.rope_dim, m.v_dim

    xn = g.tensor(f"{pre}__attn_norm", (B, S, D), BF,
                  ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_norm1", [resid], xn.name,
        {"batch": B, "seq": S, "d_model": D}, 5, kind="norm")

    wqa = g.tensor(f"{pre}__w_q_a", (D, m.q_lora), BF,
                   ("d_model", "q_lora"), is_weight=True)
    qa = g.tensor(f"{pre}__q_lora", (B, S, m.q_lora), BF,
                  ("batch", "seq", "q_lora"))
    _mm(g, f"{pre}_q_down", xn.name, wqa.name, qa.name,
        {"batch": B, "seq": S, "d_model": D, "q_lora": m.q_lora},
        2 * B * S * D * m.q_lora)
    wqb = g.tensor(f"{pre}__w_q_b", (m.q_lora, H, nope + rope), BF,
                   ("q_lora", "heads", "d_head"), is_weight=True)
    q = g.tensor(f"{pre}__q", (B, S, H, nope + rope), BF,
                 ("batch", "seq", "heads", "d_head"))
    _mm(g, f"{pre}_q_up", qa.name, wqb.name, q.name,
        {"batch": B, "seq": S, "q_lora": m.q_lora, "heads": H,
         "d_head": nope + rope},
        2 * B * S * m.q_lora * H * (nope + rope))

    wkva = g.tensor(f"{pre}__w_kv_a", (D, m.kv_lora + rope), BF,
                    ("d_model", "kv_lora"), is_weight=True)
    ckv = g.tensor(f"{pre}__c_kv", (B, S, m.kv_lora + rope), BF,
                   ("batch", "kv_seq", "kv_lora"))
    _mm(g, f"{pre}_kv_down", xn.name, wkva.name, ckv.name,
        {"batch": B, "kv_seq": S, "d_model": D,
         "kv_lora": m.kv_lora + rope},
        2 * B * S * D * (m.kv_lora + rope),
        access={xn.name: AccessMap.of(("batch", 1), ("kv_seq", 1),
                                      (None, 1))})

    if decode:
        cache = g.tensor(f"{pre}__kv_cache", (B, KV, m.kv_lora + rope), BF,
                         ("batch", "kv_seq", "kv_lora"))
        g.inputs.append(cache.name)
        g.op("cache_update", [ckv.name, cache.name], [cache.name],
             {"batch": B, "kv_lora": m.kv_lora + rope},
             name=f"{pre}_cache_upd")
        kv_use, att_kv = cache.name, KV
    else:
        kv_use, att_kv = ckv.name, S

    # Absorbed attention over the latent cache: score/combine FLOPs scale
    # with (kv_lora+rope), plus per-head absorb projections.
    ctx = g.tensor(f"{pre}__attn_ctx", (B, S, H, m.kv_lora), BF,
                   ("batch", "seq", "heads", "kv_lora"))
    wuk = g.tensor(f"{pre}__w_uk", (H, nope, m.kv_lora), BF,
                   ("heads", "d_head", "kv_lora"), is_weight=True)
    g.op("attention", [q.name, kv_use, wuk.name], [ctx.name],
         {"batch": B, "seq": S, "kv_seq": att_kv, "heads": H,
          "kv_lora": m.kv_lora + rope},
         flops=(2 * B * S * H * nope * m.kv_lora          # q absorb
                + 4 * B * H * S * att_kv * (m.kv_lora + rope)),
         name=f"{pre}_attention",
         access={
             q.name: AccessMap.of(("batch", 1), ("seq", 1), ("heads", 1),
                                  (None, 1)),
             kv_use: AccessMap.of(("batch", 1), ("kv_seq", 1),
                                  ("kv_lora", 1)),
             wuk.name: AccessMap.of(("heads", 1), (None, 1),
                                    ("kv_lora", 1)),
         })

    wuv = g.tensor(f"{pre}__w_uv_o", (H, m.kv_lora, D), BF,
                   ("heads", "kv_lora", "d_model"), is_weight=True)
    attn_out = g.tensor(f"{pre}__attn_out", (B, S, D), BF,
                        ("batch", "seq", "d_model"))
    _mm(g, f"{pre}_o_proj", ctx.name, wuv.name, attn_out.name,
        {"batch": B, "seq": S, "heads": H, "kv_lora": m.kv_lora,
         "d_model": D},
        2 * B * S * H * m.kv_lora * D)

    out = g.tensor(f"{pre}__residual", (B, S, D), BF,
                   ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_resid_add", [resid, attn_out.name], out.name,
        {"batch": B, "seq": S, "d_model": D}, 1, kind="residual")
    return out.name


def _mamba_block(g: Graph, pre: str, cfg: ArchConfig, resid: str,
                 B: int, S: int, decode: bool) -> str:
    mb = cfg.mamba
    D = cfg.d_model
    Din = mb.expand * D
    N = mb.d_state

    xn = g.tensor(f"{pre}__mix_norm", (B, S, D), BF,
                  ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_norm1", [resid], xn.name,
        {"batch": B, "seq": S, "d_model": D}, 5, kind="norm")

    w_in = g.tensor(f"{pre}__w_in", (D, 2 * Din), BF,
                    ("d_model", "d_inner"), is_weight=True)
    xz = g.tensor(f"{pre}__xz", (B, S, 2 * Din), BF,
                  ("batch", "seq", "d_inner"))
    _mm(g, f"{pre}_in_proj", xn.name, w_in.name, xz.name,
        {"batch": B, "seq": S, "d_model": D, "d_inner": 2 * Din},
        2 * B * S * D * 2 * Din)

    conv = g.tensor(f"{pre}__conv", (B, S, Din), BF,
                    ("batch", "seq", "d_inner"))
    g.op("conv", [xz.name], [conv.name],
         {"batch": B, "seq": S, "d_inner": Din},
         flops=2 * B * S * Din * mb.d_conv, name=f"{pre}_conv1d")

    w_xp = g.tensor(f"{pre}__w_xproj", (Din, 2 * N + 16), BF,
                    ("d_inner", "d_state"), is_weight=True)
    bcd = g.tensor(f"{pre}__bcdt", (B, S, 2 * N + 16), BF,
                   ("batch", "seq", "d_state"))
    _mm(g, f"{pre}_x_proj", conv.name, w_xp.name, bcd.name,
        {"batch": B, "seq": S, "d_inner": Din, "d_state": 2 * N + 16},
        2 * B * S * Din * (2 * N + 16))

    if decode:
        state = g.tensor(f"{pre}__ssm_state", (B, Din, N), "f32",
                         ("batch", "d_inner", "d_state"))
        g.inputs.append(state.name)
        y = g.tensor(f"{pre}__scan_out", (B, S, Din), BF,
                     ("batch", "seq", "d_inner"))
        g.op("scan", [conv.name, bcd.name, state.name],
             [y.name, state.name],
             {"batch": B, "d_inner": Din, "d_state": N},
             flops=6 * B * Din * N, name=f"{pre}_ssm_step")
    else:
        y = g.tensor(f"{pre}__scan_out", (B, S, Din), BF,
                     ("batch", "seq", "d_inner"))
        g.op("scan", [conv.name, bcd.name], [y.name],
             {"batch": B, "seq": S, "d_inner": Din, "d_state": N},
             flops=6 * B * S * Din * N, name=f"{pre}_ssm_scan",
             chunk=mb.chunk)

    w_out = g.tensor(f"{pre}__w_out", (Din, D), BF,
                     ("d_inner", "d_model"), is_weight=True)
    mix_out = g.tensor(f"{pre}__mix_out", (B, S, D), BF,
                       ("batch", "seq", "d_model"))
    _mm(g, f"{pre}_out_proj", y.name, w_out.name, mix_out.name,
        {"batch": B, "seq": S, "d_inner": Din, "d_model": D},
        2 * B * S * Din * D)

    out = g.tensor(f"{pre}__residual", (B, S, D), BF,
                   ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_resid_add", [resid, mix_out.name], out.name,
        {"batch": B, "seq": S, "d_model": D}, 1, kind="residual")
    return out.name


def _xlstm_block(g: Graph, pre: str, cfg: ArchConfig, resid: str,
                 B: int, S: int, kind: str, decode: bool) -> str:
    x = cfg.xlstm
    D = cfg.d_model
    xn = g.tensor(f"{pre}__mix_norm", (B, S, D), BF,
                  ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_norm1", [resid], xn.name,
        {"batch": B, "seq": S, "d_model": D}, 5, kind="norm")

    if kind == "mlstm":
        Din = x.proj_factor_mlstm * D
        w_up = g.tensor(f"{pre}__w_up", (D, 2 * Din), BF,
                        ("d_model", "d_inner"), is_weight=True)
        up = g.tensor(f"{pre}__up", (B, S, 2 * Din), BF,
                      ("batch", "seq", "d_inner"))
        _mm(g, f"{pre}_up_proj", xn.name, w_up.name, up.name,
            {"batch": B, "seq": S, "d_model": D, "d_inner": 2 * Din},
            2 * B * S * D * 2 * Din)
        Dh = Din // cfg.n_heads
        y = g.tensor(f"{pre}__scan_out", (B, S, Din), BF,
                     ("batch", "seq", "d_inner"))
        flops = (4 * B * S * x.chunk * Din        # intra-chunk quadratic
                 + 8 * B * S * Din * Dh)          # inter-chunk state
        loop = {"batch": B, "seq": S, "heads": cfg.n_heads,
                "d_inner": Din}
        if decode:
            state = g.tensor(f"{pre}__mlstm_state",
                             (B, cfg.n_heads, Dh, Dh), "f32",
                             ("batch", "heads", "d_head", "d_head2"))
            g.inputs.append(state.name)
            g.op("scan", [up.name, state.name], [y.name, state.name],
                 {"batch": B, "heads": cfg.n_heads, "d_inner": Din},
                 flops=8 * B * Din * Dh, name=f"{pre}_mlstm_step")
        else:
            g.op("scan", [up.name], [y.name], loop, flops=flops,
                 name=f"{pre}_mlstm_chunk", chunk=x.chunk)
        w_dn = g.tensor(f"{pre}__w_down", (Din, D), BF,
                        ("d_inner", "d_model"), is_weight=True)
        mix = g.tensor(f"{pre}__mix_out", (B, S, D), BF,
                       ("batch", "seq", "d_model"))
        _mm(g, f"{pre}_down_proj", y.name, w_dn.name, mix.name,
            {"batch": B, "seq": S, "d_inner": Din, "d_model": D},
            2 * B * S * Din * D)
    else:  # slstm: sequence-sequential recurrence — seq is NOT shardable
        w_g = g.tensor(f"{pre}__w_gates", (D, 4 * D), BF,
                       ("d_model", "d_inner"), is_weight=True)
        gates = g.tensor(f"{pre}__gates", (B, S, 4 * D), BF,
                         ("batch", "seq", "d_inner"))
        _mm(g, f"{pre}_gate_proj", xn.name, w_g.name, gates.name,
            {"batch": B, "seq": S, "d_model": D, "d_inner": 4 * D},
            2 * B * S * D * 4 * D)
        y = g.tensor(f"{pre}__scan_out", (B, S, D), BF,
                     ("batch", "seq", "d_model"))
        g.op("scan", [gates.name], [y.name],
             {"batch": B, "seq": S, "heads": cfg.n_heads,
              "d_model": D},
             flops=20 * B * S * D, name=f"{pre}_slstm_scan",
             no_shard=("seq",))
        w_f = g.tensor(f"{pre}__w_ffn", (D, 2 * x.d_ff_slstm), BF,
                       ("d_model", "d_ff"), is_weight=True)
        w_f2 = g.tensor(f"{pre}__w_ffn2", (x.d_ff_slstm, D), BF,
                        ("d_ff", "d_model"), is_weight=True)
        h = g.tensor(f"{pre}__ffn_hidden", (B, S, x.d_ff_slstm), BF,
                     ("batch", "seq", "d_ff"))
        _mm(g, f"{pre}_ffn_in", y.name, w_f.name, h.name,
            {"batch": B, "seq": S, "d_model": D, "d_ff": x.d_ff_slstm},
            2 * B * S * D * 2 * x.d_ff_slstm)
        mix = g.tensor(f"{pre}__mix_out", (B, S, D), BF,
                       ("batch", "seq", "d_model"))
        _mm(g, f"{pre}_ffn_out", h.name, w_f2.name, mix.name,
            {"batch": B, "seq": S, "d_ff": x.d_ff_slstm, "d_model": D},
            2 * B * S * x.d_ff_slstm * D)

    out = g.tensor(f"{pre}__residual", (B, S, D), BF,
                   ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_resid_add", [resid, mix.name], out.name,
        {"batch": B, "seq": S, "d_model": D}, 1, kind="residual")
    return out.name


def _dense_ffn(g: Graph, pre: str, cfg: ArchConfig, resid: str,
               B: int, S: int, d_ff: int) -> str:
    D = cfg.d_model
    xn = g.tensor(f"{pre}__ffn_norm", (B, S, D), BF,
                  ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_norm2", [resid], xn.name,
        {"batch": B, "seq": S, "d_model": D}, 5, kind="norm")
    w_in = g.tensor(f"{pre}__w_ffn_in", (D, 2, d_ff), BF,
                    ("d_model", "two", "d_ff"), is_weight=True)
    h = g.tensor(f"{pre}__ffn_hidden", (B, S, d_ff), BF,
                 ("batch", "seq", "d_ff"))
    _mm(g, f"{pre}_ffn_in", xn.name, w_in.name, h.name,
        {"batch": B, "seq": S, "d_model": D, "d_ff": d_ff},
        2 * B * S * D * 2 * d_ff)
    ha = g.tensor(f"{pre}__ffn_act", (B, S, d_ff), BF,
                  ("batch", "seq", "d_ff"))
    _ew(g, f"{pre}_swiglu", [h.name], ha.name,
        {"batch": B, "seq": S, "d_ff": d_ff}, 4, kind="activation")
    w_out = g.tensor(f"{pre}__w_ffn_out", (d_ff, D), BF,
                     ("d_ff", "d_model"), is_weight=True)
    f = g.tensor(f"{pre}__ffn_out", (B, S, D), BF,
                 ("batch", "seq", "d_model"))
    _mm(g, f"{pre}_ffn_out", ha.name, w_out.name, f.name,
        {"batch": B, "seq": S, "d_ff": d_ff, "d_model": D},
        2 * B * S * d_ff * D)
    out = g.tensor(f"{pre}__residual2", (B, S, D), BF,
                   ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_resid_add2", [resid, f.name], out.name,
        {"batch": B, "seq": S, "d_model": D}, 1, kind="residual")
    return out.name


def _moe_ffn(g: Graph, pre: str, cfg: ArchConfig, resid: str,
             B: int, S: int) -> str:
    moe = cfg.moe
    D, E, K = cfg.d_model, moe.n_experts, moe.top_k
    Fe = moe.d_expert
    tokens = B * S
    cap = max(1, int(tokens * K * moe.capacity_factor) // E)

    xn = g.tensor(f"{pre}__ffn_norm", (B, S, D), BF,
                  ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_norm2", [resid], xn.name,
        {"batch": B, "seq": S, "d_model": D}, 5, kind="norm")

    w_r = g.tensor(f"{pre}__w_router", (D, E), "f32",
                   ("d_model", "experts"), is_weight=True)
    logits = g.tensor(f"{pre}__router_logits", (B, S, E), "f32",
                      ("batch", "seq", "experts"))
    _mm(g, f"{pre}_router", xn.name, w_r.name, logits.name,
        {"batch": B, "seq": S, "d_model": D, "experts": E},
        2 * B * S * D * E)
    g.values[f"{pre}_router_op_marker"] = TensorValue(
        f"{pre}_router_op_marker", (1,), "f32")  # placeholder (unused)

    disp = g.tensor(f"{pre}__moe_dispatched", (E, cap, D), BF,
                    ("experts", "cap", "d_model"))
    g.op("moe_dispatch", [xn.name, logits.name], [disp.name],
         {"experts": E, "cap": cap, "d_model": D},
         flops=tokens * K * D, name=f"{pre}_dispatch",
         access={xn.name: AccessMap.of(("batch", 1), (None, 1), ("d_model", 1)),
                 logits.name: AccessMap.of(("batch", 1), (None, 1),
                                           ("experts", 1))})

    w_e1 = g.tensor(f"{pre}__w_exp_in", (E, D, 2, Fe), BF,
                    ("experts", "d_model", "two", "d_ff"), is_weight=True)
    eh = g.tensor(f"{pre}__expert_hidden", (E, cap, Fe), BF,
                  ("experts", "cap", "d_ff"))
    _mm(g, f"{pre}_expert_in", disp.name, w_e1.name, eh.name,
        {"experts": E, "cap": cap, "d_model": D, "d_ff": Fe},
        2 * E * cap * D * 2 * Fe)
    w_e2 = g.tensor(f"{pre}__w_exp_out", (E, Fe, D), BF,
                    ("experts", "d_ff", "d_model"), is_weight=True)
    eo = g.tensor(f"{pre}__expert_out", (E, cap, D), BF,
                  ("experts", "cap", "d_model"))
    _mm(g, f"{pre}_expert_out", eh.name, w_e2.name, eo.name,
        {"experts": E, "cap": cap, "d_ff": Fe, "d_model": D},
        2 * E * cap * Fe * D)

    comb = g.tensor(f"{pre}__moe_out", (B, S, D), BF,
                    ("batch", "seq", "d_model"))
    g.op("moe_combine", [eo.name, logits.name], [comb.name],
         {"batch": B, "seq": S, "d_model": D},
         flops=tokens * K * D, name=f"{pre}_combine",
         access={eo.name: AccessMap.of((None, 1), (None, 1),
                                       ("d_model", 1)),
                 logits.name: AccessMap.of(("batch", 1), ("seq", 1),
                                           (None, 1))})

    paths = [comb.name]
    if moe.n_shared:
        # Shared-expert path runs in parallel with routed dispatch — the
        # short/long path pair the balancing pass handles (Fig. 8).
        Fs = moe.n_shared * Fe
        w_s1 = g.tensor(f"{pre}__w_shared_in", (D, 2, Fs), BF,
                        ("d_model", "two", "d_ff"), is_weight=True)
        sh = g.tensor(f"{pre}__shared_hidden", (B, S, Fs), BF,
                      ("batch", "seq", "d_ff"))
        _mm(g, f"{pre}_shared_in", xn.name, w_s1.name, sh.name,
            {"batch": B, "seq": S, "d_model": D, "d_ff": Fs},
            2 * B * S * D * 2 * Fs)
        w_s2 = g.tensor(f"{pre}__w_shared_out", (Fs, D), BF,
                        ("d_ff", "d_model"), is_weight=True)
        so = g.tensor(f"{pre}__shared_out", (B, S, D), BF,
                      ("batch", "seq", "d_model"))
        _mm(g, f"{pre}_shared_out", sh.name, w_s2.name, so.name,
            {"batch": B, "seq": S, "d_ff": Fs, "d_model": D},
            2 * B * S * Fs * D)
        paths.append(so.name)

    out = g.tensor(f"{pre}__residual2", (B, S, D), BF,
                   ("batch", "seq", "d_model"))
    _ew(g, f"{pre}_resid_add2", [resid] + paths, out.name,
        {"batch": B, "seq": S, "d_model": D}, 1, kind="residual")
    return out.name


# --------------------------------------------------------------------------
# Full graph
# --------------------------------------------------------------------------

def step_flops(graph: Graph, mode: str) -> float:
    """Analytic whole-step FLOPs from the IR (op.flops × per-iteration
    repeat × super-block repeat count).  Used for the roofline compute
    term because XLA's cost analysis counts while-loop (layer-scan) bodies
    once regardless of trip count.  Training ≈ 3× forward."""
    r = graph.meta.repeat_factor  # type: ignore[attr-defined]
    fwd = sum(o.flops * o.repeat * r for o in graph.leaf_ops())
    return fwd * (3.0 if mode == "train" else 1.0)


def model_flops_6nd(cfg: ArchConfig, tokens: int) -> float:
    """The 6·N·D convention (6·N_active·D for MoE) for §Roofline."""
    # Active params: embed + per-layer weights with MoE counted at top-k.
    active = cfg.vocab * cfg.d_model
    for i in range(cfg.n_layers):
        mix, ffn = cfg.block_kind(i), cfg.ffn_kind(i)
        D = cfg.d_model
        if mix in ("attn", "xattn"):
            if cfg.mla is not None:
                m = cfg.mla
                active += (D * m.q_lora
                           + m.q_lora * cfg.n_heads * (m.nope_dim + m.rope_dim)
                           + D * (m.kv_lora + m.rope_dim)
                           + cfg.n_heads * m.kv_lora * (m.nope_dim + m.v_dim)
                           + cfg.n_heads * m.v_dim * D)
            else:
                Dh = cfg.resolved_head_dim
                active += D * Dh * (cfg.n_heads + 2 * cfg.n_kv_heads) \
                    + cfg.n_heads * Dh * D
        elif mix == "mamba":
            mb = cfg.mamba
            Din = mb.expand * D
            active += D * 2 * Din + Din * D + Din * (2 * mb.d_state + 16)
        elif mix == "mlstm":
            Din = cfg.xlstm.proj_factor_mlstm * D
            active += D * 2 * Din + Din * 3 * Din + Din * D
        elif mix == "slstm":
            active += 8 * D * D + 3 * D * cfg.xlstm.d_ff_slstm
        if ffn == "dense":
            active += 3 * D * (cfg.dense_d_ff or cfg.d_ff)
        elif ffn == "moe":
            moe = cfg.moe
            active += (3 * D * moe.d_expert * (moe.top_k + moe.n_shared)
                       + D * moe.n_experts)
    if not cfg.tie_embeddings:
        active += cfg.d_model * cfg.vocab
    return 6.0 * active * tokens


def build_lm_graph(cfg: ArchConfig, shape: ShapeSpec) -> Graph:
    decode = shape.mode == "decode"
    B = shape.global_batch
    S = 1 if decode else shape.seq_len
    KV = shape.seq_len
    D, V = cfg.d_model, cfg.vocab

    g = Graph(name=f"{cfg.name}_{shape.name}")
    groups = cfg.layer_groups()
    pattern, repeats = max(groups, key=lambda gr: len(gr[0]) * gr[1])
    # Ops outside the repeated super-block run once per step, i.e. 1/repeats
    # per block iteration — amortize so balancing sees steady-state costs.
    amort = 1.0 / max(repeats, 1)

    # ---- frontend -----------------------------------------------------------
    if cfg.frontend == "audio_frames":
        resid = g.tensor("frames", (B, S, D), BF,
                         ("batch", "seq", "d_model"), is_input=True).name
    else:
        tokens = g.tensor("tokens", (B, S), "i32", ("batch", "seq"),
                          is_input=True)
        emb = g.tensor("emb_table", (V, D), BF, ("vocab", "d_model"),
                       is_weight=True)
        resid_t = g.tensor("embed_out", (B, S, D), BF,
                           ("batch", "seq", "d_model"))
        embed_op = g.op(
            "gather", [tokens.name, emb.name], [resid_t.name],
            {"batch": B, "seq": S, "d_model": D}, flops=0, name="embed",
            access={emb.name: AccessMap.of((None, 1), ("d_model", 1))})
        embed_op.repeat = amort
        resid = resid_t.name
    img = None
    if cfg.frontend == "vision":
        img = g.tensor("img_embeds", (B, cfg.n_img_tokens, D), BF,
                       ("batch", "kv_seq", "d_model"), is_input=True).name

    # ---- representative super-block ----------------------------------------
    for j, (mix, ffn) in enumerate(pattern):
        pre = f"L{j}_{mix}"
        if mix == "attn":
            if cfg.mla is not None:
                resid = _mla_block(g, pre, cfg, resid, B, S, KV, decode)
            else:
                resid = _attn_block(g, pre, cfg, resid, B, S, KV, decode)
        elif mix == "xattn":
            resid = _attn_block(g, pre, cfg, resid, B, S, KV, decode,
                                cross_kv=img)
        elif mix == "mamba":
            resid = _mamba_block(g, pre, cfg, resid, B, S, decode)
        elif mix in ("mlstm", "slstm"):
            resid = _xlstm_block(g, pre, cfg, resid, B, S, mix, decode)
        if ffn == "dense":
            resid = _dense_ffn(g, pre, cfg, resid, B, S,
                               cfg.dense_d_ff or cfg.d_ff)
        elif ffn == "moe":
            resid = _moe_ffn(g, pre, cfg, resid, B, S)

    # ---- head ----------------------------------------------------------------
    fn = g.tensor("final_norm", (B, S, D), BF, ("batch", "seq", "d_model"))
    _ew(g, "final_norm_op", [resid], fn.name,
        {"batch": B, "seq": S, "d_model": D}, 5, kind="norm").repeat = amort
    w_head = g.tensor("w_head", (D, V), BF, ("d_model", "vocab"),
                      is_weight=True)
    logits = g.tensor("logits", (B, S, V), BF, ("batch", "seq", "vocab"))
    _mm(g, "lm_head", fn.name, w_head.name, logits.name,
        {"batch": B, "seq": S, "d_model": D, "vocab": V},
        2 * B * S * D * V).repeat = amort

    if shape.mode == "train":
        labels = g.tensor("labels", (B, S), "i32", ("batch", "seq"),
                          is_input=True)
        loss = g.tensor("loss", (), "f32", ())
        g.op("loss", [logits.name, labels.name], [loss.name],
             {"batch": B, "seq": S, "vocab": V},
             flops=4 * B * S * V, name="xent").repeat = amort
        g.outputs = [loss.name]
    else:
        g.outputs = [logits.name]

    # Backward ≈ 2x forward for training — reflected in the estimator via
    # meta, not by duplicating the graph (plan is identical for fwd/bwd).
    g.meta = GraphMeta(  # type: ignore[attr-defined]
        repeat_factor=repeats,
        layer_counts={k: sum(1 for a, b in cfg.layer_kinds()
                             if a == k or b == k)
                      for k in ("attn", "xattn", "mamba", "mlstm", "slstm",
                                "dense", "moe")})
    return g
