"""Independent static legality checker for ``(Schedule, ShardingPlan)``.

HIDA's pitch is fully-automated optimization the user never has to
inspect; ScaleHLS couples every transform to a legality check so the DSE
cannot commit an invalid point.  This module is our equivalent: a
*verifier* that shares no code path with the passes that construct the
artifacts it checks (it reads the schedule and plan, projects specs
through :func:`repro.core.plan._projected_spec`, and recomputes every
invariant from scratch), so a bug in a pass cannot also hide in the
check that was supposed to catch it.

``verify()`` returns a structured :class:`VerifyReport` — a list of
:class:`VerifyIssue` with machine-readable codes, not a bool — so the
degradation ladder in :func:`repro.core.optimize.optimize` can decide
*which* repair rung an illegal plan needs, and tests can assert on the
precise violation a hand-corrupted plan trips.

Check families (codes in parentheses; ``severity="error"`` unless
noted):

* **Topology** — the schedule's dataflow is acyclic
  (``topology-cycle``) and pipeline stages are monotone along every
  producer→consumer edge (``stage-order``).
* **Node assignments** — every ``axis_map`` axis exists in the mesh
  (``axis-unknown``), no mesh axis serves two dims of one node
  (``axis-conflict``), ``unroll`` factors equal the product of the
  assigned axes' sizes (``unroll-mismatch``) and divide the node's loop
  dims (``unroll-divisibility``).
* **Rules** — every rule's axes exist in the mesh (``axis-unknown``)
  and no rule assigns the same axis twice, i.e. never asks for more
  capacity than the mesh has on that axis (``rule-capacity``).
* **Buffer specs** — stored per-buffer specs have the buffer's rank
  (``spec-rank``), name only real mesh axes (``axis-unknown``), and —
  for coherent plans — equal the projection of the consensus rules
  through the buffer's merged access maps across *all* touching nodes
  (``spec-incoherent``); non-divisible shardings are legal under GSPMD
  padding but wasteful, so they are a ``warning`` (``spec-pad``).
* **Role aliases** — every alias resolves to an existing source buffer
  and mirrors its spec exactly (``alias-incoherent``).
* **HBM fit** — per-device resident bytes under the plan's shardings,
  using the same per-axis shard-factor model as the roofline
  estimator's ``_bytes_touched``; over an explicit
  ``hbm_capacity_bytes`` it is an error, over the default
  :data:`HBM_CAPACITY_BYTES` only a ``warning`` (big dense configs
  without ``fsdp`` legitimately exceed a single chip — the launch layer
  decides whether that is fatal) (``hbm-overflow``).

The verifier itself must never take the pipeline down: every check
family runs inside its own guard, and an unexpected exception inside a
check becomes a ``verify-internal`` error on the report instead of
propagating.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .estimator import MeshSpec
from .ir import Schedule, ScheduleTopology, topo_order_over
from .plan import ShardingPlan, _projected_spec

__all__ = ["VerifyIssue", "VerifyReport", "VerifyError", "verify",
           "verify_static", "HBM_CAPACITY_BYTES"]

#: TPU v5e per-chip HBM (16 GiB).  The default fit check warns (rather
#: than errors) above this — see the module docstring.
HBM_CAPACITY_BYTES = 16 * 1024 ** 3


class VerifyError(RuntimeError):
    """Raised by :meth:`VerifyReport.raise_if_failed`."""

    def __init__(self, report: "VerifyReport"):
        super().__init__(report.summary())
        self.report = report


@dataclass(frozen=True)
class VerifyIssue:
    code: str       # machine-readable check identifier (see module doc)
    severity: str   # "error" | "warning"
    site: str       # node / buffer / rule / alias name ("" = global)
    message: str

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"[{self.severity}:{self.code}] {self.site}: {self.message}"


@dataclass
class VerifyReport:
    issues: list[VerifyIssue] = field(default_factory=list)
    #: individual invariant evaluations performed (for "did it actually
    #: check anything" assertions — an empty schedule trivially passes).
    checks: int = 0
    stats: dict = field(default_factory=dict)
    elapsed_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.errors()

    def errors(self) -> list[VerifyIssue]:
        return [i for i in self.issues if i.severity == "error"]

    def warnings(self) -> list[VerifyIssue]:
        return [i for i in self.issues if i.severity == "warning"]

    def codes(self) -> set[str]:
        return {i.code for i in self.issues}

    def raise_if_failed(self) -> None:
        if not self.ok:
            raise VerifyError(self)

    def summary(self) -> str:
        errs, warns = self.errors(), self.warnings()
        if not errs and not warns:
            return f"verify: clean ({self.checks} checks)"
        head = (f"verify: {len(errs)} error(s), {len(warns)} warning(s) "
                f"over {self.checks} checks")
        lines = [str(i) for i in errs[:8]] + \
            ([f"... {len(errs) - 8} more errors"] if len(errs) > 8 else [])
        return "\n".join([head] + lines)


def _axes_of(entry) -> tuple[str, ...]:
    """Normalise a spec entry (tuple of axis names) defensively."""
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def verify(sched: Schedule, plan: ShardingPlan, mesh: MeshSpec, *,
           coherent: bool | None = None,
           hbm_capacity_bytes: int | None = None,
           topology: ScheduleTopology | None = None) -> VerifyReport:
    """Statically check that ``plan`` is a legal sharding of ``sched``
    on ``mesh``.  Read-only: neither the schedule nor the plan is
    mutated.  See the module docstring for the check families.

    Args:
        sched: the (parallelized) Structural schedule.
        plan: the sharding plan to validate against it.
        mesh: the target mesh the plan claims to shard over.
        coherent: whether buffer specs must equal the rule projection
            (the CA-on product).  ``None`` reads ``plan.meta["ca"]``
            (absent ⇒ not enforced), matching how ``optimize()`` builds
            plans.
        hbm_capacity_bytes: explicit per-device HBM budget — overflow
            becomes an *error*.  ``None`` checks against the default
            v5e capacity as a warning only.
        topology: shared :class:`ScheduleTopology` (defaults to the
            schedule's cached one).
    """
    t0 = time.perf_counter()
    rep = VerifyReport()
    names = set(mesh.names)
    if coherent is None:
        coherent = bool(plan.meta.get("ca", False)) if isinstance(
            plan.meta, dict) else False

    def issue(code: str, site: str, message: str,
              severity: str = "error") -> None:
        rep.issues.append(VerifyIssue(code, severity, site, message))

    def guarded(check):
        try:
            check()
        except Exception as e:  # the verifier must never crash a compile
            issue("verify-internal", check.__name__,
                  f"checker crashed: {type(e).__name__}: {e}")

    topo: ScheduleTopology | None = None

    # -- topology: acyclicity + stage monotonicity -----------------------
    def check_topology() -> None:
        nonlocal topo
        try:
            topo = topology or sched.topology()
        except Exception as e:
            issue("topology-cycle", sched.name,
                  f"topology construction failed: {e}")
            return
        rep.checks += 1
        try:
            topo_order_over(sched.nodes, topo.edges, sched.name)
        except ValueError as e:
            issue("topology-cycle", sched.name, str(e))
        for src, dst, bname in topo.edges:
            rep.checks += 1
            s_stage = sched.node(src).stage
            d_stage = sched.node(dst).stage
            if s_stage > d_stage:
                issue("stage-order", bname,
                      f"edge {src}(stage {s_stage}) -> "
                      f"{dst}(stage {d_stage}) runs backwards in the "
                      "pipeline stage map")

    # -- node assignments ------------------------------------------------
    def check_nodes() -> None:
        for node in sched.nodes:
            dims = node.loop_dims()
            used_axes: dict[str, str] = {}
            for dim, axes in node.axis_map.items():
                axes = _axes_of(axes)
                rep.checks += 1
                for a in axes:
                    if a not in names:
                        issue("axis-unknown", node.name,
                              f"dim {dim!r} assigned unknown mesh axis "
                              f"{a!r} (mesh has {sorted(names)})")
                    elif a in used_axes and used_axes[a] != dim:
                        issue("axis-conflict", node.name,
                              f"mesh axis {a!r} assigned to both "
                              f"{used_axes[a]!r} and {dim!r}")
                    else:
                        used_axes[a] = dim
                factor = 1
                for a in axes:
                    if a in names:
                        factor *= mesh.size(a)
                got = node.unroll.get(dim)
                if got != factor:
                    issue("unroll-mismatch", node.name,
                          f"dim {dim!r}: unroll {got} != product of "
                          f"axes {axes} = {factor}")
            for dim, f in node.unroll.items():
                rep.checks += 1
                if dim not in node.axis_map:
                    issue("unroll-mismatch", node.name,
                          f"unroll factor for dim {dim!r} has no "
                          "axis_map entry")
                size = dims.get(dim)
                if size is not None and f and size % f != 0:
                    issue("unroll-divisibility", node.name,
                          f"dim {dim!r} extent {size} not divisible by "
                          f"unroll {f}")

    # -- rules -----------------------------------------------------------
    def check_rules() -> None:
        for dim, axes in plan.rules.items():
            axes = _axes_of(axes)
            rep.checks += 1
            for a in axes:
                if a not in names:
                    issue("axis-unknown", dim,
                          f"rule names unknown mesh axis {a!r}")
            if len(set(axes)) != len(axes):
                issue("rule-capacity", dim,
                      f"rule {axes} assigns a mesh axis more than once "
                      "— exceeds that axis's capacity")

    # -- buffer specs ----------------------------------------------------
    def check_buffer_specs() -> None:
        if topo is None:
            return
        for bname, buf in sched.buffers.items():
            spec = plan.buffer_specs.get(bname)
            if spec is None:
                continue
            rep.checks += 1
            if len(spec) != len(buf.shape):
                issue("spec-rank", bname,
                      f"spec rank {len(spec)} != buffer rank "
                      f"{len(buf.shape)}")
                continue
            seen: set[str] = set()
            for axis_idx, entry in enumerate(spec):
                axes = _axes_of(entry)
                factor = 1
                for a in axes:
                    if a not in names:
                        issue("axis-unknown", bname,
                              f"spec axis {axis_idx} names unknown mesh "
                              f"axis {a!r}")
                    elif a not in seen:
                        seen.add(a)
                        factor *= mesh.size(a)
                if factor > 1 and buf.shape[axis_idx] % factor != 0:
                    issue("spec-pad", bname,
                          f"axis {axis_idx} extent "
                          f"{buf.shape[axis_idx]} not divisible by "
                          f"shard factor {factor} (GSPMD will pad)",
                          severity="warning")
            if coherent and topo.owners(bname):
                want = _projected_spec(plan.rules, topo.axis_dims[bname])
                got = tuple(_axes_of(e) for e in spec)
                if got != tuple(_axes_of(e) for e in want):
                    issue("spec-incoherent", bname,
                          f"stored spec {got} != rule projection {want} "
                          "through the buffer's access maps")

    # -- role aliases ----------------------------------------------------
    def check_aliases() -> None:
        for role, source in plan.role_sources.items():
            rep.checks += 1
            if source not in plan.buffer_specs:
                issue("alias-incoherent", role,
                      f"alias source {source!r} has no spec")
                continue
            if plan.buffer_specs.get(role) != plan.buffer_specs[source]:
                issue("alias-incoherent", role,
                      f"alias spec {plan.buffer_specs.get(role)} != "
                      f"source {source!r} spec "
                      f"{plan.buffer_specs[source]}")

    # -- per-device HBM fit ---------------------------------------------
    def check_hbm() -> None:
        resident = 0.0
        for bname, buf in sched.buffers.items():
            spec = plan.buffer_specs.get(bname)
            factor = 1
            if spec:
                seen: set[str] = set()
                for axis_idx, entry in enumerate(spec):
                    if axis_idx >= len(buf.shape):
                        break
                    f = 1
                    for a in _axes_of(entry):
                        if a in names and a not in seen:
                            seen.add(a)
                            f *= mesh.size(a)
                    # A shard factor beyond the axis extent cannot reduce
                    # residency further (same clamp as the estimator's
                    # buffer_shard_factor).
                    factor *= min(f, buf.shape[axis_idx]) if f > 1 else 1
            resident += buf.bytes / max(factor, 1)
        rep.checks += 1
        rep.stats["hbm_resident_bytes"] = int(resident)
        cap = hbm_capacity_bytes or HBM_CAPACITY_BYTES
        if resident > cap:
            issue("hbm-overflow", sched.name,
                  f"resident {resident / 1e9:.2f} GB/device exceeds "
                  f"capacity {cap / 1e9:.2f} GB",
                  severity=("error" if hbm_capacity_bytes is not None
                            else "warning"))

    for check in (check_topology, check_nodes, check_rules,
                  check_buffer_specs, check_aliases, check_hbm):
        guarded(check)

    rep.stats.setdefault("nodes", len(sched.nodes))
    rep.stats.setdefault("buffers", len(sched.buffers))
    rep.elapsed_s = time.perf_counter() - t0
    return rep


def verify_static(plan: ShardingPlan, mesh: MeshSpec) -> VerifyReport:
    """Schedule-free legality check of a plan against a mesh — the
    plan-cache *load* gate.

    A cache hit must cost microseconds, so this checks every invariant
    that the plan alone can witness: the plan was derived **for** this
    mesh (``mesh-mismatch``), rules and buffer specs name only real mesh
    axes without over-subscribing one (``axis-unknown``,
    ``rule-capacity``), and role aliases mirror an existing source spec
    (``alias-incoherent``).  Schedule-coupled families (topology, node
    assignments, spec coherence/rank, HBM fit) need the live schedule
    and already ran through the full :func:`verify` when the entry was
    *stored* — the cache only persists plans whose store-time report was
    clean.  Same never-crash contract as ``verify()``."""
    t0 = time.perf_counter()
    rep = VerifyReport()
    names = set(mesh.names)

    def issue(code: str, site: str, message: str,
              severity: str = "error") -> None:
        rep.issues.append(VerifyIssue(code, severity, site, message))

    def guarded(check):
        try:
            check()
        except Exception as e:
            issue("verify-internal", check.__name__,
                  f"checker crashed: {type(e).__name__}: {e}")

    def check_mesh() -> None:
        rep.checks += 1
        if tuple(plan.mesh_spec.axes) != tuple(mesh.axes):
            issue("mesh-mismatch", "mesh",
                  f"plan derived for mesh {plan.mesh_spec.axes}, "
                  f"requested {mesh.axes}")

    def check_rules() -> None:
        for dim, axes in plan.rules.items():
            axes = _axes_of(axes)
            rep.checks += 1
            for a in axes:
                if a not in names:
                    issue("axis-unknown", dim,
                          f"rule names unknown mesh axis {a!r}")
            if len(set(axes)) != len(axes):
                issue("rule-capacity", dim,
                      f"rule {axes} assigns a mesh axis more than once")

    def check_specs() -> None:
        for bname, spec in plan.buffer_specs.items():
            rep.checks += 1
            seen: set[str] = set()
            for axis_idx, entry in enumerate(spec):
                for a in _axes_of(entry):
                    if a not in names:
                        issue("axis-unknown", bname,
                              f"spec axis {axis_idx} names unknown mesh "
                              f"axis {a!r}")
                    elif a in seen:
                        # The full verifier tolerates this (it skips the
                        # duplicate when computing shard factors), so the
                        # load gate must not reject what store-time
                        # verify passed.
                        issue("rule-capacity", bname,
                              f"spec uses mesh axis {a!r} on two "
                              "dimensions of one buffer",
                              severity="warning")
                    else:
                        seen.add(a)

    def check_aliases() -> None:
        for role, source in plan.role_sources.items():
            rep.checks += 1
            if source not in plan.buffer_specs:
                issue("alias-incoherent", role,
                      f"alias source {source!r} has no spec")
            elif plan.buffer_specs.get(role) != plan.buffer_specs[source]:
                issue("alias-incoherent", role,
                      f"alias spec {plan.buffer_specs.get(role)} != "
                      f"source {source!r} spec")

    for check in (check_mesh, check_rules, check_specs, check_aliases):
        guarded(check)
    rep.stats["static"] = True
    rep.elapsed_s = time.perf_counter() - t0
    return rep
