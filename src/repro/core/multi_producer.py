"""Multi-producer elimination — paper Algorithm 3 (Section 6.4.1).

Buffers written by multiple producer nodes serialise the whole dataflow.
Two cases:

* **Internal buffers** (allocated inside the schedule): duplicate the
  buffer per extra producer — chained so each producer owns exactly one
  copy — inserting an explicit ``copy`` at the front of a producer that
  also *reads* the previous contents.  Uses dominated by that producer are
  re-pointed at the duplicate.  (Safe because nothing outside the schedule
  can observe an internal buffer.)

* **External buffers** (schedule arguments): duplication is unsound (an
  external writer could update only the original), so all producers are
  fused into a single node and executed sequentially inside it.

On TPU this pass is what legalises multi-writer streams — KV-cache slot
updates, residual-stream accumulators, microbatch gradient accumulators —
into SSA-friendly single-writer buffers that XLA can donate/alias, instead
of forcing a serialised schedule.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .ir import Buffer, MemoryEffect, Node, Op, Schedule, fresh_name


@dataclass
class MultiProducerStats:
    duplicated: int = 0
    copies: int = 0
    merged: int = 0
    log: list[str] = field(default_factory=list)


def _rename_in_node(n: Node, old: str, new: str) -> None:
    if old in n.args:
        n.args[new] = n.args.pop(old)
    for o in n.body:
        o.ins = [new if v == old else v for v in o.ins]
        o.outs = [new if v == old else v for v in o.outs]
        if old in o.access:
            o.access[new] = o.access.pop(old)


def make_copy_op(buf: Buffer, src: str, dst: str) -> Op:
    """An explicit memory copy over the buffer's full index space — the
    copy iterates every axis, so it is shardable like any other node."""
    from .ir import AccessMap
    loop = {d: s for d, s in zip(buf.dims, buf.shape)}
    am = AccessMap.identity(buf.dims)
    return Op(name=fresh_name("copy"), kind="copy", ins=[src], outs=[dst],
              loop_dims=loop, access={src: am, dst: am})


def _insert_copy(n: Node, buf: Buffer, src: str, dst: str) -> None:
    """Prepend an explicit memory copy ``src -> dst`` to node ``n``
    (paper Alg. 3 lines 5-7)."""
    n.body.insert(0, make_copy_op(buf, src, dst))
    n.args[src] = MemoryEffect.READ


def eliminate_multi_producers(sched: Schedule) -> MultiProducerStats:
    stats = MultiProducerStats()
    # Paper: producers sorted by SSA dominance — i.e. program order, not
    # buffer-dataflow order (an RW node dominates a later W node even
    # though the buffer edge points the other way).
    order = {n.name: i for i, n in enumerate(sched.nodes)}

    def dominates(a: Node, b: Node) -> bool:
        return order[a.name] <= order[b.name]

    # -- case (1): internal buffers → duplication ---------------------------
    for bname in list(sched.internal_buffers()):
        producers = sorted(sched.producers_of(bname),
                           key=lambda n: order[n.name])
        if len(producers) <= 1:
            continue
        cur = bname
        for p in producers[1:]:
            base = sched.buffers[bname]
            dup_name = fresh_name(f"{bname}_dup")
            sched.buffers[dup_name] = Buffer(
                name=dup_name, shape=base.shape, dtype=base.dtype,
                dims=base.dims, stages=base.stages, partition=base.partition,
                tiling=base.tiling, placement=base.placement)
            stats.duplicated += 1
            reads_prev = p.args.get(cur) in (MemoryEffect.READ,
                                             MemoryEffect.READ_WRITE)
            # Re-point every use dominated by p (including p itself).
            for u in sched.nodes:
                if cur in u.args and dominates(p, u):
                    _rename_in_node(u, cur, dup_name)
            if reads_prev:
                _insert_copy(p, sched.buffers[dup_name], cur, dup_name)
                stats.copies += 1
            stats.log.append(f"dup {cur}->{dup_name} for producer {p.name}")
            cur = dup_name

    # -- case (2): external buffers → producer fusion -----------------------
    for bname in list(sched.external_buffers()):
        producers = sorted(sched.producers_of(bname),
                           key=lambda n: order[n.name])
        if len(producers) <= 1:
            continue
        merged = Node(name=fresh_name("merged_node"))
        for p in producers:
            merged.body.extend(p.body)
            for v, e in p.args.items():
                prev = merged.args.get(v)
                if prev is None:
                    merged.args[v] = e
                elif prev != e:
                    merged.args[v] = MemoryEffect.READ_WRITE
        first_idx = min(sched.nodes.index(p) for p in producers)
        for p in producers:
            sched.nodes.remove(p)
        sched.nodes.insert(first_idx, merged)
        stats.merged += len(producers)
        stats.log.append(
            f"merged producers {[p.name for p in producers]} of {bname} "
            f"-> {merged.name}")
    return stats
