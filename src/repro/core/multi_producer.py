"""Multi-producer elimination — paper Algorithm 3 (Section 6.4.1).

Buffers written by multiple producer nodes serialise the whole dataflow.
Two cases:

* **Internal buffers** (allocated inside the schedule): duplicate the
  buffer per extra producer — chained so each producer owns exactly one
  copy — inserting an explicit ``copy`` at the front of a producer that
  also *reads* the previous contents.  Uses dominated by that producer are
  re-pointed at the duplicate.  (Safe because nothing outside the schedule
  can observe an internal buffer.)

* **External buffers** (schedule arguments): duplication is unsound (an
  external writer could update only the original), so all producers are
  fused into a single node and executed sequentially inside it.

On TPU this pass is what legalises multi-writer streams — KV-cache slot
updates, residual-stream accumulators, microbatch gradient accumulators —
into SSA-friendly single-writer buffers that XLA can donate/alias, instead
of forcing a serialised schedule.

All mutation flows through
:class:`~repro.core.rewrite.ScheduleRewriteSession`: producer lists and
dominated-use sets come from the session's Δ-maintained indices (no
per-buffer node scans), buffer duplication / use re-pointing / copy
insertion / producer fusion are session primitives, and the whole pass is
one transaction — an exception rolls the schedule back to its pre-pass
state.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .faults import fault_point
from .ir import Buffer, MemoryEffect, Node, Schedule, fresh_name
from .rewrite import ScheduleRewriteSession, make_copy_op

__all__ = ["MultiProducerStats", "eliminate_multi_producers", "make_copy_op"]


@dataclass
class MultiProducerStats:
    duplicated: int = 0
    copies: int = 0
    merged: int = 0
    log: list[str] = field(default_factory=list)


def eliminate_multi_producers(sched: Schedule,
                              selfcheck: bool = False) -> MultiProducerStats:
    stats = MultiProducerStats()
    with ScheduleRewriteSession(sched, selfcheck=selfcheck) as rs:
        _eliminate(sched, rs, stats)
    return stats


def _eliminate(sched: Schedule, rs: ScheduleRewriteSession,
               stats: MultiProducerStats) -> None:
    # Paper: producers sorted by SSA dominance — i.e. program order, not
    # buffer-dataflow order (an RW node dominates a later W node even
    # though the buffer edge points the other way).

    # -- case (1): internal buffers → duplication ---------------------------
    for bname in list(sched.internal_buffers()):
        producers = sorted(rs.producers(bname), key=rs.position)
        if len(producers) <= 1:
            continue
        cur = bname
        for p in producers[1:]:
            fault_point("mp.duplicate")
            base = sched.buffers[bname]
            dup_name = fresh_name(f"{bname}_dup")
            rs.add_buffer(Buffer(
                name=dup_name, shape=base.shape, dtype=base.dtype,
                dims=base.dims, stages=base.stages, partition=base.partition,
                tiling=base.tiling, placement=base.placement))
            stats.duplicated += 1
            reads_prev = p.args.get(cur) in (MemoryEffect.READ,
                                             MemoryEffect.READ_WRITE)
            # Re-point every use dominated by p (including p itself).
            rs.replace_uses(cur, dup_name,
                            [u for u in rs.users_in_program_order(cur)
                             if rs.position(p) <= rs.position(u)])
            if reads_prev:
                rs.insert_copy(p, sched.buffers[dup_name], cur, dup_name)
                stats.copies += 1
            stats.log.append(f"dup {cur}->{dup_name} for producer {p.name}")
            cur = dup_name

    # -- case (2): external buffers → producer fusion -----------------------
    for bname in list(sched.external_buffers()):
        producers = sorted(rs.producers(bname), key=rs.position)
        if len(producers) <= 1:
            continue
        fault_point("mp.merge")
        # Body concatenation and effect merging are pass policy; the
        # session owns the structural swap (retire olds + insert merged).
        merged = Node(name=fresh_name("merged_node"))
        for p in producers:
            merged.body.extend(p.body)
            for v, e in p.args.items():
                prev = merged.args.get(v)
                if prev is None:
                    merged.args[v] = e
                elif prev != e:
                    merged.args[v] = MemoryEffect.READ_WRITE
        first_idx = min(rs.position(p) for p in producers)
        rs.replace_nodes(producers, merged, first_idx)
        stats.merged += len(producers)
        stats.log.append(
            f"merged producers {[p.name for p in producers]} of {bname} "
            f"-> {merged.name}")
