"""Transactional rewrite layer: every structural mutation of the
Functional graph and the Structural schedule flows through a session.

HIDA's optimizer is hierarchical precisely because every pass — task
fusion (Alg. 2), multi-producer elimination (Alg. 3), data-path balancing
(Section 6.4.2), Functional→Structural lowering (Section 6.3) — reasons
over the *same* dataflow structure.  Before this layer, each pass kept
its own ad-hoc producer/consumer scans and mutated ``Graph`` /
``Schedule`` raw, leaving ``Schedule.topology()`` to detect the damage by
signature mismatch and re-index from scratch.  Now:

* :class:`GraphRewriteSession` wraps a :class:`~repro.core.ir.Graph` and
  owns the fusion-facing view of :class:`~repro.core.ir.GraphTopology`:
  per-dispatch successor graphs, task rollups (produces / consumes /
  intensity / leaf kinds), cycle queries — maintained in **O(Δ)** per
  :meth:`~GraphRewriteSession.fuse` / :meth:`~GraphRewriteSession.split`
  (one region scan, not a quadratic rebuild per worklist step).

* :class:`ScheduleRewriteSession` wraps a
  :class:`~repro.core.ir.Schedule` and maintains the producer/consumer
  indices of :class:`~repro.core.ir.ScheduleTopology` across its
  primitives (``add_node`` / ``retire_node`` / ``replace_nodes`` /
  ``rename_arg`` / ``rename_buffer`` / ``insert_copy`` / ``set_arg`` /
  ``drop_arg`` / buffer and token edits).  Derived per-buffer structures
  (axis dims, the edge list, the dim→buffer inverted index) are
  invalidated per *touched buffer* and regenerated only for those buffers
  at :meth:`~ScheduleRewriteSession.commit` — untouched buffers reuse the
  pre-session topology's entries verbatim.

Both sessions are **transactions**, mirroring
:class:`~repro.core.incremental.IncrementalEstimator`'s
propose/commit/rollback:

* ``commit()`` installs the maintained topology into the owner's cache
  (``graph._topology`` / ``sched._topology``) with a fresh structure
  signature, so the next ``topology()`` call is a cache *hit* — no pass
  boundary pays a re-index.
* ``rollback()`` undoes every IR mutation (each primitive logs an exact
  inverse) and reinstates the untouched pre-session topology object.
* Used as a context manager, exit commits on success and rolls back on
  exception — a pass can never leave the IR half-rewritten.

``tests/test_rewrite.py`` property-checks the whole contract: after any
prefix of a pass's rewrite trace, the maintained topology fingerprint
equals a from-scratch ``build()``, and rollback restores the pre-session
schedule and topology bit-exactly.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional, Sequence

from .ir import (AccessMap, Buffer, Graph, GraphTopology, MemoryEffect, Node,
                 Op, Schedule, ScheduleTopology, TokenEdge, depth_map_over,
                 fresh_name, make_task, topo_order_over)


class RewriteError(RuntimeError):
    """Misuse of a rewrite session (closed session, duplicate buffer,
    canonicalized graph rolled back, …)."""


def _remove_identical(lst: list, obj) -> bool:
    """Remove ``obj`` from ``lst`` by identity (dataclass ``==`` is deep
    and could match a distinct object)."""
    for i, x in enumerate(lst):
        if x is obj:
            del lst[i]
            return True
    return False


def _index_identical(lst: list, obj) -> int:
    """``lst.index(obj)`` by identity (see :func:`_remove_identical`)."""
    for i, x in enumerate(lst):
        if x is obj:
            return i
    raise ValueError(f"{getattr(obj, 'name', obj)!r} not in list")


def make_copy_op(buf: Buffer, src: str, dst: str) -> Op:
    """An explicit memory copy over the buffer's full index space — the
    copy iterates every axis, so it is shardable like any other node."""
    loop = {d: s for d, s in zip(buf.dims, buf.shape)}
    am = AccessMap.identity(buf.dims)
    return Op(name=fresh_name("copy"), kind="copy", ins=[src], outs=[dst],
              loop_dims=loop, access={src: am, dst: am})


# --------------------------------------------------------------------------
# Topology fingerprints (property tests + selfcheck mode)
# --------------------------------------------------------------------------

def schedule_topology_fingerprint(topo: ScheduleTopology) -> dict:
    """Name-based semantic content of a :class:`ScheduleTopology` — two
    topologies describe the same structure iff their fingerprints are
    equal (the lazy ``_access`` cache is deliberately excluded)."""
    return {
        "producers": {b: [n.name for n in v]
                      for b, v in topo.producers.items() if v},
        "consumers": {b: [n.name for n in v]
                      for b, v in topo.consumers.items() if v},
        "edges": list(topo.edges),
        "axis_owner_dims": {
            b: tuple(tuple((n.name, d) for n, d in pairs) for pairs in per)
            for b, per in topo.axis_owner_dims.items()},
        "axis_dims": dict(topo.axis_dims),
        "buffers_of_dim": dict(topo.buffers_of_dim),
        "signature": topo.signature,
    }


def graph_topology_fingerprint(topo: GraphTopology, graph: Graph) -> dict:
    """Name-based semantic content of a :class:`GraphTopology` restricted
    to ops currently reachable from ``graph`` (rollup memos are lazy
    caches and excluded; parent entries for retired ops are ignored)."""
    live = {id(o): o.name for o in graph.walk()}
    return {
        "producers": {v: [o.name for o in ops]
                      for v, ops in topo.producers.items() if ops},
        "consumers": {v: [o.name for o in ops]
                      for v, ops in topo.consumers.items() if ops},
        "parent": {name: (topo.parent.get(i).name
                          if topo.parent.get(i) is not None else None)
                   for i, name in live.items()},
        "signature": topo.signature,
    }


# --------------------------------------------------------------------------
# Functional-level session
# --------------------------------------------------------------------------

class GraphRewriteSession:
    """Transactional rewrites over a Functional :class:`Graph`.

    The fusion pass (Alg. 2) drives its whole worklist through this:
    adjacency / cycle queries against a per-dispatch successor graph that
    is built once per dispatch and then **maintained** across
    :meth:`fuse` calls (one O(region) rescan of the merged task's row and
    column — never the O(region²) full rebuild the old ``_RegionIndex``
    paid per worklist step), and rollups served from the shared
    :class:`GraphTopology` memos."""

    def __init__(self, graph: Graph, selfcheck: bool = False):
        self.graph = graph
        self._base = graph.topology()
        self._parent = dict(self._base.parent)
        #: id(dispatch) -> {id(task) -> set of successor task ids}
        self._succ: dict[int, dict[int, set[int]]] = {}
        self._pins: list[Op] = []
        self._undo: list[Callable[[], None]] = []
        self._canonicalized = False
        self._open = True
        self._selfcheck = selfcheck

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "GraphRewriteSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._open:
            return
        if exc_type is None:
            self.commit()
        else:
            self.rollback()

    def _check_open(self) -> None:
        if not self._open:
            raise RewriteError("graph rewrite session is closed")

    def commit(self) -> Optional[GraphTopology]:
        """Install the maintained topology on the graph and close the
        session.  After :meth:`canonicalize` the region tree was
        restructured wholesale, so the cache is invalidated instead (the
        next ``graph.topology()`` rebuilds lazily)."""
        self._check_open()
        self._open = False
        g = self.graph
        if self._canonicalized:
            g._topology = None
            return None
        sig = g.structure_signature()
        base = self._base
        if sig == base.signature:
            g._topology = base
            return base
        topo = GraphTopology(
            # Fusion only regroups tasks; the leaf ops — and hence the
            # value→op indices — are untouched and shared with the base.
            producers=base.producers, consumers=base.consumers,
            parent=self._parent, signature=sig,
            _produces=base._produces, _consumes=base._consumes,
            _intensity=base._intensity, _leaf_meta=base._leaf_meta,
            _pins=base._pins)
        g._topology = topo
        return topo

    def rollback(self) -> None:
        """Undo every rewrite (exact inverses, reverse order) and
        reinstate the untouched pre-session topology.  The lazy rollup
        memos are dropped wholesale: any entry recomputed *mid-session*
        (a selfcheck, or an ancestor query after `_invalidate_ancestors`)
        was computed against the mutated tree and must not survive into
        the restored one — they rebuild lazily against the rolled-back
        structure on next query."""
        self._check_open()
        self._open = False
        for undo in reversed(self._undo):
            undo()
        if self._undo:
            base = self._base
            base._produces.clear()
            base._consumes.clear()
            base._intensity.clear()
            base._leaf_meta.clear()
        self.graph._topology = self._base

    # -- queries ------------------------------------------------------------
    def produces(self, t: Op) -> frozenset:
        return self._base.produces(t)

    def consumes(self, t: Op) -> frozenset:
        return self._base.consumes(t)

    def intensity(self, t: Op) -> float:
        return self._base.intensity(t)

    def leaf_meta(self, t: Op) -> tuple[Optional[str], frozenset]:
        return self._base.leaf_meta(t)

    def _ensure_region(self, d: Op) -> dict[int, set[int]]:
        succ = self._succ.get(id(d))
        if succ is None:
            topo = self._base
            region = list(d.region)
            prods = [topo.produces(t) for t in region]
            cons = [topo.consumes(t) for t in region]
            succ = {}
            for i, a in enumerate(region):
                succ[id(a)] = {id(b) for j, b in enumerate(region)
                               if i != j and prods[i] & cons[j]}
            self._succ[id(d)] = succ
            self._pins.extend(region)
            self._pins.append(d)
        return succ

    def adjacent(self, d: Op, a: Op, b: Op) -> bool:
        """True when a feeds b or b feeds a through any value."""
        succ = self._ensure_region(d)
        return id(b) in succ[id(a)] or id(a) in succ[id(b)]

    def creates_cycle(self, d: Op, a: Op, b: Op) -> bool:
        """Fusing a and b is illegal when a third task sits on a dataflow
        path between them (the merged task would both feed and consume
        it).  This matters for decode graphs: qkv → cache-update →
        attention must not fuse qkv with attention around the
        cache-update node."""
        succ = self._ensure_region(d)
        for src, dst in ((id(a), id(b)), (id(b), id(a))):
            seen: set[int] = set()
            stack = [n for n in succ[src] if n != dst]
            while stack:
                n = stack.pop()
                if n in seen:
                    continue
                seen.add(n)
                if dst in succ[n]:
                    return True
                stack.extend(m for m in succ[n] if m != dst)
        return False

    def _invalidate_ancestors(self, d: Op) -> None:
        """Drop the rollup memos of ``d`` and every enclosing region op:
        restructuring inside ``d`` leaves ancestor produces/consumes sets
        intact *as sets* but reassociates their float intensity sums and
        leaf walks — a stale memo here would leak into a later query
        (the selfcheck catches exactly this drift)."""
        topo = self._base
        cur: Optional[Op] = d
        seen: set[int] = set()
        while cur is not None and id(cur) not in seen:
            seen.add(id(cur))
            for memo in (topo._produces, topo._consumes, topo._intensity,
                         topo._leaf_meta):
                memo.pop(id(cur), None)
            cur = self._parent.get(id(cur))

    # -- rewrites -----------------------------------------------------------
    def fuse(self, d: Op, a: Op, b: Op) -> Op:
        """Fuse two tasks of one dispatch region into a new task,
        preserving program order (transparent regions make this a pure
        re-wrap).  The merged task's rollups come from O(1) set algebra
        over the memoized operands; its successor row/column are rescanned
        in one O(region) pass, everything else is untouched."""
        self._check_open()
        succ = self._ensure_region(d)
        region = d.region
        ia, ib = _index_identical(region, a), _index_identical(region, b)
        first, second = (a, b) if ia <= ib else (b, a)
        i = min(ia, ib)
        merged = make_task(list(first.region) + list(second.region))
        old_region = list(region)
        region[i] = merged
        _remove_identical(region, second)

        topo = self._base
        topo.note_fusion(merged, first, second)
        mid = id(merged)
        mprod, mcons = topo.produces(merged), topo.consumes(merged)
        out: set[int] = set()
        for t in region:
            if t is merged:
                continue
            row = succ[id(t)]
            row.discard(id(first))
            row.discard(id(second))
            if topo.produces(t) & mcons:
                row.add(mid)
            if mprod & topo.consumes(t):
                out.add(id(t))
        succ.pop(id(first), None)
        succ.pop(id(second), None)
        succ[mid] = out

        self._parent[mid] = d
        for c in merged.region:
            self._parent[id(c)] = merged
        self._pins.append(merged)
        self._invalidate_ancestors(d)

        def undo() -> None:
            region[:] = old_region
        self._undo.append(undo)
        self._after()
        return merged

    def split(self, d: Op, task: Op, at: int) -> tuple[Op, Op]:
        """Split ``task`` (a region op of dispatch ``d``) into two tasks
        at child index ``at`` — the inverse of :meth:`fuse`.  Successor
        rows for the two halves are rescanned in one O(region) pass."""
        self._check_open()
        if not 0 < at < len(task.region):
            raise RewriteError(f"split index {at} out of range for "
                               f"{task.name} ({len(task.region)} children)")
        succ = self._ensure_region(d)
        region = d.region
        i = _index_identical(region, task)
        head = make_task(list(task.region[:at]))
        tail = make_task(list(task.region[at:]))
        old_region = list(region)
        region[i:i + 1] = [head, tail]

        topo = self._base
        succ.pop(id(task), None)
        for part in (head, tail):
            self._parent[id(part)] = d
            for c in part.region:
                self._parent[id(c)] = part
            self._pins.append(part)
        for part in (head, tail):
            pprod, pcons = topo.produces(part), topo.consumes(part)
            row: set[int] = set()
            for t in region:
                if t is part:
                    continue
                if pprod & topo.consumes(t):
                    row.add(id(t))
            succ[id(part)] = row
        for t in region:
            if t is head or t is tail:
                continue
            row = succ[id(t)]
            row.discard(id(task))
            tprod = topo.produces(t)
            for part in (head, tail):
                if tprod & topo.consumes(part):
                    row.add(id(part))
        self._invalidate_ancestors(d)

        def undo() -> None:
            region[:] = old_region
        self._undo.append(undo)
        self._after()
        return head, tail

    def canonicalize(self, fn: Callable[[Op], Op]) -> None:
        """Wholesale region-tree restructure (e.g.
        :func:`~repro.core.fusion.simplify_hierarchy`): apply ``fn`` to
        every top-level op.  This invalidates the maintained topology at
        commit (the one full rebuild happens lazily on the next
        ``graph.topology()`` call, *after* the worklist is done — never
        between worklist steps)."""
        self._check_open()
        g = self.graph
        # fn may rewrite or REBIND op.region at any depth: snapshot both
        # the list object and its content for an exact inverse.  Identity
        # matters — earlier fuse/split undos captured these very list
        # objects, so the inverse must restore content *into them* and
        # re-point op.region at them, or a later rollback would mutate an
        # orphaned list while the op shows the canonicalized one.
        snapshot = [(op, op.region, list(op.region)) for op in g.walk()]
        ops_obj = g.ops
        old_ops = list(g.ops)

        def undo() -> None:
            for op, region_obj, children in snapshot:
                region_obj[:] = children
                op.region = region_obj
            ops_obj[:] = old_ops
            g.ops = ops_obj
        # Logged before fn runs: simplify-style callbacks mutate the tree
        # while traversing, so an exception mid-apply must still restore.
        self._undo.append(undo)
        self._canonicalized = True
        g.ops = [fn(o) for o in g.ops]

    # -- selfcheck ----------------------------------------------------------
    def _after(self) -> None:
        if self._selfcheck:
            self.selfcheck()

    def selfcheck(self) -> None:
        """Assert every maintained structure equals a from-scratch
        rebuild (property-test / debugging hook; O(graph) per call)."""
        g = self.graph
        fresh = GraphTopology.build(g)
        live = {id(o) for o in g.walk()}
        # Rollups for every live op the memo knows about.
        for op in list(g.walk()):
            assert self._base.produces(op) == frozenset(op.all_outs()), \
                f"produces drift on {op.name}"
            assert self._base.consumes(op) == frozenset(op.all_ins()), \
                f"consumes drift on {op.name}"
            assert self._base.intensity(op) == op.intensity(), \
                f"intensity drift on {op.name}"
        # Parent map over live ops.
        maintained_parent = {
            o.name: (self._parent.get(id(o)).name
                     if self._parent.get(id(o)) is not None else None)
            for o in g.walk()}
        fresh_parent = {
            o.name: (fresh.parent[id(o)].name
                     if fresh.parent[id(o)] is not None else None)
            for o in g.walk()}
        assert maintained_parent == fresh_parent, "parent map drift"
        # Successor graphs for every ensured dispatch still in the graph.
        by_id = {id(o): o for o in g.walk()}
        for did, succ in self._succ.items():
            d = by_id.get(did)
            if d is None or d.kind != "dispatch":
                continue
            fresh_succ = {}
            for i, a in enumerate(d.region):
                fresh_succ[id(a)] = {
                    id(b) for j, b in enumerate(d.region)
                    if i != j and frozenset(a.all_outs()) & frozenset(
                        b.all_ins())}
            live_rows = {k: v & live for k, v in succ.items() if k in live}
            assert live_rows == fresh_succ, f"succ drift in {d.name}"


# --------------------------------------------------------------------------
# Structural-level session
# --------------------------------------------------------------------------

class ScheduleRewriteSession:
    """Transactional rewrites over a Structural :class:`Schedule`.

    Maintains the producer/consumer indices of
    :class:`ScheduleTopology` in O(Δ) per primitive and re-derives the
    per-buffer axis structures only for buffers a rewrite actually
    touched; :meth:`commit` installs the result as the schedule's cached
    topology (so the downstream DSE starts on a warm cache), and
    :meth:`rollback` restores the schedule and its pre-session topology
    exactly."""

    def __init__(self, sched: Schedule, selfcheck: bool = False):
        self.sched = sched
        self._base = sched.topology()
        self._producers = {b: list(v) for b, v in self._base.producers.items()}
        self._consumers = {b: list(v) for b, v in self._base.consumers.items()}
        self._pos = {n.name: i for i, n in enumerate(sched.nodes)}
        self._dirty: set[str] = set()
        self._edges: Optional[list[tuple[str, str, str]]] = list(
            self._base.edges)
        self._undo: list[Callable[[], None]] = []
        self._open = True
        self._selfcheck = selfcheck

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "ScheduleRewriteSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._open:
            return
        if exc_type is None:
            self.commit()
        else:
            self.rollback()

    def _check_open(self) -> None:
        if not self._open:
            raise RewriteError("schedule rewrite session is closed")

    def commit(self) -> ScheduleTopology:
        """Assemble the maintained topology, install it as the
        schedule's cache, and close the session."""
        self._check_open()
        topo = self._assemble()
        self._open = False
        self.sched._topology = topo
        return topo

    def rollback(self) -> None:
        """Undo every rewrite (exact inverses, reverse order) and
        reinstate the untouched pre-session topology.  The base's lazy
        per-(node, buffer) access cache is dropped: an entry computed
        mid-session (e.g. an external ``access_for`` query) reflects a
        mutated node body and must not survive into the restored one."""
        self._check_open()
        self._open = False
        for undo in reversed(self._undo):
            undo()
        if self._undo:
            self._base._access.clear()
        self.sched._topology = self._base

    def _assemble(self) -> ScheduleTopology:
        sched = self.sched
        sig = sched.structure_signature()
        base = self._base
        if sig == base.signature and not self._dirty:
            return base
        producers = {b: list(v) for b, v in self._producers.items() if v}
        consumers = {b: list(v) for b, v in self._consumers.items() if v}
        edges = self._edge_list()
        access: dict[tuple[str, str], Optional[AccessMap]] = {}
        axis_owner_dims: dict[str, tuple] = {}
        axis_dims: dict[str, tuple] = {}
        for bname, buf in sched.buffers.items():
            if bname not in self._dirty and bname in base.axis_owner_dims:
                # Untouched buffer: owners and their access maps are
                # unchanged — reuse the pre-session derivation.
                axis_owner_dims[bname] = base.axis_owner_dims[bname]
                axis_dims[bname] = base.axis_dims[bname]
                continue
            owners = producers.get(bname, []) + consumers.get(bname, [])
            per_axis: list[tuple] = []
            dims: list[Optional[str]] = []
            for axis in range(len(buf.shape)):
                pairs = []
                for node in owners:
                    key = (node.name, bname)
                    if key not in access:
                        access[key] = node.access_for(bname)
                    am = access[key]
                    if am is None or axis >= len(am.entries):
                        continue
                    d = am.entries[axis][0]
                    if d is not None:
                        pairs.append((node, d))
                per_axis.append(tuple(pairs))
                dims.append(pairs[0][1] if pairs else None)
            axis_owner_dims[bname] = tuple(per_axis)
            axis_dims[bname] = tuple(dims)
        buffers_of_dim: dict[str, list[str]] = {}
        for bname in sched.buffers:
            for d in axis_dims[bname]:
                if d is not None and (d not in buffers_of_dim
                                      or buffers_of_dim[d][-1] != bname):
                    buffers_of_dim.setdefault(d, []).append(bname)
        return ScheduleTopology(
            producers=producers, consumers=consumers, edges=edges,
            axis_owner_dims=axis_owner_dims, axis_dims=axis_dims,
            buffers_of_dim={d: tuple(v) for d, v in buffers_of_dim.items()},
            _access=access, signature=sig)

    # -- queries ------------------------------------------------------------
    def producers(self, value: str) -> list[Node]:
        """Nodes writing ``value``, in node order."""
        return list(self._producers.get(value, ()))

    def consumers(self, value: str) -> list[Node]:
        """Nodes reading ``value``, in node order."""
        return list(self._consumers.get(value, ()))

    def owners(self, value: str) -> list[Node]:
        """Producers then consumers — the plan-projection scan order."""
        return self.producers(value) + self.consumers(value)

    def users_in_program_order(self, value: str) -> list[Node]:
        """Every node with ``value`` in its args, ascending node order,
        deduplicated (an RW node indexes as both producer and consumer)."""
        seen: set[str] = set()
        out: list[Node] = []
        nodes = (self._producers.get(value, [])
                 + self._consumers.get(value, []))
        for n in sorted(nodes, key=lambda n: self._pos[n.name]):
            if n.name not in seen:
                seen.add(n.name)
                out.append(n)
        return out

    def position(self, node: Node) -> int:
        return self._pos[node.name]

    def _edge_list(self) -> list[tuple[str, str, str]]:
        if self._edges is None:
            edges = []
            for buf in self.sched.buffers:
                for p in self._producers.get(buf, ()):
                    for c in self._consumers.get(buf, ()):
                        if p.name != c.name:
                            edges.append((p.name, c.name, buf))
            self._edges = edges
        return self._edges

    def edges(self) -> list[tuple[str, str, str]]:
        """(src, dst, buffer) edges over the current structure, in the
        canonical ``ScheduleTopology.build`` order (regenerated from the
        Δ-maintained indices only when a rewrite invalidated them)."""
        return list(self._edge_list())

    def topo_order(self) -> list[Node]:
        return topo_order_over(self.sched.nodes, self._edge_list(),
                               self.sched.name)

    def depth_of(self) -> dict[str, int]:
        return depth_map_over(self.sched.nodes, self._edge_list(),
                              self.sched.name)

    # -- index maintenance ---------------------------------------------------
    def _touch(self, *values: str) -> None:
        self._dirty.update(values)
        self._edges = None

    def _reindex_positions(self) -> None:
        self._pos = {n.name: i for i, n in enumerate(self.sched.nodes)}

    def _index_insert(self, index: dict[str, list[Node]], value: str,
                      node: Node) -> None:
        lst = index.setdefault(value, [])
        if any(x is node for x in lst):
            return
        pos = self._pos[node.name]
        at = len(lst)
        for j, other in enumerate(lst):
            if self._pos[other.name] > pos:
                at = j
                break
        lst.insert(at, node)

    def _index_discard(self, index: dict[str, list[Node]], value: str,
                       node: Node) -> None:
        lst = index.get(value)
        if lst is not None:
            _remove_identical(lst, node)

    def _sync_arg_index(self, node: Node, value: str) -> None:
        """Make the two indices agree with ``node.args.get(value)``."""
        effect = node.args.get(value)
        if effect in (MemoryEffect.WRITE, MemoryEffect.READ_WRITE):
            self._index_insert(self._producers, value, node)
        else:
            self._index_discard(self._producers, value, node)
        if effect in (MemoryEffect.READ, MemoryEffect.READ_WRITE):
            self._index_insert(self._consumers, value, node)
        else:
            self._index_discard(self._consumers, value, node)

    def _after(self) -> None:
        if self._selfcheck:
            self.selfcheck()

    def selfcheck(self) -> None:
        """Assert the maintained topology equals a from-scratch build
        (property-test / debugging hook; O(schedule) per call)."""
        fresh = ScheduleTopology.build(self.sched)
        assert (schedule_topology_fingerprint(self._assemble())
                == schedule_topology_fingerprint(fresh)), \
            f"topology drift on schedule {self.sched.name}"

    # -- node primitives -----------------------------------------------------
    def add_node(self, node: Node, index: int | None = None) -> Node:
        """Insert ``node`` (at ``index``, default append) and index its
        argument effects."""
        self._check_open()
        sched = self.sched
        if any(n.name == node.name for n in sched.nodes):
            raise RewriteError(f"duplicate node {node.name}")
        old_nodes = list(sched.nodes)
        sched.nodes.insert(len(sched.nodes) if index is None else index,
                           node)
        self._reindex_positions()
        for b in node.writes():
            self._index_insert(self._producers, b, node)
        for b in node.reads():
            self._index_insert(self._consumers, b, node)
        self._touch(*node.args)

        def undo() -> None:
            sched.nodes[:] = old_nodes
        self._undo.append(undo)
        self._after()
        return node

    def retire_node(self, node: Node) -> None:
        """Remove ``node`` from the schedule and the indices."""
        self._check_open()
        sched = self.sched
        old_nodes = list(sched.nodes)
        if not _remove_identical(sched.nodes, node):
            raise RewriteError(f"unknown node {node.name}")
        self._reindex_positions()
        for b in node.writes():
            self._index_discard(self._producers, b, node)
        for b in node.reads():
            self._index_discard(self._consumers, b, node)
        self._touch(*node.args)

        def undo() -> None:
            sched.nodes[:] = old_nodes
        self._undo.append(undo)
        self._after()

    def replace_nodes(self, olds: Sequence[Node], new: Node,
                      index: int) -> Node:
        """Atomically retire ``olds`` and insert ``new`` at ``index`` —
        the multi-producer *fusion* arm (Alg. 3 case 2).  The caller
        builds ``new`` (body concatenation, effect merging are pass
        policy); the session owns the structural swap and re-indexing."""
        self._check_open()
        sched = self.sched
        old_nodes = list(sched.nodes)
        for o in olds:
            if not _remove_identical(sched.nodes, o):
                raise RewriteError(f"unknown node {o.name}")
        sched.nodes.insert(index, new)
        self._reindex_positions()
        touched: set[str] = set(new.args)
        for o in olds:
            touched.update(o.args)
            for b in o.writes():
                self._index_discard(self._producers, b, o)
            for b in o.reads():
                self._index_discard(self._consumers, b, o)
        for b in new.writes():
            self._index_insert(self._producers, b, new)
        for b in new.reads():
            self._index_insert(self._consumers, b, new)
        self._touch(*touched)

        def undo() -> None:
            sched.nodes[:] = old_nodes
        self._undo.append(undo)
        self._after()
        return new

    # -- argument / body primitives ------------------------------------------
    def set_arg(self, node: Node, value: str, effect: str) -> None:
        """Set ``node.args[value] = effect`` (dict position preserved for
        an existing key, appended for a new one) and re-index."""
        self._check_open()
        old_args = dict(node.args)
        node.args[value] = effect
        self._sync_arg_index(node, value)
        self._touch(value)

        def undo() -> None:
            node.args.clear()
            node.args.update(old_args)
        self._undo.append(undo)
        self._after()

    def drop_arg(self, node: Node, value: str) -> None:
        """Remove ``value`` from ``node.args`` and the indices (used by
        lowering to drop node-internal temporaries)."""
        self._check_open()
        old_args = dict(node.args)
        node.args.pop(value, None)
        self._index_discard(self._producers, value, node)
        self._index_discard(self._consumers, value, node)
        self._touch(value)

        def undo() -> None:
            node.args.clear()
            node.args.update(old_args)
        self._undo.append(undo)
        self._after()

    def rename_arg(self, node: Node, old: str, new: str) -> None:
        """Re-point every use of ``old`` inside ``node`` (args entry, body
        op operands, access-map keys) at ``new`` — the
        ``replace_uses``-per-node primitive of multi-producer elimination
        and balancing."""
        self._check_open()
        old_args = dict(node.args)
        body_snapshot = [(o, list(o.ins), list(o.outs), dict(o.access))
                         for o in node.body]
        if old in node.args:
            node.args[new] = node.args.pop(old)
        for o in node.body:
            o.ins = [new if v == old else v for v in o.ins]
            o.outs = [new if v == old else v for v in o.outs]
            if old in o.access:
                o.access[new] = o.access.pop(old)
        self._index_discard(self._producers, old, node)
        self._index_discard(self._consumers, old, node)
        self._sync_arg_index(node, new)
        self._touch(old, new)

        def undo() -> None:
            node.args.clear()
            node.args.update(old_args)
            for o, ins, outs, access in body_snapshot:
                o.ins = ins
                o.outs = outs
                o.access = access
        self._undo.append(undo)
        self._after()

    def replace_uses(self, old: str, new: str,
                     nodes: Iterable[Node]) -> None:
        """:meth:`rename_arg` over a node subset (e.g. the dominated uses
        of a duplicated buffer)."""
        for n in nodes:
            self.rename_arg(n, old, new)

    def insert_copy(self, node: Node, buf: Buffer, src: str,
                    dst: str) -> Op:
        """Prepend an explicit memory copy ``src -> dst`` to ``node``
        (paper Alg. 3 lines 5-7) and record the new READ effect."""
        self._check_open()
        old_args = dict(node.args)
        old_body = list(node.body)
        op = make_copy_op(buf, src, dst)
        node.body.insert(0, op)
        node.args[src] = MemoryEffect.READ
        self._sync_arg_index(node, src)
        self._touch(src, dst)

        def undo() -> None:
            node.args.clear()
            node.args.update(old_args)
            node.body[:] = old_body
        self._undo.append(undo)
        self._after()
        return op

    # -- buffer / stream primitives -------------------------------------------
    def add_buffer(self, buf: Buffer, external: bool = False) -> Buffer:
        """Register a new buffer (optionally as a schedule argument)."""
        self._check_open()
        sched = self.sched
        if buf.name in sched.buffers:
            raise RewriteError(f"duplicate buffer {buf.name}")
        sched.buffers[buf.name] = buf
        if external:
            sched.args.append(buf.name)
        self._touch(buf.name)

        def undo() -> None:
            del sched.buffers[buf.name]
            if external:
                sched.args.remove(buf.name)
        self._undo.append(undo)
        self._after()
        return buf

    def rename_buffer(self, old: str, new: str) -> None:
        """Rename a buffer everywhere: the buffers dict key, the args
        list, and every owning node (args + body operands)."""
        self._check_open()
        sched = self.sched
        if old not in sched.buffers:
            raise RewriteError(f"unknown buffer {old}")
        if new in sched.buffers:
            raise RewriteError(f"duplicate buffer {new}")
        for n in self.users_in_program_order(old):
            self.rename_arg(n, old, new)
        buf = sched.buffers[old]
        old_buffers = dict(sched.buffers)
        old_args = list(sched.args)
        old_outputs = list(sched.outputs)
        old_value_bytes = dict(sched.value_bytes)
        old_name = buf.name
        sched.buffers = {(new if k == old else k): v
                         for k, v in sched.buffers.items()}
        buf.name = new
        sched.args = [new if a == old else a for a in sched.args]
        sched.outputs = [new if o == old else o for o in sched.outputs]
        # The estimator costs reduction collectives off value_bytes; a
        # stale key would silently zero this buffer's traffic.
        sched.value_bytes = {(new if k == old else k): v
                             for k, v in sched.value_bytes.items()}
        self._touch(old, new)

        def undo() -> None:
            buf.name = old_name
            sched.buffers = old_buffers
            sched.args[:] = old_args
            sched.outputs[:] = old_outputs
            sched.value_bytes = old_value_bytes
        self._undo.append(undo)
        self._after()

    def set_buffer_attrs(self, name: str, *, stages: int | None = None,
                         placement: str | None = None) -> None:
        """Adjust ping-pong depth / placement (the soft-FIFO transform).
        Neither attribute participates in the topology, so no index
        maintenance is needed — but the change still logs an inverse."""
        self._check_open()
        buf = self.sched.buffers[name]
        old = (buf.stages, buf.placement)
        if stages is not None:
            buf.stages = stages
        if placement is not None:
            buf.placement = placement

        def undo() -> None:
            buf.stages, buf.placement = old
        self._undo.append(undo)
        self._after()

    def add_token(self, src: str, dst: str) -> TokenEdge:
        """Append an elastic-ordering token edge (Section 6.4.2)."""
        self._check_open()
        edge = TokenEdge(src=src, dst=dst)
        self.sched.tokens.append(edge)

        def undo() -> None:
            _remove_identical(self.sched.tokens, edge)
        self._undo.append(undo)
        self._after()
        return edge

    # -- schedule-level attributes --------------------------------------------
    def set_stage(self, node: Node, stage: int) -> None:
        """Pipeline-stage assignment (not a topology input, but staged
        state must still be transactional so callers can never observe a
        half-applied mapping)."""
        self._check_open()
        old = node.stage
        node.stage = stage

        def undo() -> None:
            node.stage = old
        self._undo.append(undo)

    def set_outputs(self, outputs: Sequence[str]) -> None:
        self._check_open()
        sched = self.sched
        old = list(sched.outputs)
        sched.outputs = list(outputs)

        def undo() -> None:
            sched.outputs = old
        self._undo.append(undo)

    def set_value_bytes(self, value_bytes: dict[str, int]) -> None:
        self._check_open()
        sched = self.sched
        old = dict(sched.value_bytes)
        sched.value_bytes = dict(value_bytes)

        def undo() -> None:
            sched.value_bytes = old
        self._undo.append(undo)
