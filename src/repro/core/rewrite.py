"""Transactional rewrite layer: every structural mutation of the
Functional graph and the Structural schedule flows through a session.

HIDA's optimizer is hierarchical precisely because every pass — task
fusion (Alg. 2), multi-producer elimination (Alg. 3), data-path balancing
(Section 6.4.2), Functional→Structural lowering (Section 6.3) — reasons
over the *same* dataflow structure.  Before this layer, each pass kept
its own ad-hoc producer/consumer scans and mutated ``Graph`` /
``Schedule`` raw, leaving ``Schedule.topology()`` to detect the damage by
signature mismatch and re-index from scratch.  Now:

* :class:`GraphRewriteSession` wraps a :class:`~repro.core.ir.Graph` and
  owns the fusion-facing view of :class:`~repro.core.ir.GraphTopology`:
  per-dispatch region indices (direct successor/predecessor graphs, an
  incrementally-maintained transitive-closure reachability index, and
  program-order ranks), task rollups (produces / consumes / intensity /
  leaf kinds), adjacency / cycle queries — maintained in **O(Δ)** per
  :meth:`~GraphRewriteSession.fuse` / :meth:`~GraphRewriteSession.split`
  (one region scan plus closure-row updates for the tasks whose
  reachability actually changed — never a DFS per query, never a
  quadratic rebuild per worklist step).

* :class:`ScheduleRewriteSession` wraps a
  :class:`~repro.core.ir.Schedule` and maintains the producer/consumer
  indices of :class:`~repro.core.ir.ScheduleTopology` across its
  primitives (``add_node`` / ``retire_node`` / ``replace_nodes`` /
  ``rename_arg`` / ``rename_buffer`` / ``insert_copy`` / ``set_arg`` /
  ``drop_arg`` / buffer and token edits).  Derived per-buffer structures
  (axis dims, the edge list, the dim→buffer inverted index) are
  invalidated per *touched buffer* and regenerated only for those buffers
  at :meth:`~ScheduleRewriteSession.commit` — untouched buffers reuse the
  pre-session topology's entries verbatim.

Both sessions are **transactions**, mirroring
:class:`~repro.core.incremental.IncrementalEstimator`'s
propose/commit/rollback:

* ``commit()`` installs the maintained topology into the owner's cache
  (``graph._topology`` / ``sched._topology``) with a fresh structure
  signature, so the next ``topology()`` call is a cache *hit* — no pass
  boundary pays a re-index.
* ``rollback()`` undoes every IR mutation (each primitive logs an exact
  inverse) and reinstates the untouched pre-session topology object.
* Used as a context manager, exit commits on success and rolls back on
  exception — a pass can never leave the IR half-rewritten.

``tests/test_rewrite.py`` property-checks the whole contract: after any
prefix of a pass's rewrite trace, the maintained topology fingerprint
equals a from-scratch ``build()``, and rollback restores the pre-session
schedule and topology bit-exactly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Iterable, Optional, Sequence

from .faults import fault_point
from .ir import (AccessMap, Buffer, Graph, GraphTopology, MemoryEffect, Node,
                 Op, Schedule, ScheduleTopology, TokenEdge, depth_map_over,
                 fresh_name, make_dispatch, make_task, topo_order_over)


class RewriteError(RuntimeError):
    """Misuse of a rewrite session (closed session, duplicate buffer,
    canonicalized graph rolled back, …)."""


def _remove_identical(lst: list, obj) -> bool:
    """Remove ``obj`` from ``lst`` by identity (dataclass ``==`` is deep
    and could match a distinct object)."""
    for i, x in enumerate(lst):
        if x is obj:
            del lst[i]
            return True
    return False


def _index_identical(lst: list, obj) -> int:
    """``lst.index(obj)`` by identity (see :func:`_remove_identical`)."""
    for i, x in enumerate(lst):
        if x is obj:
            return i
    raise ValueError(f"{getattr(obj, 'name', obj)!r} not in list")


def make_copy_op(buf: Buffer, src: str, dst: str) -> Op:
    """An explicit memory copy over the buffer's full index space — the
    copy iterates every axis, so it is shardable like any other node."""
    loop = {d: s for d, s in zip(buf.dims, buf.shape)}
    am = AccessMap.identity(buf.dims)
    return Op(name=fresh_name("copy"), kind="copy", ins=[src], outs=[dst],
              loop_dims=loop, access={src: am, dst: am})


# --------------------------------------------------------------------------
# Topology fingerprints (property tests + selfcheck mode)
# --------------------------------------------------------------------------

def schedule_topology_fingerprint(topo: ScheduleTopology) -> dict:
    """Name-based semantic content of a :class:`ScheduleTopology` — two
    topologies describe the same structure iff their fingerprints are
    equal (the lazy ``_access`` cache is deliberately excluded)."""
    return {
        "producers": {b: [n.name for n in v]
                      for b, v in topo.producers.items() if v},
        "consumers": {b: [n.name for n in v]
                      for b, v in topo.consumers.items() if v},
        "edges": list(topo.edges),
        "axis_owner_dims": {
            b: tuple(tuple((n.name, d) for n, d in pairs) for pairs in per)
            for b, per in topo.axis_owner_dims.items()},
        "axis_dims": dict(topo.axis_dims),
        "buffers_of_dim": dict(topo.buffers_of_dim),
        "signature": topo.signature,
    }


def graph_topology_fingerprint(topo: GraphTopology, graph: Graph) -> dict:
    """Name-based semantic content of a :class:`GraphTopology` restricted
    to ops currently reachable from ``graph`` (rollup memos are lazy
    caches and excluded; parent entries for retired ops are ignored)."""
    live = {id(o): o.name for o in graph.walk()}
    return {
        "producers": {v: [o.name for o in ops]
                      for v, ops in topo.producers.items() if ops},
        "consumers": {v: [o.name for o in ops]
                      for v, ops in topo.consumers.items() if ops},
        "parent": {name: (topo.parent.get(i).name
                          if topo.parent.get(i) is not None else None)
                   for i, name in live.items()},
        "signature": topo.signature,
    }


# --------------------------------------------------------------------------
# Functional-level session
# --------------------------------------------------------------------------

def _bits(mask: int):
    """Yield the set bit positions of ``mask`` (ascending)."""
    while mask:
        low = mask & -mask
        yield low.bit_length() - 1
        mask ^= low


# -- blocked closure rows ---------------------------------------------------
#
# A closure row is a sparse bitset stored as ``dict[block -> word]``: bit
# position ``p`` lives in 64-bit word ``p >> 6`` at offset ``p & 63``, and
# zero words are never stored — the dict keys *are* the per-block occupancy
# index.  Arbitrary-precision int rows pay for every bit below the highest
# set one (a task late in a 10k-task region costs ~1.2 KB per row even when
# it reaches three neighbours); blocked rows pay 8 bytes per *occupied*
# block, so band-structured closures (pipelines, MoE fan-outs) stay linear
# in the edges that exist.  Rows are immutable by convention — every helper
# returns a fresh dict — which keeps the exact-rollback contract of the int
# representation: undo logs store the previous row object, nothing aliases.

def _row_bits(row: dict[int, int]):
    """Yield the set bit positions of ``row`` (ascending)."""
    for k in sorted(row):
        w = row[k]
        base = k << 6
        while w:
            low = w & -w
            yield base + low.bit_length() - 1
            w ^= low


def _row_has(row: dict[int, int], p: int) -> bool:
    return bool(row.get(p >> 6, 0) >> (p & 63) & 1)


def _row_set(row: dict[int, int], p: int) -> dict[int, int]:
    """``row | {p}`` as a fresh row."""
    new = dict(row)
    new[p >> 6] = new.get(p >> 6, 0) | 1 << (p & 63)
    return new


def _row_or(a: dict[int, int], b: dict[int, int]) -> dict[int, int]:
    new = dict(a)
    for k, w in b.items():
        new[k] = new.get(k, 0) | w
    return new


def _row_fold(row: dict[int, int], p1: int, p2: int,
              add: dict[int, int]) -> dict[int, int]:
    """``(row & ~{p1, p2}) | add`` in one allocation — the per-row fuse
    update (kill the fused pair's bits, add the merged task's)."""
    new = dict(row)
    for p in (p1, p2):
        k = p >> 6
        w = new.get(k)
        if w is not None:
            w &= ~(1 << (p & 63))
            if w:
                new[k] = w
            else:
                del new[k]
    for k, w in add.items():
        new[k] = new.get(k, 0) | w
    return new


_ROW_EMPTY: dict[int, int] = {}


def _row_intersects(a: dict[int, int], b: dict[int, int]) -> bool:
    if len(b) < len(a):
        a, b = b, a
    return any(w & b.get(k, 0) for k, w in a.items())


def _row_count(row: dict[int, int]) -> int:
    return sum(w.bit_count() for w in row.values())


def _row_bytes(row: dict[int, int]) -> int:
    """Logical footprint: 8 bytes per occupied 64-bit block."""
    return 8 * len(row)


def _row_to_int(row: dict[int, int]) -> int:
    """Flatten a blocked row to the equivalent bitmask int (the reference
    representation the property tests compare against)."""
    mask = 0
    for k, w in row.items():
        mask |= w << (k << 6)
    return mask


def _row_from_int(mask: int) -> dict[int, int]:
    """Inverse of :func:`_row_to_int`."""
    row: dict[int, int] = {}
    k = 0
    while mask:
        w = mask & 0xFFFFFFFFFFFFFFFF
        if w:
            row[k] = w
        mask >>= 64
        k += 1
    return row


@dataclass
class _RegionIndex:
    """Maintained structure over one dispatch region's task graph.

    ``succ`` / ``pred`` are the direct dataflow edges (a task feeds
    another through some value); ``reach`` / ``rreach`` are the
    transitive closure and its inverse (every task reachable via ≥1 edge
    / every task that reaches the key) — the index behind
    :meth:`GraphRewriteSession.creates_cycle`, which becomes two bitwise
    ANDs instead of a DFS.

    Rows are **blocked bitsets** (``dict[block -> 64-bit word]``, zero
    words never stored — see the row helpers above): each task owns a
    bit position for the index's lifetime (merged tasks append new
    bits), so the per-fuse row rewrites — the dominant maintenance cost
    with set rows — are single ``(row & kill) | add`` folds over the
    *occupied* blocks only.  Dense int rows were the previous
    representation; they stay exact but pay for every bit below the
    highest set one, which at 10k tasks costs ~12 MB of closure rows and
    an O(n²) build even when the closure is band-structured.  Blocked
    rows keep both linear in the occupancy, and the rows stay immutable
    by convention (helpers return fresh dicts), so the exact-rollback
    contract is unchanged: undo logs store the previous row object,
    nothing can alias.  ``tests/test_blocked_rows.py`` pins blocked ==
    int-row == from-scratch-DFS closures on every rewrite.

    Interval/ILP-style orders were considered and rejected: they answer
    reachability in O(1) but cost O(region) relabelling per contraction,
    while closure rows cost O(changed rows · occupied blocks) and stay
    exact.

    ``rank`` is a program-order rank: it respects the region list order
    at all times (fusing assigns the merged task the lower of its
    parents' ranks — exactly where the merged task lands in the region),
    stays unique, and is *static* per task, so worklist structures keyed
    on it (the balance phase's pair heap) never need re-keying as the
    region list shifts.

    All keys are ``id(task)`` (tasks are pinned by the session for the
    index's lifetime); ``ops`` maps each live id back to its task and
    doubles as the liveness set; ``by_bit`` maps bit positions back to
    tasks (entries for fused-away tasks are stale — live rows never
    reference a dead bit, the maintenance clears them)."""

    ops: dict[int, Op]
    bit: dict[int, int]
    by_bit: list[Op]
    succ: dict[int, dict[int, int]]
    pred: dict[int, dict[int, int]]
    reach: dict[int, dict[int, int]]
    rreach: dict[int, dict[int, int]]
    rank: dict[int, int]
    #: bumped whenever reachability may have been *reduced* (the
    #: vanished-edge fuse fallback, split) — pure contraction never bumps.
    #: Worklists that cached a cycle verdict must reseed when it changes.
    epoch: int = 0

    def tasks(self, row: dict[int, int]) -> list[Op]:
        return [self.by_bit[b] for b in _row_bits(row)]


def _closure_rows(n: int, succ: list[dict[int, int]],
                  pred: list[dict[int, int]]) -> tuple[
        list[dict[int, int]], list[dict[int, int]]]:
    """Transitive closure (and inverse) of the DAG given as per-position
    blocked successor/predecessor rows — one Kahn walk plus one
    OR-per-occupied-block per edge (never touches the empty blocks a
    dense row representation would).  Falls back to per-node DFS if the
    input has a cycle (cannot happen for SSA-derived regions, but a
    query index must not infinite-loop on degenerate input)."""
    indeg = [_row_count(pred[i]) for i in range(n)]
    stack = [i for i in range(n) if indeg[i] == 0]
    order: list[int] = []
    while stack:
        i = stack.pop()
        order.append(i)
        for j in _row_bits(succ[i]):
            indeg[j] -= 1
            if indeg[j] == 0:
                stack.append(j)
    reach: list[dict[int, int]] = [{} for _ in range(n)]
    rreach: list[dict[int, int]] = [{} for _ in range(n)]
    if len(order) == n:
        for i in reversed(order):
            r: dict[int, int] = {}
            for j in _row_bits(succ[i]):
                r[j >> 6] = r.get(j >> 6, 0) | 1 << (j & 63)
                for k, w in reach[j].items():
                    r[k] = r.get(k, 0) | w
            reach[i] = r
        for i in order:
            rr: dict[int, int] = {}
            for j in _row_bits(pred[i]):
                rr[j >> 6] = rr.get(j >> 6, 0) | 1 << (j & 63)
                for k, w in rreach[j].items():
                    rr[k] = rr.get(k, 0) | w
            rreach[i] = rr
    else:
        for i in range(n):
            seen: set[int] = set()
            work = list(_row_bits(succ[i]))
            while work:
                j = work.pop()
                if j in seen:
                    continue
                seen.add(j)
                work.extend(_row_bits(succ[j]))
            seen.discard(i)
            row: dict[int, int] = {}
            for j in seen:
                row[j >> 6] = row.get(j >> 6, 0) | 1 << (j & 63)
            reach[i] = row
        for i in range(n):
            for j in _row_bits(reach[i]):
                rw = rreach[j]
                rw[i >> 6] = rw.get(i >> 6, 0) | 1 << (i & 63)
    return reach, rreach


def _closure_rows_int(n: int, succ: list[int], pred: list[int]) -> tuple[
        list[int], list[int]]:
    """Reference transitive closure over dense int bitmask rows — the
    previous representation, kept as the differential oracle the blocked
    closure is property-tested against (``tests/test_blocked_rows.py``
    asserts blocked == int on every rewrite of a random-DAG sweep)."""
    indeg = [pred[i].bit_count() for i in range(n)]
    stack = [i for i in range(n) if indeg[i] == 0]
    order: list[int] = []
    while stack:
        i = stack.pop()
        order.append(i)
        for j in _bits(succ[i]):
            indeg[j] -= 1
            if indeg[j] == 0:
                stack.append(j)
    reach = [0] * n
    rreach = [0] * n
    if len(order) == n:
        for i in reversed(order):
            r = 0
            for j in _bits(succ[i]):
                r |= (1 << j) | reach[j]
            reach[i] = r
        for i in order:
            rr = 0
            for j in _bits(pred[i]):
                rr |= (1 << j) | rreach[j]
            rreach[i] = rr
    else:
        for i in range(n):
            seen = 0
            work = succ[i]
            while work:
                low = work & -work
                j = low.bit_length() - 1
                work ^= low
                if not seen >> j & 1:
                    seen |= low
                    work |= succ[j] & ~seen
            reach[i] = seen & ~(1 << i)
        for i in range(n):
            for j in _bits(reach[i]):
                rreach[j] |= 1 << i
    return reach, rreach


def _build_region_index(topo: GraphTopology, d: Op) -> _RegionIndex:
    """From-scratch index for dispatch ``d`` — built once per dispatch,
    then maintained across fuses.  Direct edges come from an inverted
    value→consumers map (O(region + edges), identical edge set to the
    former all-pairs ``produces(i) & consumes(j)`` scan, which was the
    O(region²) wall at 5k+ tasks), and the closure build touches only
    occupied blocks."""
    region = list(d.region)
    n = len(region)
    succ: list[dict[int, int]] = [{} for _ in range(n)]
    pred: list[dict[int, int]] = [{} for _ in range(n)]
    cons_at: dict[str, list[int]] = {}
    for j, t in enumerate(region):
        for v in topo.consumes(t):
            cons_at.setdefault(v, []).append(j)
    for i, t in enumerate(region):
        row = succ[i]
        for v in topo.produces(t):
            for j in cons_at.get(v, ()):
                if j != i:
                    row[j >> 6] = row.get(j >> 6, 0) | 1 << (j & 63)
                    pr = pred[j]
                    pr[i >> 6] = pr.get(i >> 6, 0) | 1 << (i & 63)
    reach, rreach = _closure_rows(n, succ, pred)
    ids = [id(t) for t in region]
    return _RegionIndex(
        ops=dict(zip(ids, region)),
        bit=dict(zip(ids, range(n))),
        by_bit=list(region),
        succ=dict(zip(ids, succ)), pred=dict(zip(ids, pred)),
        reach=dict(zip(ids, reach)), rreach=dict(zip(ids, rreach)),
        rank=dict(zip(ids, range(n))))


def region_index_bytes(idx: _RegionIndex) -> int:
    """Logical byte footprint of a region index: 8 bytes per occupied
    64-bit closure-row block across all four row families, plus one word
    per rank/bit entry.  This counts the information the index holds —
    not CPython dict overhead — so it is comparable across
    representations and is what the bench memory gate tracks."""
    total = 0
    for table in (idx.succ, idx.pred, idx.reach, idx.rreach):
        for row in table.values():
            total += _row_bytes(row)
    return total + 8 * (len(idx.rank) + len(idx.bit))


def region_index_fingerprint(idx: _RegionIndex) -> dict:
    """Name-based content of a :class:`_RegionIndex` (exact-rollback
    tests compare these across a mutate → rollback round trip)."""
    def rows(d: dict[int, int]) -> dict:
        return {idx.ops[k].name: frozenset(t.name for t in idx.tasks(v))
                for k, v in d.items()}

    return {"succ": rows(idx.succ), "pred": rows(idx.pred),
            "reach": rows(idx.reach), "rreach": rows(idx.rreach),
            "rank": {idx.ops[k].name: r for k, r in idx.rank.items()},
            "bits": {idx.ops[k].name: b for k, b in idx.bit.items()}}


class GraphRewriteSession:
    """Transactional rewrites over a Functional :class:`Graph`.

    The construction pass (Alg. 1) and the fusion pass (Alg. 2) drive
    their whole worklists through this: adjacency and cycle queries run
    against a per-dispatch :class:`_RegionIndex` (direct edges + an
    incrementally-maintained transitive-closure reachability index) that
    is built once per dispatch and then **maintained** across
    :meth:`fuse` calls — ``creates_cycle`` is two C-level set
    intersections, never a DFS — and rollups are served from the shared
    :class:`GraphTopology` memos."""

    def __init__(self, graph: Graph, selfcheck: bool = False):
        self.graph = graph
        self._base = graph.topology()
        self._parent = dict(self._base.parent)
        #: id(dispatch) -> maintained region index
        self._regions: dict[int, _RegionIndex] = {}
        self._pins: list[Op] = []
        self._undo: list[Callable[[], None]] = []
        self._canonicalized = False
        self._open = True
        self._selfcheck = selfcheck
        #: peak logical footprint of the maintained region indices
        #: (:func:`region_index_bytes` summed over live dispatches),
        #: sampled at every index build and at commit — surfaced through
        #: ``FusionStats.index_peak_bytes`` into the bench memory gate.
        self.index_peak_bytes = 0

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "GraphRewriteSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._open:
            return
        if exc_type is None:
            try:
                self.commit()
            except BaseException:
                # A commit-time failure must not leave a half-mutated
                # graph behind: the undo log is still intact, replay it.
                if self._open:
                    self.rollback()
                raise
        else:
            self.rollback()

    def _check_open(self) -> None:
        if not self._open:
            raise RewriteError("graph rewrite session is closed")

    def commit(self) -> Optional[GraphTopology]:
        """Install the maintained topology on the graph and close the
        session.  After :meth:`canonicalize` the region tree was
        restructured wholesale, so the cache is invalidated instead (the
        next ``graph.topology()`` rebuilds lazily)."""
        self._check_open()
        fault_point("rewrite.commit")
        self._sample_index_bytes()
        self._open = False
        g = self.graph
        if self._canonicalized:
            g._topology = None
            return None
        sig = g.structure_signature()
        base = self._base
        # Ops created mid-session (merged/wrapper tasks) join the pin
        # list even when a later rewrite removed them from the tree:
        # their ids key _parent entries, and a recycled id would alias a
        # stale parent onto a future op.
        base._pins.extend(self._pins)
        if sig == base.signature:
            g._topology = base
            return base
        topo = GraphTopology(
            # Fusion only regroups tasks; the leaf ops — and hence the
            # value→op indices — are untouched and shared with the base.
            producers=base.producers, consumers=base.consumers,
            parent=self._parent, signature=sig,
            _produces=base._produces, _consumes=base._consumes,
            _intensity=base._intensity, _leaf_meta=base._leaf_meta,
            _pins=base._pins)
        g._topology = topo
        return topo

    def rollback(self) -> None:
        """Undo every rewrite (exact inverses, reverse order) and
        reinstate the untouched pre-session topology.  The lazy rollup
        memos are dropped wholesale: any entry recomputed *mid-session*
        (a selfcheck, or an ancestor query after `_invalidate_ancestors`)
        was computed against the mutated tree and must not survive into
        the restored one — they rebuild lazily against the rolled-back
        structure on next query."""
        self._check_open()
        self._open = False
        for undo in reversed(self._undo):
            undo()
        if self._undo:
            base = self._base
            base._produces.clear()
            base._consumes.clear()
            base._intensity.clear()
            base._leaf_meta.clear()
        self.graph._topology = self._base

    # -- queries ------------------------------------------------------------
    def produces(self, t: Op) -> frozenset:
        return self._base.produces(t)

    def consumes(self, t: Op) -> frozenset:
        return self._base.consumes(t)

    def intensity(self, t: Op) -> float:
        return self._base.intensity(t)

    def leaf_meta(self, t: Op) -> tuple[Optional[str], frozenset]:
        return self._base.leaf_meta(t)

    def _ensure_region(self, d: Op) -> _RegionIndex:
        if self._canonicalized:
            raise RewriteError(
                "region queries are invalid after canonicalize() — the "
                "maintained indices no longer describe the tree")
        idx = self._regions.get(id(d))
        if idx is None:
            idx = _build_region_index(self._base, d)
            self._regions[id(d)] = idx
            self._pins.extend(d.region)
            self._pins.append(d)
            self._sample_index_bytes()
        return idx

    def _sample_index_bytes(self) -> None:
        total = sum(region_index_bytes(i) for i in self._regions.values())
        if total > self.index_peak_bytes:
            self.index_peak_bytes = total

    def adjacent(self, d: Op, a: Op, b: Op) -> bool:
        """True when a feeds b or b feeds a through any value."""
        idx = self._ensure_region(d)
        return (_row_has(idx.succ[id(a)], idx.bit[id(b)])
                or _row_has(idx.succ[id(b)], idx.bit[id(a)]))

    def adjacent_pairs(self, d: Op) -> list[tuple[Op, Op]]:
        """Every adjacent task pair of dispatch ``d``, one entry per
        unordered pair (the region graph is a DAG, so each pair has at
        most one direct edge) — the balance phase's seed worklist,
        enumerated in O(edges)."""
        idx = self._ensure_region(d)
        return [(idx.ops[sid], t)
                for sid, row in idx.succ.items() for t in idx.tasks(row)]

    def neighbors(self, d: Op, t: Op) -> list[Op]:
        """Tasks adjacent to ``t`` (either direction), deduplicated."""
        idx = self._ensure_region(d)
        tid = id(t)
        return idx.tasks(_row_or(idx.succ[tid], idx.pred[tid]))

    def neighbors_in_order(self, d: Op, t: Op) -> list[Op]:
        """:meth:`neighbors` sorted by region program order — what a
        candidate scan over ``d.region`` would visit, without the
        O(region) walk."""
        idx = self._ensure_region(d)
        tid = id(t)
        out = idx.tasks(_row_or(idx.succ[tid], idx.pred[tid]))
        out.sort(key=lambda u: idx.rank[id(u)])
        return out

    def alive(self, d: Op, t: Op) -> bool:
        """True while ``t`` is a live task of dispatch ``d`` (not yet
        fused away) — O(1), for lazily-invalidated worklist entries."""
        return id(t) in self._ensure_region(d).ops

    def region_epoch(self, d: Op) -> int:
        """Bumped whenever ``d``'s reachability may have been *reduced*
        (the vanished-edge fuse fallback, :meth:`split`); unchanged by
        pure contraction.  A worklist that permanently discarded a
        cycle-creating pair (legal under contraction, where paths only
        ever appear) must reseed when this changes."""
        return self._ensure_region(d).epoch

    def rank(self, d: Op, t: Op) -> int:
        """Program-order rank of ``t`` in ``d``'s region: respects the
        region list order at all times and is static per task (a merged
        task inherits the lower parent rank — its region position), so
        heap keys built from it never go stale."""
        return self._ensure_region(d).rank[id(t)]

    def order(self, d: Op, a: Op, b: Op) -> tuple[Op, Op]:
        """``(a, b)`` sorted by region program order (rank-served — the
        O(region) ``list.index`` scan the passes used to pay)."""
        idx = self._ensure_region(d)
        return (a, b) if idx.rank[id(a)] <= idx.rank[id(b)] else (b, a)

    def creates_cycle(self, d: Op, a: Op, b: Op) -> bool:
        """Fusing a and b is illegal when a third task sits on a dataflow
        path between them (the merged task would both feed and consume
        it).  This matters for decode graphs: qkv → cache-update →
        attention must not fuse qkv with attention around the
        cache-update node.

        Served by the maintained reachability index: a third task sits
        between a and b iff ``reach(a) ∩ rreach(b)`` (or the mirror) is
        non-empty — two bitwise ANDs, no DFS.  While both tasks live the
        status is monotone *under pure contraction* (fusing other pairs
        only adds paths), so a ``True`` answer may be cached as long as
        :meth:`region_epoch` is unchanged; the vanished-edge fallback and
        :meth:`split` can remove paths and bump the epoch."""
        idx = self._ensure_region(d)
        ia, ib = id(a), id(b)
        return (_row_intersects(idx.reach[ia], idx.rreach[ib])
                or _row_intersects(idx.reach[ib], idx.rreach[ia]))

    def _invalidate_ancestors(self, d: Op) -> None:
        """Drop the rollup memos of ``d`` and every enclosing region op:
        restructuring inside ``d`` leaves ancestor produces/consumes sets
        intact *as sets* but reassociates their float intensity sums and
        leaf walks — a stale memo here would leak into a later query
        (the selfcheck catches exactly this drift)."""
        topo = self._base
        cur: Optional[Op] = d
        seen: set[int] = set()
        while cur is not None and id(cur) not in seen:
            seen.add(id(cur))
            for memo in (topo._produces, topo._consumes, topo._intensity,
                         topo._leaf_meta):
                memo.pop(id(cur), None)
            cur = self._parent.get(id(cur))

    # -- rewrites -----------------------------------------------------------
    def fuse(self, d: Op, a: Op, b: Op) -> Op:
        """Fuse two tasks of one dispatch region into a new task,
        preserving program order (transparent regions make this a pure
        re-wrap).  The merged task's rollups come from O(1) set algebra
        over the memoized operands; the region index is maintained in
        O(Δ): direct edges fold from the fused pair's rows, and only the
        closure rows of tasks whose reachability actually changed (the
        merged task's ancestors and descendants) are rewritten — each
        fold touching just that row's occupied blocks.  Every touched
        row's previous value is logged for an exact inverse, so rollback
        restores the index bit-for-bit."""
        self._check_open()
        idx = self._ensure_region(d)
        region = d.region
        ia, ib = _index_identical(region, a), _index_identical(region, b)
        first, second = (a, b) if ia <= ib else (b, a)
        i = min(ia, ib)
        merged = make_task(list(first.region) + list(second.region))
        old_region = list(region)
        region[i] = merged
        _remove_identical(region, second)

        topo = self._base
        topo.note_fusion(merged, first, second)
        fid, sid, mid = id(first), id(second), id(merged)
        mcons = topo.consumes(merged)
        rank_first = idx.rank[fid]   # == min of the two: rank ≡ region order

        # Fusion is edge *contraction* — almost.  Outgoing edges rename
        # exactly (produces(m) is the full union), and no incoming edge
        # appears from nowhere, but an edge into `second` through a value
        # `first` also produces VANISHES (the value became region-internal
        # to m, so m's live-ins drop it).  Detect that case by re-deriving
        # m's true predecessors from the rollups; when an edge vanished,
        # the incremental closure formula is invalid and the index is
        # rebuilt (rare: it needs a multi-produced Functional value).
        bf, bs = idx.bit[fid], idx.bit[sid]
        succ_m = _row_fold(_row_or(idx.succ[fid], idx.succ[sid]),
                           bf, bs, _ROW_EMPTY)
        pred_renamed = _row_fold(_row_or(idx.pred[fid], idx.pred[sid]),
                                 bf, bs, _ROW_EMPTY)
        pred_m: dict[int, int] = {}
        vanished = False
        for pos in _row_bits(pred_renamed):
            if topo.produces(idx.by_bit[pos]) & mcons:
                pred_m[pos >> 6] = pred_m.get(pos >> 6, 0) | 1 << (pos & 63)
            else:
                vanished = True

        if not vanished:
            # Pure contraction: maintain in O(Δ).  Rows are treated as
            # immutable (folds allocate fresh dicts), so the undo log
            # just keeps the previous row object; only rows incident to
            # m's ancestors / descendants change, and each fold touches
            # only that row's occupied blocks.
            bm = len(idx.by_bit)
            idx.by_bit.append(merged)
            add_m = {bm >> 6: 1 << (bm & 63)}
            old_rows: list[tuple[dict, int, dict]] = []
            reach_m = _row_fold(_row_or(idx.reach[fid], idx.reach[sid]),
                                bf, bs, _ROW_EMPTY)
            rreach_m = _row_fold(_row_or(idx.rreach[fid], idx.rreach[sid]),
                                 bf, bs, _ROW_EMPTY)
            for pos in _row_bits(pred_m):
                tid = id(idx.by_bit[pos])
                old_rows.append((idx.succ, tid, idx.succ[tid]))
                idx.succ[tid] = _row_fold(idx.succ[tid], bf, bs, add_m)
            for pos in _row_bits(succ_m):
                tid = id(idx.by_bit[pos])
                old_rows.append((idx.pred, tid, idx.pred[tid]))
                idx.pred[tid] = _row_fold(idx.pred[tid], bf, bs, add_m)
            add_reach = _row_or(reach_m, add_m)
            for pos in _row_bits(rreach_m):
                tid = id(idx.by_bit[pos])
                old_rows.append((idx.reach, tid, idx.reach[tid]))
                idx.reach[tid] = _row_fold(idx.reach[tid], bf, bs,
                                           add_reach)
            add_rreach = _row_or(rreach_m, add_m)
            for pos in _row_bits(reach_m):
                tid = id(idx.by_bit[pos])
                old_rows.append((idx.rreach, tid, idx.rreach[tid]))
                idx.rreach[tid] = _row_fold(idx.rreach[tid], bf, bs,
                                            add_rreach)
            popped: list[tuple[dict, int, object]] = []
            for table in (idx.succ, idx.pred, idx.reach, idx.rreach,
                          idx.rank, idx.ops, idx.bit):
                for tid in (fid, sid):
                    popped.append((table, tid, table.pop(tid)))
            idx.succ[mid] = succ_m
            idx.pred[mid] = pred_m
            idx.reach[mid] = reach_m
            idx.rreach[mid] = rreach_m
            # The merged task replaces `first` in the region list, so it
            # inherits first's rank — order-consistency and uniqueness
            # hold, and heap keys built from older ranks stay coherent.
            idx.rank[mid] = rank_first
            idx.ops[mid] = merged
            idx.bit[mid] = bm

            def undo_index() -> None:
                for table in (idx.succ, idx.pred, idx.reach, idx.rreach,
                              idx.rank, idx.ops, idx.bit):
                    table.pop(mid, None)
                del idx.by_bit[bm]
                for table, tid, row in old_rows:
                    table[tid] = row
                for table, tid, val in popped:
                    table[tid] = val
        else:
            # A vanished edge invalidated closure deltas: rebuild, but
            # preserve the maintained ranks (heap keys outlive this call).
            # Losing an edge can also *remove* reachability, so cycle
            # verdicts cached by worklists are stale — bump the epoch.
            old_idx = idx
            idx = _build_region_index(topo, d)
            idx.rank = {tid: (rank_first if tid == mid
                              else old_idx.rank[tid])
                        for tid in idx.ops}
            idx.epoch = old_idx.epoch + 1
            self._regions[id(d)] = idx
            self._sample_index_bytes()

            def undo_index() -> None:
                self._regions[id(d)] = old_idx

        self._parent[mid] = d
        for c in merged.region:
            self._parent[id(c)] = merged
        self._pins.append(merged)
        self._invalidate_ancestors(d)

        def undo() -> None:
            region[:] = old_region
            undo_index()
        self._undo.append(undo)
        self._after()
        return merged

    def split(self, d: Op, task: Op, at: int) -> tuple[Op, Op]:
        """Split ``task`` (a region op of dispatch ``d``) into two tasks
        at child index ``at`` — the inverse of :meth:`fuse`.  Splitting
        can *sever* reachability (paths through the merged task may not
        exist through either half), which no closure delta expresses
        cheaply, so the region index is rebuilt (ranks reset to the
        current region order); split is an API-completeness primitive,
        not a worklist step — no pass splits mid-heap."""
        self._check_open()
        if not 0 < at < len(task.region):
            raise RewriteError(f"split index {at} out of range for "
                               f"{task.name} ({len(task.region)} children)")
        old_idx = self._ensure_region(d)
        region = d.region
        i = _index_identical(region, task)
        head = make_task(list(task.region[:at]))
        tail = make_task(list(task.region[at:]))
        old_region = list(region)
        region[i:i + 1] = [head, tail]

        for part in (head, tail):
            self._parent[id(part)] = d
            for c in part.region:
                self._parent[id(c)] = part
            self._pins.append(part)
        new_idx = _build_region_index(self._base, d)
        new_idx.epoch = old_idx.epoch + 1   # reachability may have shrunk
        self._regions[id(d)] = new_idx
        self._sample_index_bytes()
        self._invalidate_ancestors(d)

        def undo() -> None:
            region[:] = old_region
            self._regions[id(d)] = old_idx
        self._undo.append(undo)
        self._after()
        return head, tail

    def wrap_dispatch(self, owner: Optional[Op]) -> Op:
        """Construction primitive (paper Alg. 1): wrap every op of
        ``owner``'s region (or the graph's top level when ``owner`` is
        None) into its own ``task`` — existing tasks/dispatches pass
        through — and the whole list into one ``dispatch`` that replaces
        the region's content.

        Leaf ops are untouched, so the value→op indices stay valid
        verbatim; only the parent map grows (O(wrapped) new entries).
        That is what lets ``construct_functional`` run transactionally
        *and* hand the fusion pass a warm topology at commit instead of
        forcing the full rebuild the pre-session construct pass caused."""
        self._check_open()
        if self._canonicalized:
            raise RewriteError("wrap_dispatch after canonicalize()")
        container = owner.region if owner is not None else self.graph.ops
        old = list(container)
        tasks = [o if o.kind in ("task", "dispatch") else make_task([o])
                 for o in old]
        d = make_dispatch(tasks)
        container[:] = [d]

        old_parents = {id(o): self._parent.get(id(o)) for o in old}
        self._parent[id(d)] = owner
        for t, o in zip(tasks, old):
            self._parent[id(t)] = d
            if t is not o:
                self._parent[id(o)] = t
        self._pins.append(d)
        self._pins.extend(t for t, o in zip(tasks, old) if t is not o)
        if owner is not None:
            self._invalidate_ancestors(owner)

        def undo() -> None:
            container[:] = old
            self._parent.pop(id(d), None)
            for t, o in zip(tasks, old):
                if t is not o:
                    self._parent.pop(id(t), None)
            for oid, par in old_parents.items():
                self._parent[oid] = par
        self._undo.append(undo)
        self._after()
        return d

    def canonicalize(self, fn: Callable[[Op], Op]) -> None:
        """Wholesale region-tree restructure (e.g.
        :func:`~repro.core.fusion.simplify_hierarchy`): apply ``fn`` to
        every top-level op.  This invalidates the maintained topology at
        commit (the one full rebuild happens lazily on the next
        ``graph.topology()`` call, *after* the worklist is done — never
        between worklist steps)."""
        self._check_open()
        g = self.graph
        # fn may rewrite or REBIND op.region at any depth: snapshot both
        # the list object and its content for an exact inverse.  Identity
        # matters — earlier fuse/split undos captured these very list
        # objects, so the inverse must restore content *into them* and
        # re-point op.region at them, or a later rollback would mutate an
        # orphaned list while the op shows the canonicalized one.
        snapshot = [(op, op.region, list(op.region)) for op in g.walk()]
        ops_obj = g.ops
        old_ops = list(g.ops)

        def undo() -> None:
            for op, region_obj, children in snapshot:
                region_obj[:] = children
                op.region = region_obj
            ops_obj[:] = old_ops
            g.ops = ops_obj
        # Logged before fn runs: simplify-style callbacks mutate the tree
        # while traversing, so an exception mid-apply must still restore.
        self._undo.append(undo)
        self._canonicalized = True
        g.ops = [fn(o) for o in g.ops]

    # -- selfcheck ----------------------------------------------------------
    def _after(self) -> None:
        if self._selfcheck:
            self.selfcheck()

    def selfcheck(self) -> None:
        """Assert every maintained structure equals a from-scratch
        rebuild (property-test / debugging hook; O(graph) per call)."""
        g = self.graph
        fresh = GraphTopology.build(g)
        live = {id(o) for o in g.walk()}
        # Rollups for every live op the memo knows about.
        for op in list(g.walk()):
            assert self._base.produces(op) == frozenset(op.all_outs()), \
                f"produces drift on {op.name}"
            assert self._base.consumes(op) == frozenset(op.all_ins()), \
                f"consumes drift on {op.name}"
            assert self._base.intensity(op) == op.intensity(), \
                f"intensity drift on {op.name}"
        # Parent map over live ops.
        maintained_parent = {
            o.name: (self._parent.get(id(o)).name
                     if self._parent.get(id(o)) is not None else None)
            for o in g.walk()}
        fresh_parent = {
            o.name: (fresh.parent[id(o)].name
                     if fresh.parent[id(o)] is not None else None)
            for o in g.walk()}
        assert maintained_parent == fresh_parent, "parent map drift"
        # Region indices for every ensured dispatch still in the graph:
        # direct edges, the reachability closure (vs a from-scratch DFS),
        # its inverse, and the program-order rank invariant.
        by_id = {id(o): o for o in g.walk()}
        for did, idx in self._regions.items():
            d = by_id.get(did)
            if d is None or d.kind != "dispatch":
                continue
            fresh_succ = {}
            for i, a in enumerate(d.region):
                fresh_succ[id(a)] = {
                    id(b) for j, b in enumerate(d.region)
                    if i != j and frozenset(a.all_outs()) & frozenset(
                        b.all_ins())}
            assert set(idx.ops) == {id(t) for t in d.region}, \
                f"live-task drift in {d.name}"

            def ids_of(mask: int) -> set[int]:
                return {id(t) for t in idx.tasks(mask)}

            maintained_succ = {k: ids_of(v) for k, v in idx.succ.items()}
            assert maintained_succ == fresh_succ, f"succ drift in {d.name}"
            fresh_pred = {k: set() for k in fresh_succ}
            for s, row in fresh_succ.items():
                for t in row:
                    fresh_pred[t].add(s)
            maintained_pred = {k: ids_of(v) for k, v in idx.pred.items()}
            assert maintained_pred == fresh_pred, f"pred drift in {d.name}"
            fresh_reach = {}
            for tid in fresh_succ:
                seen: set[int] = set()
                stack = list(fresh_succ[tid])
                while stack:
                    n = stack.pop()
                    if n in seen:
                        continue
                    seen.add(n)
                    stack.extend(fresh_succ[n])
                seen.discard(tid)
                fresh_reach[tid] = seen
            maintained_reach = {k: ids_of(v) for k, v in idx.reach.items()}
            assert maintained_reach == fresh_reach, f"reach drift in {d.name}"
            fresh_rreach = {k: set() for k in fresh_reach}
            for s, row in fresh_reach.items():
                for t in row:
                    fresh_rreach[t].add(s)
            maintained_rreach = {k: ids_of(v) for k, v in idx.rreach.items()}
            assert maintained_rreach == fresh_rreach, \
                f"rreach drift in {d.name}"
            ranks = [idx.rank[id(t)] for t in d.region]
            assert ranks == sorted(ranks) and len(set(ranks)) == len(ranks), \
                f"rank order drift in {d.name}"


# --------------------------------------------------------------------------
# Structural-level session
# --------------------------------------------------------------------------

class ScheduleRewriteSession:
    """Transactional rewrites over a Structural :class:`Schedule`.

    Maintains the producer/consumer indices of
    :class:`ScheduleTopology` in O(Δ) per primitive and re-derives the
    per-buffer axis structures only for buffers a rewrite actually
    touched; :meth:`commit` installs the result as the schedule's cached
    topology (so the downstream DSE starts on a warm cache), and
    :meth:`rollback` restores the schedule and its pre-session topology
    exactly."""

    def __init__(self, sched: Schedule, selfcheck: bool = False):
        self.sched = sched
        self._base = sched.topology()
        self._producers = {b: list(v) for b, v in self._base.producers.items()}
        self._consumers = {b: list(v) for b, v in self._base.consumers.items()}
        self._pos = {n.name: i for i, n in enumerate(sched.nodes)}
        self._dirty: set[str] = set()
        # Per-buffer edge buckets: the canonical edge list is the
        # concatenation of buckets in ``sched.buffers`` order, and a
        # rewrite only invalidates the buckets of the buffers it touched
        # — a 1k-dispatch balance pass regenerates a handful of buckets
        # instead of re-deriving the whole O(buffers·degree) list.
        self._edge_buckets: dict[str, list[tuple[str, str, str]]] = {}
        for e in self._base.edges:
            self._edge_buckets.setdefault(e[2], []).append(e)
        self._stale_buckets: set[str] = set()
        self._edges: Optional[list[tuple[str, str, str]]] = list(
            self._base.edges)
        # Memoized order/depth over the maintained edges.  The depth map
        # is Δ-maintained across pure insertions (add_node can only
        # deepen paths through the new node — a bounded worklist
        # propagation); any shrinking primitive drops it for a lazy full
        # recompute.  Equality with the from-scratch walks is pinned by
        # tests/test_rewrite.py.
        self._order_memo: Optional[list[Node]] = None
        self._depth_memo: Optional[dict[str, int]] = None
        self._undo: list[Callable[[], None]] = []
        self._open = True
        self._selfcheck = selfcheck

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "ScheduleRewriteSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self._open:
            return
        if exc_type is None:
            try:
                self.commit()
            except BaseException:
                # A commit-time failure must not leave a half-mutated
                # schedule behind: the undo log is still intact, replay it.
                if self._open:
                    self.rollback()
                raise
        else:
            self.rollback()

    def _check_open(self) -> None:
        if not self._open:
            raise RewriteError("schedule rewrite session is closed")

    def commit(self) -> ScheduleTopology:
        """Assemble the maintained topology, install it as the
        schedule's cache, and close the session."""
        self._check_open()
        fault_point("rewrite.commit")
        topo = self._assemble()
        self._open = False
        self.sched._topology = topo
        return topo

    def rollback(self) -> None:
        """Undo every rewrite (exact inverses, reverse order) and
        reinstate the untouched pre-session topology.  The base's lazy
        per-(node, buffer) access cache is dropped: an entry computed
        mid-session (e.g. an external ``access_for`` query) reflects a
        mutated node body and must not survive into the restored one."""
        self._check_open()
        self._open = False
        for undo in reversed(self._undo):
            undo()
        if self._undo:
            self._base._access.clear()
        self.sched._topology = self._base

    def _assemble(self) -> ScheduleTopology:
        sched = self.sched
        sig = sched.structure_signature()
        base = self._base
        if sig == base.signature and not self._dirty:
            return base
        producers = {b: list(v) for b, v in self._producers.items() if v}
        consumers = {b: list(v) for b, v in self._consumers.items() if v}
        edges = self._edge_list()
        access: dict[tuple[str, str], Optional[AccessMap]] = {}
        axis_owner_dims: dict[str, tuple] = {}
        axis_dims: dict[str, tuple] = {}
        for bname, buf in sched.buffers.items():
            if bname not in self._dirty and bname in base.axis_owner_dims:
                # Untouched buffer: owners and their access maps are
                # unchanged — reuse the pre-session derivation.
                axis_owner_dims[bname] = base.axis_owner_dims[bname]
                axis_dims[bname] = base.axis_dims[bname]
                continue
            owners = producers.get(bname, []) + consumers.get(bname, [])
            per_axis: list[tuple] = []
            dims: list[Optional[str]] = []
            for axis in range(len(buf.shape)):
                pairs = []
                for node in owners:
                    key = (node.name, bname)
                    if key not in access:
                        access[key] = node.access_for(bname)
                    am = access[key]
                    if am is None or axis >= len(am.entries):
                        continue
                    d = am.entries[axis][0]
                    if d is not None:
                        pairs.append((node, d))
                per_axis.append(tuple(pairs))
                dims.append(pairs[0][1] if pairs else None)
            axis_owner_dims[bname] = tuple(per_axis)
            axis_dims[bname] = tuple(dims)
        buffers_of_dim: dict[str, list[str]] = {}
        for bname in sched.buffers:
            for d in axis_dims[bname]:
                if d is not None and (d not in buffers_of_dim
                                      or buffers_of_dim[d][-1] != bname):
                    buffers_of_dim.setdefault(d, []).append(bname)
        return ScheduleTopology(
            producers=producers, consumers=consumers, edges=edges,
            axis_owner_dims=axis_owner_dims, axis_dims=axis_dims,
            buffers_of_dim={d: tuple(v) for d, v in buffers_of_dim.items()},
            _access=access, signature=sig)

    # -- queries ------------------------------------------------------------
    def producers(self, value: str) -> list[Node]:
        """Nodes writing ``value``, in node order."""
        return list(self._producers.get(value, ()))

    def consumers(self, value: str) -> list[Node]:
        """Nodes reading ``value``, in node order."""
        return list(self._consumers.get(value, ()))

    def owners(self, value: str) -> list[Node]:
        """Producers then consumers — the plan-projection scan order."""
        return self.producers(value) + self.consumers(value)

    def users_in_program_order(self, value: str) -> list[Node]:
        """Every node with ``value`` in its args, ascending node order,
        deduplicated (an RW node indexes as both producer and consumer)."""
        seen: set[str] = set()
        out: list[Node] = []
        nodes = (self._producers.get(value, [])
                 + self._consumers.get(value, []))
        for n in sorted(nodes, key=lambda n: self._pos[n.name]):
            if n.name not in seen:
                seen.add(n.name)
                out.append(n)
        return out

    def position(self, node: Node) -> int:
        return self._pos[node.name]

    def _edge_list(self) -> list[tuple[str, str, str]]:
        if self._edges is None:
            buckets = self._edge_buckets
            for b in self._stale_buckets:
                bucket = []
                for p in self._producers.get(b, ()):
                    for c in self._consumers.get(b, ()):
                        if p.name != c.name:
                            bucket.append((p.name, c.name, b))
                if bucket:
                    buckets[b] = bucket
                else:
                    buckets.pop(b, None)
            self._stale_buckets.clear()
            self._edges = [e for b in self.sched.buffers if b in buckets
                           for e in buckets[b]]
        return self._edges

    def edges(self) -> list[tuple[str, str, str]]:
        """(src, dst, buffer) edges over the current structure, in the
        canonical ``ScheduleTopology.build`` order (only the buckets of
        buffers a rewrite touched are regenerated from the Δ-maintained
        indices; untouched buckets are reused verbatim)."""
        return list(self._edge_list())

    def topo_order(self) -> list[Node]:
        """Stable topological order over the maintained edges, memoized
        until the next structural rewrite (stage-assignment and the
        region partitioner query it repeatedly between rewrites)."""
        if self._order_memo is None:
            self._order_memo = topo_order_over(
                self.sched.nodes, self._edge_list(), self.sched.name)
        return list(self._order_memo)

    def depth_of(self) -> dict[str, int]:
        """Longest-path depth per node over the maintained edges.
        Memoized, and Δ-maintained across :meth:`add_node` (a pure
        insertion only deepens paths through the new node, so a bounded
        worklist propagation replaces the full O(V+E) rebuild the
        balance pass used to pay per query)."""
        if self._depth_memo is None:
            self._depth_memo = depth_map_over(
                self.sched.nodes, self._edge_list(), self.sched.name)
        return dict(self._depth_memo)

    def dse_regions(self, *, max_cut: int = 2,
                    min_nodes: int | None = None,
                    max_nodes: int | None = None) -> "list[RegionSpec]":
        """Region partition for the hierarchical DSE over the session's
        Δ-maintained edge list (same contract as the module-level
        :func:`dse_regions`, without forcing a topology rebuild
        mid-session).  ``min_nodes`` / ``max_nodes`` default to the
        scale-aware :func:`default_region_bounds`."""
        mn, mx = default_region_bounds(len(self.sched.nodes))
        return _dse_regions_over(self.sched.nodes, self._edge_list(),
                                 self.sched.name, max_cut=max_cut,
                                 min_nodes=mn if min_nodes is None
                                 else min_nodes,
                                 max_nodes=mx if max_nodes is None
                                 else max_nodes,
                                 order=self.topo_order())

    # -- index maintenance ---------------------------------------------------
    def _touch(self, *values: str) -> None:
        self._dirty.update(values)
        self._stale_buckets.update(values)
        self._edges = None
        self._order_memo = None
        self._depth_memo = None

    def _reindex_positions(self) -> None:
        self._pos = {n.name: i for i, n in enumerate(self.sched.nodes)}

    def _index_insert(self, index: dict[str, list[Node]], value: str,
                      node: Node) -> None:
        lst = index.setdefault(value, [])
        if any(x is node for x in lst):
            return
        pos = self._pos[node.name]
        at = len(lst)
        for j, other in enumerate(lst):
            if self._pos[other.name] > pos:
                at = j
                break
        lst.insert(at, node)

    def _index_discard(self, index: dict[str, list[Node]], value: str,
                       node: Node) -> None:
        lst = index.get(value)
        if lst is not None:
            _remove_identical(lst, node)

    def _propagate_depth(self, depth: dict[str, int], node: Node
                         ) -> Optional[dict[str, int]]:
        """Depth map after inserting ``node``: a pure insertion only
        *adds* edges, and longest-path depth is monotone under edge
        addition, so relaxing from the insertion point reproduces the
        full recompute exactly.  Returns ``None`` — forcing the lazy
        full rebuild — if the relaxation fails to settle within a
        generous pop budget (only possible when the insertion created a
        cycle, where the rebuild is the path that raises)."""
        d0 = 0
        for b in node.reads():
            for p in self._producers.get(b, ()):
                if p is not node and p.name in depth:
                    d0 = max(d0, depth[p.name] + 1)
        depth[node.name] = d0
        budget = 8 * (len(depth) + 1)
        work = [node]
        while work:
            budget -= 1
            if budget < 0:
                return None
            src = work.pop()
            ds = depth[src.name]
            for b in src.writes():
                for c in self._consumers.get(b, ()):
                    if c is not src and depth.get(c.name, 0) < ds + 1:
                        depth[c.name] = ds + 1
                        work.append(c)
        return depth

    def _sync_arg_index(self, node: Node, value: str) -> None:
        """Make the two indices agree with ``node.args.get(value)``."""
        effect = node.args.get(value)
        if effect in (MemoryEffect.WRITE, MemoryEffect.READ_WRITE):
            self._index_insert(self._producers, value, node)
        else:
            self._index_discard(self._producers, value, node)
        if effect in (MemoryEffect.READ, MemoryEffect.READ_WRITE):
            self._index_insert(self._consumers, value, node)
        else:
            self._index_discard(self._consumers, value, node)

    def _after(self) -> None:
        if self._selfcheck:
            self.selfcheck()

    def selfcheck(self) -> None:
        """Assert the maintained topology equals a from-scratch build
        (property-test / debugging hook; O(schedule) per call)."""
        fresh = ScheduleTopology.build(self.sched)
        assert (schedule_topology_fingerprint(self._assemble())
                == schedule_topology_fingerprint(fresh)), \
            f"topology drift on schedule {self.sched.name}"

    # -- node primitives -----------------------------------------------------
    def add_node(self, node: Node, index: int | None = None) -> Node:
        """Insert ``node`` (at ``index``, default append) and index its
        argument effects."""
        self._check_open()
        sched = self.sched
        # _pos mirrors sched.nodes exactly, so membership there is the
        # duplicate check (the old any() scan was O(n) per insert —
        # quadratic over a 5k-node lowering).
        if node.name in self._pos:
            raise RewriteError(f"duplicate node {node.name}")
        at = len(sched.nodes) if index is None else index
        sched.nodes.insert(at, node)
        if at == len(sched.nodes) - 1:
            # Append fast path: no existing position shifts, so the
            # full O(n) renumber reduces to one dict store.
            self._pos[node.name] = at
        else:
            # Mid-insert: only suffix positions shift, so bump those in
            # place instead of rebuilding the whole dict (balance does
            # ~n mid-inserts; full rebuilds made that quadratic).
            pos = self._pos
            for n in sched.nodes[at + 1:]:
                pos[n.name] += 1
            pos[node.name] = at
        # Keep the schedule's name→Node lookup cache live instead of
        # letting the length change force an O(n) rebuild per insert
        # (balance alone does ~n inserts).
        if sched._node_cache is not None:
            sched._node_cache[node.name] = node
            sched._node_cache_len = len(sched.nodes)
        for b in node.writes():
            self._index_insert(self._producers, b, node)
        for b in node.reads():
            self._index_insert(self._consumers, b, node)
        depth = self._depth_memo
        self._touch(*node.args)
        if depth is not None:
            # Pure insertion: Δ-maintain the depth memo instead of
            # letting _touch force a full O(V+E) rebuild on next query.
            self._depth_memo = self._propagate_depth(depth, node)

        def undo() -> None:
            # Undos run LIFO, so the list state here is exactly the
            # post-insert state: a positional delete is the precise
            # inverse (and O(1) at the tail, vs the old O(n) snapshot
            # copy taken on every insert).  Mirror the insert's
            # shift-only position update.
            del sched.nodes[at]
            pos = self._pos
            del pos[node.name]
            for n in sched.nodes[at:]:
                pos[n.name] -= 1
        self._undo.append(undo)
        self._after()
        return node

    def retire_node(self, node: Node) -> None:
        """Remove ``node`` from the schedule and the indices."""
        self._check_open()
        sched = self.sched
        old_nodes = list(sched.nodes)
        if not _remove_identical(sched.nodes, node):
            raise RewriteError(f"unknown node {node.name}")
        # Drop (not patch) the name cache: a later 1-for-1 replace keeps
        # the list length, so the length check alone can't catch a stale
        # hit on the retired name.
        sched._node_cache = None
        self._reindex_positions()
        for b in node.writes():
            self._index_discard(self._producers, b, node)
        for b in node.reads():
            self._index_discard(self._consumers, b, node)
        self._touch(*node.args)

        def undo() -> None:
            sched.nodes[:] = old_nodes
            # add_node's shift-only undo assumes _pos mirrors the list,
            # so restoring the list must restore the mirror too.
            self._reindex_positions()
        self._undo.append(undo)
        self._after()

    def replace_nodes(self, olds: Sequence[Node], new: Node,
                      index: int) -> Node:
        """Atomically retire ``olds`` and insert ``new`` at ``index`` —
        the multi-producer *fusion* arm (Alg. 3 case 2).  The caller
        builds ``new`` (body concatenation, effect merging are pass
        policy); the session owns the structural swap and re-indexing."""
        self._check_open()
        sched = self.sched
        old_nodes = list(sched.nodes)
        for o in olds:
            if not _remove_identical(sched.nodes, o):
                raise RewriteError(f"unknown node {o.name}")
        sched.nodes.insert(index, new)
        # See retire_node: a 1-for-1 swap preserves the list length, so
        # the name cache must be dropped, not patched.
        sched._node_cache = None
        self._reindex_positions()
        touched: set[str] = set(new.args)
        for o in olds:
            touched.update(o.args)
            for b in o.writes():
                self._index_discard(self._producers, b, o)
            for b in o.reads():
                self._index_discard(self._consumers, b, o)
        for b in new.writes():
            self._index_insert(self._producers, b, new)
        for b in new.reads():
            self._index_insert(self._consumers, b, new)
        self._touch(*touched)

        def undo() -> None:
            sched.nodes[:] = old_nodes
            # See retire_node's undo: keep the _pos mirror in sync.
            self._reindex_positions()
        self._undo.append(undo)
        self._after()
        return new

    # -- argument / body primitives ------------------------------------------
    def set_arg(self, node: Node, value: str, effect: str) -> None:
        """Set ``node.args[value] = effect`` (dict position preserved for
        an existing key, appended for a new one) and re-index."""
        self._check_open()
        old_args = dict(node.args)
        node.args[value] = effect
        self._sync_arg_index(node, value)
        self._touch(value)

        def undo() -> None:
            node.args.clear()
            node.args.update(old_args)
        self._undo.append(undo)
        self._after()

    def drop_arg(self, node: Node, value: str) -> None:
        """Remove ``value`` from ``node.args`` and the indices (used by
        lowering to drop node-internal temporaries)."""
        self._check_open()
        old_args = dict(node.args)
        node.args.pop(value, None)
        self._index_discard(self._producers, value, node)
        self._index_discard(self._consumers, value, node)
        self._touch(value)

        def undo() -> None:
            node.args.clear()
            node.args.update(old_args)
        self._undo.append(undo)
        self._after()

    def rename_arg(self, node: Node, old: str, new: str) -> None:
        """Re-point every use of ``old`` inside ``node`` (args entry, body
        op operands, access-map keys) at ``new`` — the
        ``replace_uses``-per-node primitive of multi-producer elimination
        and balancing."""
        self._check_open()
        old_args = dict(node.args)
        body_snapshot = [(o, list(o.ins), list(o.outs), dict(o.access))
                         for o in node.body]
        if old in node.args:
            node.args[new] = node.args.pop(old)
        for o in node.body:
            o.ins = [new if v == old else v for v in o.ins]
            o.outs = [new if v == old else v for v in o.outs]
            if old in o.access:
                o.access[new] = o.access.pop(old)
        self._index_discard(self._producers, old, node)
        self._index_discard(self._consumers, old, node)
        self._sync_arg_index(node, new)
        self._touch(old, new)

        def undo() -> None:
            node.args.clear()
            node.args.update(old_args)
            for o, ins, outs, access in body_snapshot:
                o.ins = ins
                o.outs = outs
                o.access = access
        self._undo.append(undo)
        self._after()

    def replace_uses(self, old: str, new: str,
                     nodes: Iterable[Node]) -> None:
        """:meth:`rename_arg` over a node subset (e.g. the dominated uses
        of a duplicated buffer)."""
        for n in nodes:
            self.rename_arg(n, old, new)

    def insert_copy(self, node: Node, buf: Buffer, src: str,
                    dst: str) -> Op:
        """Prepend an explicit memory copy ``src -> dst`` to ``node``
        (paper Alg. 3 lines 5-7) and record the new READ effect."""
        self._check_open()
        old_args = dict(node.args)
        old_body = list(node.body)
        op = make_copy_op(buf, src, dst)
        node.body.insert(0, op)
        node.args[src] = MemoryEffect.READ
        self._sync_arg_index(node, src)
        self._touch(src, dst)

        def undo() -> None:
            node.args.clear()
            node.args.update(old_args)
            node.body[:] = old_body
        self._undo.append(undo)
        self._after()
        return op

    # -- buffer / stream primitives -------------------------------------------
    def add_buffer(self, buf: Buffer, external: bool = False) -> Buffer:
        """Register a new buffer (optionally as a schedule argument)."""
        self._check_open()
        sched = self.sched
        if buf.name in sched.buffers:
            raise RewriteError(f"duplicate buffer {buf.name}")
        sched.buffers[buf.name] = buf
        if external:
            sched.args.append(buf.name)
        self._touch(buf.name)

        def undo() -> None:
            del sched.buffers[buf.name]
            if external:
                sched.args.remove(buf.name)
        self._undo.append(undo)
        self._after()
        return buf

    def rename_buffer(self, old: str, new: str) -> None:
        """Rename a buffer everywhere: the buffers dict key, the args
        list, and every owning node (args + body operands)."""
        self._check_open()
        sched = self.sched
        if old not in sched.buffers:
            raise RewriteError(f"unknown buffer {old}")
        if new in sched.buffers:
            raise RewriteError(f"duplicate buffer {new}")
        for n in self.users_in_program_order(old):
            self.rename_arg(n, old, new)
        buf = sched.buffers[old]
        old_buffers = dict(sched.buffers)
        old_args = list(sched.args)
        old_outputs = list(sched.outputs)
        old_value_bytes = dict(sched.value_bytes)
        old_name = buf.name
        sched.buffers = {(new if k == old else k): v
                         for k, v in sched.buffers.items()}
        buf.name = new
        sched.args = [new if a == old else a for a in sched.args]
        sched.outputs = [new if o == old else o for o in sched.outputs]
        # The estimator costs reduction collectives off value_bytes; a
        # stale key would silently zero this buffer's traffic.
        sched.value_bytes = {(new if k == old else k): v
                             for k, v in sched.value_bytes.items()}
        self._touch(old, new)

        def undo() -> None:
            buf.name = old_name
            sched.buffers = old_buffers
            sched.args[:] = old_args
            sched.outputs[:] = old_outputs
            sched.value_bytes = old_value_bytes
        self._undo.append(undo)
        self._after()

    def set_buffer_attrs(self, name: str, *, stages: int | None = None,
                         placement: str | None = None) -> None:
        """Adjust ping-pong depth / placement (the soft-FIFO transform).
        Neither attribute participates in the topology, so no index
        maintenance is needed — but the change still logs an inverse."""
        self._check_open()
        buf = self.sched.buffers[name]
        old = (buf.stages, buf.placement)
        if stages is not None:
            buf.stages = stages
        if placement is not None:
            buf.placement = placement

        def undo() -> None:
            buf.stages, buf.placement = old
        self._undo.append(undo)
        self._after()

    def add_token(self, src: str, dst: str) -> TokenEdge:
        """Append an elastic-ordering token edge (Section 6.4.2)."""
        self._check_open()
        edge = TokenEdge(src=src, dst=dst)
        self.sched.tokens.append(edge)

        def undo() -> None:
            _remove_identical(self.sched.tokens, edge)
        self._undo.append(undo)
        self._after()
        return edge

    # -- schedule-level attributes --------------------------------------------
    def set_stage(self, node: Node, stage: int) -> None:
        """Pipeline-stage assignment (not a topology input, but staged
        state must still be transactional so callers can never observe a
        half-applied mapping)."""
        self._check_open()
        old = node.stage
        node.stage = stage

        def undo() -> None:
            node.stage = old
        self._undo.append(undo)

    def set_outputs(self, outputs: Sequence[str]) -> None:
        self._check_open()
        sched = self.sched
        old = list(sched.outputs)
        sched.outputs = list(outputs)

        def undo() -> None:
            sched.outputs = old
        self._undo.append(undo)

    def set_value_bytes(self, value_bytes: dict[str, int]) -> None:
        self._check_open()
        sched = self.sched
        old = dict(sched.value_bytes)
        sched.value_bytes = dict(value_bytes)

        def undo() -> None:
            sched.value_bytes = old
        self._undo.append(undo)


# --------------------------------------------------------------------------
# Region partitions for the hierarchical DSE
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class RegionSpec:
    """One contiguous slice of the schedule's topological order, exported
    to the two-level DSE (paper Section 4: solve each dataflow node's
    local space, compose at the inter-node level).

    The partition contract (see docs/ARCHITECTURE.md):

    * ``nodes`` is a contiguous run of the stable topological order —
      every node belongs to exactly one region, and concatenating the
      regions in ``index`` order reproduces the topo order exactly.
    * ``boundary`` lists the shared-buffer edges with exactly one
      endpoint inside the region (both directions), in canonical
      topology-edge order — the only coupling the outer composition
      level has to score.
    * Cuts are chosen where few edges cross (layer seams in the LM
      configs), so inner searches see almost all of their QoR terms.
    """

    index: int
    nodes: tuple[str, ...]
    #: (src, dst, buffer) edges crossing the region border.
    boundary: tuple[tuple[str, str, str], ...]


def default_region_bounds(n: int) -> tuple[int, int]:
    """Scale-aware ``(min_nodes, max_nodes)`` for :func:`dse_regions`.

    Schedules up to 256 nodes get the historical ``(3, 16)`` — every
    existing config (≤43 nodes) partitions bit-identically.  Beyond
    that, the region cap grows as ~√n, so region count and per-region
    inner-DSE cost grow together: at 10k nodes the fixed cap would
    produce ~600 three-to-sixteen-node regions (outer composition
    dominates), while √n yields ~100 regions of ~100 nodes — both
    levels stay beam-sized."""
    if n <= 256:
        return 3, 16
    mx = max(16, math.isqrt(n - 1) + 1)
    return max(3, mx // 5), mx


def dse_regions(sched: Schedule,
                topology: ScheduleTopology | None = None, *,
                max_cut: int = 2, min_nodes: int | None = None,
                max_nodes: int | None = None) -> list[RegionSpec]:
    """Partition ``sched`` into dispatch regions for the hierarchical DSE.

    Walks the stable topological order and cuts at boundaries crossed by
    at most ``max_cut`` shared-buffer edges (first such boundary once the
    open region holds ``min_nodes``); a region is force-closed at its
    cheapest seen boundary when it would exceed ``max_nodes``.  The walk
    depends only on the topology (edge structure + program order), never
    on node *names*, so the partition — and every boundary signature
    derived from it — is stable under node renaming.

    ``min_nodes`` / ``max_nodes`` default to the scale-aware
    :func:`default_region_bounds` (identical to the historical ``(3,
    16)`` for schedules up to 256 nodes).

    Returns a single whole-schedule region when the schedule is too small
    to split (callers treat that as "run the flat beam").
    """
    topo = topology if topology is not None else sched.topology()
    mn, mx = default_region_bounds(len(sched.nodes))
    return _dse_regions_over(sched.nodes, topo.edges, sched.name,
                             max_cut=max_cut,
                             min_nodes=mn if min_nodes is None
                             else min_nodes,
                             max_nodes=mx if max_nodes is None
                             else max_nodes)


def _dse_regions_over(nodes: Sequence[Node],
                      edge_iter: Iterable[tuple[str, str, str]],
                      name: str, *, max_cut: int, min_nodes: int,
                      max_nodes: int,
                      order: Sequence[Node] | None = None
                      ) -> list[RegionSpec]:
    edges = list(edge_iter)
    if order is None:
        order = topo_order_over(nodes, edges, name)
    names = [n.name for n in order]
    n = len(names)
    if n < 2 * min_nodes:
        return [RegionSpec(index=0, nodes=tuple(names), boundary=())]

    pos = {nm: i for i, nm in enumerate(names)}
    # crossing[b] = edges spanning the boundary between order[b-1] and
    # order[b]; an edge (s, d) crosses every boundary in (pos[s], pos[d]]
    # — accumulated as a difference array (+1 at lo+1, −1 at hi+1, prefix
    # sum), O(E + n) instead of the former O(E · span) inner loop that
    # dominated at 5k+ nodes.
    diff = [0] * (n + 2)
    for s, d, _b in edges:
        lo, hi = pos[s], pos[d]
        if lo > hi:
            lo, hi = hi, lo
        diff[lo + 1] += 1
        diff[hi + 1] -= 1
    crossing = [0] * (n + 1)
    run = 0
    for b in range(n + 1):
        run += diff[b]
        crossing[b] = run

    cuts: list[int] = []
    start = 0
    best_b: int | None = None  # cheapest boundary seen in the open region
    for b in range(start + 1, n):
        if b - start >= min_nodes and (
                best_b is None or crossing[b] < crossing[best_b]):
            best_b = b
        closeable = b - start >= min_nodes and n - b >= min_nodes
        if closeable and crossing[b] <= max_cut:
            cuts.append(b)
            start, best_b = b, None
        elif b - start >= max_nodes and best_b is not None \
                and n - best_b >= min_nodes:
            cuts.append(best_b)
            start, best_b = best_b, None
    if not cuts:
        return [RegionSpec(index=0, nodes=tuple(names), boundary=())]

    bounds = [0] + cuts + [n]
    region_of: dict[str, int] = {}
    for r in range(len(bounds) - 1):
        for nm in names[bounds[r]:bounds[r + 1]]:
            region_of[nm] = r
    boundary: list[list[tuple[str, str, str]]] = [
        [] for _ in range(len(bounds) - 1)]
    for s, d, bname in edges:
        rs, rd = region_of[s], region_of[d]
        if rs != rd:
            boundary[rs].append((s, d, bname))
            boundary[rd].append((s, d, bname))
    return [
        RegionSpec(index=r, nodes=tuple(names[bounds[r]:bounds[r + 1]]),
                   boundary=tuple(boundary[r]))
        for r in range(len(bounds) - 1)]
