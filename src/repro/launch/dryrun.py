import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, lower + compile the real
``train_step`` (train shapes) or ``serve_step`` (prefill/decode shapes)
against the production mesh — 16×16 single-pod and 2×16×16 multi-pod —
with every input a ShapeDtypeStruct (zero allocation).  Captures:

* ``compiled.memory_analysis()``  — bytes/device (proves it fits),
* ``compiled.cost_analysis()``    — FLOPs/bytes for §Roofline,
* collective bytes parsed from the post-SPMD HLO,
* HIDA-OPT pass reports + the derived plan.

Artifacts land in ``experiments/dryrun/<arch>__<shape>__<mesh>.json``;
``benchmarks/roofline.py`` renders the §Roofline table from them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k [--multi-pod] [--all] [--strategy hida|naive|...]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import SHAPES, get_config, list_archs, shape_applicable
from ..core import MULTI_POD, SINGLE_POD, build_lm_graph, optimize
from ..core.graph import model_flops_6nd, step_flops
from ..core.plan import replicated_plan
from .hlo_analysis import collective_bytes, hlo_op_histogram
from .mesh import make_production_mesh, mesh_spec, set_mesh
from .steps import build_prefill_step, build_serve_step, build_train_step

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def make_plan(arch: str, shape_name: str, multi_pod: bool,
              strategy: str = "hida", fsdp: bool | None = None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mspec = mesh_spec(multi_pod)
    if fsdp is None:
        # Big configs need ZeRO-3 params/opt sharding to fit 16 GB HBM.
        fsdp = shape.mode == "train"
    if strategy == "naive":
        plan = replicated_plan(mspec, fsdp=fsdp)
        report = None
    else:
        ia = strategy in ("hida", "ia")
        ca = strategy in ("hida", "ca")
        g = build_lm_graph(cfg, shape)
        sched, plan, report = optimize(
            g, mspec, ia=ia, ca=ca, fsdp=fsdp,
            training=shape.mode == "train")
    return cfg, shape, plan, report


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             strategy: str = "hida", save: bool = True,
             remat: str = "full", accum_steps: int = 1) -> dict:
    cfg, shape, plan, report = make_plan(arch, shape_name, multi_pod,
                                         strategy)
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "strategy": strategy, "status": "ok"}
    if not ok:
        result.update(status="skipped", reason=why)
        if save:
            _save(result)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.perf_counter()
    try:
        with set_mesh(mesh):
            if shape.mode == "train":
                step = build_train_step(cfg, shape, mesh, plan,
                                        remat=remat,
                                        accum_steps=accum_steps)
                lowered = step.fn.lower(*step.abstract_inputs)
            elif shape.mode == "prefill":
                fn, abs_in = build_prefill_step(cfg, shape, mesh, plan)
                lowered = fn.lower(*abs_in)
            else:
                step = build_serve_step(cfg, shape, mesh, plan)
                lowered = step.decode.lower(*step.abstract_inputs)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # The layer scan is a while loop; scale loop-resident collectives
        # by its trip count (XLA cost/byte counts see the body once).
        loop_trip = max(r for _, r in cfg.layer_groups())
        coll = collective_bytes(hlo)
        g = build_lm_graph(cfg, shape)
        tokens = shape.global_batch * (1 if shape.mode == "decode"
                                       else shape.seq_len)
        result.update({
            "analytic_flops": step_flops(g, shape.mode),
            "model_flops_6nd": model_flops_6nd(
                cfg, tokens) * (1.0 if shape.mode == "train" else 1 / 3),
            "loop_trip": loop_trip,
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": {
                k: int(getattr(mem, k, 0) or 0)
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")},
            "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed",
                                        "transcendentals",
                                        "optimal_seconds")},
            "collectives": coll.to_dict(loop_trip),
            "hlo_ops": hlo_op_histogram(hlo, top=12),
            "plan_rules": {k: list(v) for k, v in plan.rules.items()},
            "fsdp": plan.fsdp,
        })
        if report is not None:
            result["hida"] = {
                "nodes": report.meta.get("nodes"),
                "estimated_total_s": report.cost.total_s,
                "estimated_critical_s": report.cost.critical_s,
                "estimated_dominant": report.cost.dominant,
                "opt_time_s": round(report.compile_time_s, 2),
            }
    except Exception as e:  # a failure here is a bug in the system
        result.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    if save:
        _save(result)
    return result


def _save(result: dict) -> None:
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    name = (f"{result['arch']}__{result['shape']}__{result['mesh']}"
            + (f"__{result['strategy']}" if result.get("strategy", "hida")
               != "hida" else "") + ".json")
    (ARTIFACT_DIR / name).write_text(json.dumps(result, indent=2))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list_archs())
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch, shape) cell")
    ap.add_argument("--strategy", default="hida",
                    choices=("hida", "naive", "ia", "ca"))
    ap.add_argument("--remat", default="full",
                    choices=("full", "none", "dots"))
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    args = ap.parse_args()

    archs = list_archs() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, multi_pod=mp,
                             strategy=args.strategy, remat=args.remat,
                             accum_steps=args.accum)
                status = r["status"]
                line = (f"{arch:22s} {shape:12s} {r['mesh']:8s} {status}")
                if status == "ok":
                    mem = r["memory_analysis"]
                    per_dev = (mem["argument_size_in_bytes"]
                               + mem["temp_size_in_bytes"])
                    line += (f" args+temp={per_dev/2**30:.2f}GiB/dev"
                             f" flops={r['cost_analysis'].get('flops', 0):.3g}"
                             f" coll={r['collectives']['total_bytes']/2**30:.3f}GiB"
                             f" compile={r['compile_s']:.1f}s")
                elif status == "failed":
                    failures += 1
                    line += f"  {r['error'][:120]}"
                print(line, flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
