"""Step builders shared by the dry-run, trainer, and server.

``build_train_step`` / ``build_serve_step`` assemble the jitted step with
in/out shardings derived entirely from the HIDA ShardingPlan (params via
``param_spec`` + FSDP, batch via logical dims, caches via ``cache_dims``).
``input_specs`` returns ShapeDtypeStruct stand-ins for every input of a
cell — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..core.plan import ShardingPlan
from ..models.lm import LM
from ..optim import AdamW

BF16 = jnp.bfloat16


def _is_dims_leaf(x) -> bool:
    return (isinstance(x, tuple)
            and all(isinstance(i, str) for i in x)) or x == ()


def sharding_tree(dims_tree, mesh: Mesh, plan: ShardingPlan,
                  weight: bool = False, shapes_tree=None):
    """Map a logical-dims pytree to NamedShardings."""
    def one(dims, leaf=None):
        shape = leaf.shape if (leaf is not None and weight) else None
        return plan.named_sharding(mesh, dims, weight=weight, shape=shape)
    if shapes_tree is not None:
        return jax.tree.map(one, dims_tree, shapes_tree,
                            is_leaf=_is_dims_leaf)
    return jax.tree.map(one, dims_tree, is_leaf=_is_dims_leaf)


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, deliverable e step 2)
# --------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> tuple[dict, dict]:
    """(specs, dims) for the data batch of one cell."""
    B = shape.global_batch
    S = 1 if shape.mode == "decode" else shape.seq_len
    specs: dict = {}
    dims: dict = {}
    if cfg.frontend == "audio_frames":
        specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), BF16)
        dims["frames"] = ("batch", "seq", "d_model")
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        dims["tokens"] = ("batch", "seq")
    if cfg.frontend == "vision":
        specs["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, cfg.d_model), BF16)
        dims["img_embeds"] = ("batch", "kv_seq", "d_model")
    if shape.mode == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        dims["labels"] = ("batch", "seq")
    if shape.mode == "decode":
        specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        dims["pos"] = ()
    return specs, dims


def input_specs(cfg: ArchConfig, shape: ShapeSpec, lm: LM | None = None
                ) -> dict:
    """All abstract inputs of the cell: batch (+ params/caches trees)."""
    lm = lm or LM(cfg)
    specs, _ = batch_specs(cfg, shape)
    out = {"batch": specs}
    out["params"], _ = lm.init(None, abstract=True)
    if shape.mode == "decode":
        out["caches"] = lm.init_caches(shape.global_batch, shape.seq_len,
                                       abstract=True)
    return out


# --------------------------------------------------------------------------
# Steps
# --------------------------------------------------------------------------

@dataclass
class TrainStep:
    fn: Callable            # (params, opt_state, batch) -> (params, opt_state, metrics)
    in_shardings: tuple
    out_shardings: Any
    abstract_inputs: tuple  # matching ShapeDtypeStruct trees


def build_train_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                     plan: ShardingPlan, opt: AdamW | None = None,
                     remat: str = "full", use_kernels: bool = False,
                     accum_steps: int = 1) -> TrainStep:
    """``accum_steps > 1`` microbatches the global batch inside the step
    (lax.scan over B/K slices accumulating gradients, one optimizer
    update): live activation set shrinks ~K× at the cost of a
    params-shaped f32 accumulator — the standard memory lever for cells
    whose activations exceed HBM at the full per-step token count."""
    lm = LM(cfg, plan=plan, mesh=mesh, remat=remat,
            use_kernels=use_kernels)
    opt = opt or AdamW(moment_dtype=cfg.opt_moment_dtype)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lm.loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:])
                if x.ndim else jnp.broadcast_to(x, (accum_steps,)),
                batch)

            def body(carry, mb):
                gsum, _ = carry
                (l, m), g = jax.value_and_grad(
                    lm.loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, m), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros(()), "xent": jnp.zeros(()),
                  "aux_lb": jnp.zeros(()), "aux_z": jnp.zeros(())}
            if cfg.mtp:
                m0["mtp"] = jnp.zeros(())
            (gsum, metrics), _ = jax.lax.scan(body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, metrics

    params_abs, dims = lm.init(None, abstract=True)
    opt_abs = opt.init(params_abs)
    bspecs, bdims = batch_specs(cfg, shape)

    p_sh = sharding_tree(dims, mesh, plan, weight=True,
                         shapes_tree=params_abs)
    o_sh = (NamedSharding(mesh, P()),
            jax.tree.map(lambda s: s, p_sh), jax.tree.map(lambda s: s, p_sh))
    o_sh = type(opt_abs)(*o_sh)
    b_sh = sharding_tree(bdims, mesh, plan)
    m_sh = NamedSharding(mesh, P())

    fn = jax.jit(train_step,
                 in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, None),
                 donate_argnums=(0, 1))
    return TrainStep(fn, (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                     (params_abs, opt_abs, bspecs))


@dataclass
class ServeStep:
    prefill: Callable | None
    decode: Callable
    abstract_inputs: tuple   # (params, batch, caches)


def build_serve_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                     plan: ShardingPlan, use_kernels: bool = False
                     ) -> ServeStep:
    lm = LM(cfg, plan=plan, mesh=mesh, remat="none",
            use_kernels=use_kernels)

    params_abs, dims = lm.init(None, abstract=True)
    p_sh = sharding_tree(dims, mesh, plan, weight=True,
                         shapes_tree=params_abs)
    bspecs, bdims = batch_specs(cfg, shape)
    b_sh = sharding_tree(bdims, mesh, plan)

    caches_abs = lm.init_caches(shape.global_batch, shape.seq_len,
                                abstract=True)
    cdims = lm.cache_dims()
    c_sh = sharding_tree(cdims, mesh, plan)

    decode = jax.jit(lm.decode_step,
                     in_shardings=(p_sh, b_sh, c_sh),
                     out_shardings=(None, c_sh),
                     donate_argnums=(2,))
    prefill = None
    if shape.mode == "prefill":
        prefill = jax.jit(lm.prefill, in_shardings=(p_sh, b_sh))
    return ServeStep(prefill, decode, (params_abs, bspecs, caches_abs))


def build_prefill_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                       plan: ShardingPlan, use_kernels: bool = False):
    lm = LM(cfg, plan=plan, mesh=mesh, remat="none",
            use_kernels=use_kernels)
    params_abs, dims = lm.init(None, abstract=True)
    p_sh = sharding_tree(dims, mesh, plan, weight=True,
                         shapes_tree=params_abs)
    bspecs, bdims = batch_specs(cfg, shape)
    b_sh = sharding_tree(bdims, mesh, plan)
    fn = jax.jit(lm.prefill, in_shardings=(p_sh, b_sh))
    return fn, (params_abs, bspecs)
