"""Post-SPMD HLO analysis: collective-byte accounting for §Roofline.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled (post-optimization, per-device) HLO text and sum operand bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.

HLO cost analysis counts a ``while`` body ONCE regardless of trip count —
and the layer scan is a while loop.  We therefore split collective bytes
into *top-level* vs *loop-resident* (computations reachable from any
``while`` body/condition): the caller scales loop-resident bytes by the
known layer-scan trip count.  Bytes are per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{")
_CALL_REF = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations=\{)[=\s]*"
    r"(%[\w\.\-]+(?:\s*,\s*%[\w\.\-]+)*)")
_WHILE_REF = re.compile(r"condition=(%[\w\.\-]+), body=(%[\w\.\-]+)")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)?\s*"
    r"(" + "|".join(COLLECTIVE_OPS) + r")(-start|-done)?[\.\d]*\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)
    loop_bytes_by_kind: dict = field(default_factory=dict)

    @property
    def top_bytes(self) -> int:
        return (sum(self.bytes_by_kind.values())
                - sum(self.loop_bytes_by_kind.values()))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def scaled_total(self, loop_trip: int) -> int:
        """Total per-device bytes with loop-resident collectives scaled by
        the layer-scan trip count."""
        return self.top_bytes + loop_trip * sum(
            self.loop_bytes_by_kind.values())

    def to_dict(self, loop_trip: int = 1) -> dict:
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "loop_bytes_by_kind": self.loop_bytes_by_kind,
                "top_bytes": self.top_bytes,
                "total_bytes": self.total_bytes,
                "loop_trip": loop_trip,
                "scaled_total_bytes": self.scaled_total(loop_trip)}


def _parse_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = "%__toplevel__"
    comps[cur] = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_HDR.match(line)  # headers start at column 0
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        comps[cur].append(stripped)
    return comps


def _loop_reachable(comps: dict[str, list[str]]) -> set[str]:
    """Computations executed under any while (bodies, conditions, and
    everything they call)."""
    calls: dict[str, set[str]] = {c: set() for c in comps}
    roots: set[str] = set()
    for cname, lines in comps.items():
        for line in lines:
            for m in _WHILE_REF.finditer(line):
                roots.add(m.group(1))
                roots.add(m.group(2))
            for m in _CALL_REF.finditer(line):
                for ref in re.findall(r"%[\w\.\-]+", m.group(1)):
                    calls[cname].add(ref)
    seen: set[str] = set()
    stack = [r for r in roots if r in comps]
    while stack:
        c = stack.pop()
        if c in seen:
            continue
        seen.add(c)
        stack.extend(r for r in calls.get(c, ()) if r in comps and
                     r not in seen)
    return seen


def collective_bytes(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    comps = _parse_computations(hlo_text)
    in_loop = _loop_reachable(comps)
    for cname, lines in comps.items():
        loop = cname in in_loop
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            if m.group(3) == "-done":
                continue  # async completion: payload counted at -start
            kind = m.group(2)
            payload = m.group(1) or ""
            nbytes = _shape_bytes(payload)
            if nbytes == 0:
                nbytes = _shape_bytes(line.split("(", 1)[0])
            if kind == "all-gather" and m.group(3) == "-start":
                # (operand, result) tuple: count the gathered result only
                nbytes = nbytes // 2 if nbytes else nbytes
            stats.bytes_by_kind[kind] = (
                stats.bytes_by_kind.get(kind, 0) + nbytes)
            stats.count_by_kind[kind] = (
                stats.count_by_kind.get(kind, 0) + 1)
            if loop:
                stats.loop_bytes_by_kind[kind] = (
                    stats.loop_bytes_by_kind.get(kind, 0) + nbytes)
    return stats


def hlo_op_histogram(hlo_text: str, top: int = 20) -> list[tuple[str, int]]:
    """Opcode frequency — spotting remat-duplicated fusions and reshape
    storms during §Perf iterations."""
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1].strip()
        m = re.match(r"(?:\([^)]*\)\s*|[a-z0-9]+\[[0-9,]*\][^ ]*\s+)?"
                     r"([a-z][a-z0-9-]*)[\.\d]*\(", rhs)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
