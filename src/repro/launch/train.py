"""Fault-tolerant training driver (deliverable b: end-to-end example).

Wires every substrate layer together: HIDA-OPT plan → pjit train step,
deterministic sharded data pipeline, AdamW, async checkpointing with
auto-resume, straggler monitoring, and (optionally) simulated preemption
to exercise the restart path.

On this CPU container run the reduced configs::

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --smoke --steps 50 --batch 8 --seq 64

On a real pod the same driver runs the full config against
``make_production_mesh()`` — nothing in the loop is CPU-specific.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from ..configs import SHAPES, get_config, list_archs
from ..configs.base import ShapeSpec
from ..core import build_lm_graph, optimize
from ..core.estimator import MeshSpec
from ..data import ShardedLoader, SyntheticCorpus
from ..distributed import CheckpointManager, StragglerMonitor
from ..models.lm import LM
from ..optim import AdamW, cosine_schedule
from .mesh import make_host_mesh, set_mesh


def build(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    n_dev = len(jax.devices())
    mesh = make_host_mesh((n_dev, 1))
    mspec = MeshSpec((("data", n_dev), ("model", 1)))

    g = build_lm_graph(cfg, shape)
    sched, plan, report = optimize(g, mspec, fsdp=args.fsdp)
    lm = LM(cfg, plan=plan, remat=args.remat)
    opt = AdamW(lr=args.lr, moment_dtype=cfg.opt_moment_dtype)
    lr_fn = cosine_schedule(1.0, warmup=max(args.steps // 20, 1),
                            total=args.steps)

    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params,
                                       lr_scale=lr_fn(step))
        return params, opt_state, metrics

    return cfg, shape, mesh, plan, lm, opt, jax.jit(
        train_step, donate_argnums=(0, 1))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--simulate-preemption-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, shape, mesh, plan, lm, opt, step_fn = build(args)
    corpus = SyntheticCorpus(cfg.vocab, seed=args.seed)
    loader = ShardedLoader(corpus, args.batch, args.seq)
    ckpt = CheckpointManager(args.ckpt_dir)
    monitor = StragglerMonitor(n_hosts=1)

    params, _ = lm.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)

    start, restored = 0, False
    latest = ckpt.latest_step()
    if latest is not None:
        start = latest
        state = ckpt.restore(latest, {"params": params,
                                      "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        restored = True
        print(f"[train] resumed from step {latest}")

    losses = []
    with set_mesh(mesh):
        for step in range(start, args.steps):
            if step == args.simulate_preemption_at and not restored:
                print(f"[train] simulated preemption at step {step}")
                ckpt.wait()
                return {"preempted_at": step, "losses": losses}
            t0 = time.perf_counter()
            batch = {k: jax.device_put(v)
                     for k, v in loader.batch_at(step).items()}
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, step)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            monitor.step({0: dt})
            if step % 10 == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
    ckpt.wait()
    return {"final_loss": losses[-1] if losses else None,
            "losses": losses, "resumed_from": start}


if __name__ == "__main__":
    out = main()
    print(f"[train] done: {out.get('final_loss')}")
