"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.
"""
from __future__ import annotations

import jax

from ..core.estimator import MULTI_POD, SINGLE_POD, MeshSpec


def set_mesh(mesh):
    """Version-compat mesh context: ``jax.set_mesh`` where it exists
    (sharding-in-types JAX), otherwise the legacy global-mesh context
    manager (``with mesh:``), which is what scopes
    ``with_sharding_constraint(PartitionSpec)`` on older JAX."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_spec(multi_pod: bool = False) -> MeshSpec:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_host_mesh(shape: tuple[int, ...] = None,
                   axes: tuple[str, ...] = ("data", "model")):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return jax.make_mesh(shape, axes)
