"""Continuous-batching request scheduler over ``LM.decode_step``.

The hypergraph runner split (ready set vs. running set) applied to
token serving: a fixed-width **decode batch** of ``slots`` rows steps
every iteration, while a **request queue** feeds free slots through
shape-bucketed prefill *side steps*.  A slot is freed the moment its
request finishes (EOS or ``max_new``) and the next queued request is
admitted into it — the decode batch never drains to wait for stragglers
the way a static batch does, which is where the tok/s win over
lock-step serving comes from on mixed-length traces.

Correctness rests on three model-layer properties (``models/``):

* **per-slot positions** — ``init_caches(vector_pos=True)`` makes every
  cache position a ``(B,)`` vector, so slot ``i`` can sit at position
  417 while slot ``j`` is at 12;
* **active gating** — ``batch["active"]`` makes an inactive slot's
  caches pass through bit-identical to never stepping, so empty slots
  neither advance nor pollute anything;
* **row independence** — with MoE excluded (expert capacity couples
  rows through whole-batch token counts), every slot's computation is
  independent of its neighbours, so the streamed tokens are identical
  to offline per-request decode (:func:`decode_offline`;
  ``tests/test_scheduler.py`` pins this).

Prefill runs per request at batch 1, padded to a power-of-two bucket
(:func:`prefill_bucket`) so at most ``log2`` distinct lengths ever
compile, as a ``lax.scan`` of gated ``decode_step``s — exact for every
architecture including the recurrent mixers, which have no fused
prefill.  The filled cache is scattered into the free slot.

RNG: every request owns an independent stream,
``fold_in(PRNGKey(seed), request_id)``, and every draw inside it is
keyed by position — no key is ever reused across steps or requests
(the serve-driver bug this PR fixes), and the whole trace is
reproducible from ``seed`` alone.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

#: Per-model jit memo: ``jax.jit(lm.decode_step)`` binds a *new*
#: function object every time, so naively jitting in each batcher (or
#: each ``decode_offline`` call) recompiles everything from scratch —
#: the warm-path numbers would be compile benchmarks.  Keyed by model
#: identity with a strong reference held (LM dataclasses are
#: unhashable, and the ref keeps a dead model's id from being reused
#: by a live one); models are few and long-lived per process.
_JIT_MEMO: dict[int, tuple[object, dict]] = {}


def _jit_cache(lm) -> dict:
    ent = _JIT_MEMO.get(id(lm))
    if ent is None or ent[0] is not lm:
        ent = _JIT_MEMO[id(lm)] = (lm, {})
    return ent[1]


def _jitted_step(lm):
    cache = _jit_cache(lm)
    fn = cache.get("step")
    if fn is None:
        fn = cache["step"] = jax.jit(lm.decode_step)
    return fn

__all__ = ["Request", "ServeReport", "ContinuousBatcher", "decode_offline",
           "run_static", "prefill_bucket"]

#: Distinct fold tag for a request's (single) image draw, so it can
#: never collide with a per-position draw.
_IMG_TAG = 0x494D47


def prefill_bucket(length: int, minimum: int = 16) -> int:
    """Smallest power-of-two ≥ ``length`` (floor ``minimum``) — the
    padded prefill length, bounding distinct compiles to log2."""
    b = max(minimum, 1)
    while b < length:
        b *= 2
    return b


@dataclass
class Request:
    """One generation request plus its lifecycle bookkeeping."""
    rid: int
    prompt_len: int
    max_new: int
    #: prompt token ids, shape (prompt_len,); ``None`` for audio-frame
    #: frontends (frames are drawn from the request's RNG stream).
    prompt: np.ndarray | None = None
    temperature: float = 0.0
    #: generated token ids, in order.
    out: list[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    finish: str = ""        # "eos" | "length"

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        """Submit → first generated token."""
        return self.t_first - self.t_submit


@dataclass
class ServeReport:
    requests: list[Request] = field(default_factory=list)
    generated: int = 0
    steps: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    wall_s: float = 0.0
    occupancy: float = 0.0      # mean active-slot fraction per decode step
    slots: int = 0

    @property
    def tok_per_s(self) -> float:
        return self.generated / self.wall_s if self.wall_s else 0.0

    @property
    def decode_tok_per_s(self) -> float:
        return self.generated / self.decode_s if self.decode_s else 0.0

    def latency_percentiles(self) -> dict[str, float]:
        lats = sorted(r.latency_s for r in self.requests)
        if not lats:
            return {"p50": 0.0, "p99": 0.0}
        def pct(p: float) -> float:
            i = min(len(lats) - 1, int(round(p / 100 * (len(lats) - 1))))
            return lats[i]
        return {"p50": pct(50), "p99": pct(99)}

    def to_dict(self) -> dict:
        lat = self.latency_percentiles()
        return {"requests": len(self.requests),
                "generated": self.generated, "steps": self.steps,
                "tok_per_s": self.tok_per_s,
                "decode_tok_per_s": self.decode_tok_per_s,
                "prefill_s": self.prefill_s, "decode_s": self.decode_s,
                "wall_s": self.wall_s, "occupancy": self.occupancy,
                "latency_p50_s": lat["p50"], "latency_p99_s": lat["p99"],
                "slots": self.slots}


def _request_key(seed: int, rid: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), rid)


def _frames_at(key: jax.Array, pos: int, d_model: int) -> jax.Array:
    """The audio frontend's frame at ``pos`` in a request's stream —
    one draw per (request, position), reproducible offline."""
    return jax.random.normal(jax.random.fold_in(key, pos),
                             (1, 1, d_model), jnp.bfloat16)


def _image_of(key: jax.Array, n_img: int, d_model: int) -> jax.Array:
    return jax.random.normal(jax.random.fold_in(key, _IMG_TAG),
                             (1, n_img, d_model), jnp.bfloat16)


def _sample(logits_row: np.ndarray, key: jax.Array, pos: int,
            temperature: float) -> int:
    """Sampling rule shared by the batcher and the offline reference:
    greedy at temperature 0, else categorical keyed by the *input*
    position that produced these logits."""
    if temperature > 0:
        tok = jax.random.categorical(
            jax.random.fold_in(key, pos),
            jnp.asarray(logits_row) / temperature)
        return int(tok)
    return int(np.argmax(np.asarray(logits_row), axis=-1))


class ContinuousBatcher:
    """Admit/evict scheduler around a jitted ``decode_step``.

    Args:
        lm: the model (``repro.models.lm.LM``).
        params: its parameters.
        slots: decode batch width (fixed for the jit).
        s_max: cache capacity per slot; a request needs
            ``prompt_len + max_new <= s_max``.
        seed: root of every RNG stream (see module docstring).
        eos_id: token id that finishes a request early (``None``
            disables EOS detection — length-only termination).
        prefill_min: minimum prefill bucket (power-of-two padding).
    """

    def __init__(self, lm, params, *, slots: int, s_max: int,
                 seed: int = 0, eos_id: int | None = None,
                 prefill_min: int = 16):
        cfg = lm.cfg
        if any(ffn == "moe" for _, ffn in cfg.layer_kinds()):
            raise ValueError(
                "continuous batching requires row-independent compute; "
                f"{cfg.name} has MoE layers whose expert capacity couples "
                "slots through whole-batch token counts (serve MoE "
                "configs with the static path)")
        self.lm, self.params = lm, params
        self.cfg = cfg
        self.slots, self.s_max, self.seed = slots, s_max, seed
        self.eos_id = eos_id
        self.prefill_min = prefill_min

        self.caches = lm.init_caches(slots, s_max, vector_pos=True)
        self._step = _jitted_step(lm)

        self.queue: deque[Request] = deque()
        self._next_rid = 0
        self.pos = np.zeros(slots, np.int32)
        self.active = np.zeros(slots, bool)
        self.tokens = np.zeros((slots, 1), np.int32)
        self.slot_req: list[Request | None] = [None] * slots
        self._slot_key: list[jax.Array | None] = [None] * slots
        self._slot_img = (np.zeros(
            (slots, cfg.n_img_tokens, cfg.d_model), np.float32)
            if cfg.frontend == "vision" else None)

    # -- submission ------------------------------------------------------
    def submit(self, prompt: np.ndarray | None, max_new: int, *,
               prompt_len: int | None = None,
               temperature: float = 0.0) -> Request:
        if prompt is not None:
            prompt = np.asarray(prompt, np.int32).reshape(-1)
            prompt_len = len(prompt)
        assert prompt_len is not None and prompt_len >= 1
        if prompt_len + max_new > self.s_max:
            raise ValueError(f"request needs {prompt_len + max_new} "
                             f"positions, cache holds {self.s_max}")
        req = Request(rid=self._next_rid, prompt_len=prompt_len,
                      max_new=max_new, prompt=prompt,
                      temperature=temperature,
                      t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req

    # -- prefill side step -----------------------------------------------
    def _prefill_fn(self, bucket: int, k: int):
        """One jitted executable per (bucket, group-width) doing the
        whole admit-side device work in a *single* dispatch: scan the
        gated prompt steps for ``k`` same-bucket requests at once over
        a zero batch-``k`` cache, scatter each filled row into its
        target slot of the batch cache, and gather each request's
        last-prompt-step logits.  Batch-1 python prefill + per-leaf
        install was ~15 ms of dispatch per admit — more than the decode
        steps it was feeding — and burst admits (server start, a wave
        finishing together) prefill ``k`` requests for the price of
        one scan."""
        cache = _jit_cache(self.lm)
        fn = cache.get(("prefill", bucket, k))
        if fn is not None:
            return fn
        cfg, lm = self.cfg, self.lm
        groups = lm._groups()

        def prefill(params, xs, lengths, big, slot_vec, small, img):
            def body(caches, x):
                t, inp = x
                batch = {"pos": jnp.full((k,), t, jnp.int32),
                         "active": t < lengths}
                if cfg.frontend == "audio_frames":
                    batch["frames"] = inp
                else:
                    batch["tokens"] = inp
                if img is not None:
                    batch["img_embeds"] = img
                logits, caches = lm.decode_step(params, batch, caches)
                return caches, logits[:, -1]

            small, logits = jax.lax.scan(
                body, small, (jnp.arange(bucket), xs))
            # install: batch axis of every leaf is 0, except inside
            # stacked (scanned) layer groups where axis 0 is layers.
            out = {}
            for gi, (_pattern, repeats) in enumerate(groups):
                ax = 1 if repeats > 1 else 0
                g = f"group{gi}"

                def ins(b, s, ax=ax):
                    if ax == 0:
                        return b.at[slot_vec].set(s)
                    return b.at[:, slot_vec].set(s)

                out[g] = jax.tree.map(ins, big[g], small[g])
            # logits: (bucket, k, vocab) → each request's row at its
            # own last prompt position.
            last = jnp.take_along_axis(
                logits, (lengths - 1)[None, :, None], axis=0)[0]
            return out, last                               # (k, vocab)

        fn = cache[("prefill", bucket, k)] = jax.jit(prefill)
        return fn

    def _zero_cache(self, k: int):
        """Immutable zero batch-``k`` cache template, built once per
        width (jax arrays are functional — no admit can corrupt it)."""
        cache = _jit_cache(self.lm)
        z = cache.get(("zeros", k, self.s_max))
        if z is None:
            z = cache[("zeros", k, self.s_max)] = self.lm.init_caches(
                k, self.s_max, vector_pos=True)
        return z

    def _admit_group(self, pairs: list[tuple[int, Request]],
                     bucket: int) -> None:
        """Prefill + install one same-bucket group of requests into
        their slots (a single device dispatch), then sample each
        request's first token."""
        cfg = self.cfg
        k = len(pairs)
        now = time.perf_counter()
        keys = []
        lengths = np.zeros(k, np.int32)
        slot_vec = np.zeros(k, np.int32)
        for i, (slot, req) in enumerate(pairs):
            req.t_admit = now
            keys.append(_request_key(self.seed, req.rid))
            lengths[i] = req.prompt_len
            slot_vec[i] = slot
        if cfg.frontend == "audio_frames":
            cols = []
            for i, (_slot, req) in enumerate(pairs):
                pad = jnp.zeros((bucket - req.prompt_len, 1, cfg.d_model),
                                jnp.bfloat16)
                cols.append(jnp.concatenate(
                    [_frames_at(keys[i], t, cfg.d_model)
                     for t in range(req.prompt_len)] + [pad]))
            xs = jnp.stack(cols, axis=1)   # (bucket, k, 1, d_model)
        else:
            toks = np.zeros((bucket, k, 1), np.int32)
            for i, (_slot, req) in enumerate(pairs):
                toks[:req.prompt_len, i, 0] = req.prompt
            xs = jnp.asarray(toks)
        img = (jnp.concatenate(
            [_image_of(kk, cfg.n_img_tokens, cfg.d_model) for kk in keys])
            if cfg.frontend == "vision" else None)
        fn = self._prefill_fn(bucket, k)
        self.caches, last = fn(
            self.params, xs, jnp.asarray(lengths), self.caches,
            jnp.asarray(slot_vec), self._zero_cache(k), img)
        last_np = np.asarray(last)
        t_first = time.perf_counter()
        for i, (slot, req) in enumerate(pairs):
            tok = _sample(last_np[i], keys[i], req.prompt_len - 1,
                          req.temperature)
            req.out.append(tok)
            req.t_first = t_first
            self.pos[slot] = req.prompt_len
            self.active[slot] = True
            self.tokens[slot, 0] = tok
            self.slot_req[slot] = req
            self._slot_key[slot] = keys[i]
            if self._slot_img is not None:
                self._slot_img[slot] = np.asarray(img[i], np.float32)
            self._maybe_finish(slot, tok)

    def _evict(self, slot: int, finish: str) -> None:
        req = self.slot_req[slot]
        req.finish = finish
        req.t_done = time.perf_counter()
        self.active[slot] = False
        self.slot_req[slot] = None
        self._slot_key[slot] = None

    def _maybe_finish(self, slot: int, tok: int) -> bool:
        req = self.slot_req[slot]
        if self.eos_id is not None and tok == self.eos_id:
            self._evict(slot, "eos")
            return True
        if len(req.out) >= req.max_new:
            self._evict(slot, "length")
            return True
        return False

    # -- main loop -------------------------------------------------------
    def _decode_batch(self) -> dict:
        cfg = self.cfg
        batch = {"pos": jnp.asarray(self.pos),
                 "active": jnp.asarray(self.active)}
        if cfg.frontend == "audio_frames":
            rows = [(_frames_at(self._slot_key[i], int(self.pos[i]),
                                cfg.d_model)[0]
                     if self.active[i]
                     else jnp.zeros((1, cfg.d_model), jnp.bfloat16))
                    for i in range(self.slots)]
            batch["frames"] = jnp.stack(rows)
        else:
            batch["tokens"] = jnp.asarray(self.tokens)
        if cfg.frontend == "vision":
            batch["img_embeds"] = jnp.asarray(self._slot_img, jnp.bfloat16)
        return batch

    def run(self, max_steps: int | None = None) -> ServeReport:
        """Drain the queue: admit → step → sample/evict until every
        submitted request has finished.  Returns the serving report;
        per-request tokens live on the :class:`Request` objects."""
        rep = ServeReport(slots=self.slots)
        occ_sum = 0.0
        t_start = time.perf_counter()
        budget = max_steps if max_steps is not None else (
            sum(r.max_new for r in self.queue) + len(self.queue) + 64)
        while self.queue or self.active.any():
            # admit: fill the free slots from the queue, grouped by
            # prefill bucket so each group is one batched side step.
            if self.queue:
                t0 = time.perf_counter()
                groups: dict[int, list[tuple[int, Request]]] = {}
                for slot in range(self.slots):
                    if not self.queue:
                        break
                    if not self.active[slot]:
                        req = self.queue.popleft()
                        b = prefill_bucket(req.prompt_len,
                                           self.prefill_min)
                        groups.setdefault(b, []).append((slot, req))
                        rep.requests.append(req)
                for b, pairs in sorted(groups.items()):
                    self._admit_group(pairs, b)
                if groups:
                    rep.prefill_s += time.perf_counter() - t0
            if not self.active.any():
                continue    # every admitted request finished at token 0
            # one decode step over the whole batch
            t0 = time.perf_counter()
            batch = self._decode_batch()
            logits, self.caches = self._step(self.params, batch,
                                             self.caches)
            logits_np = np.asarray(logits[:, -1])
            rep.decode_s += time.perf_counter() - t0
            rep.steps += 1
            occ_sum += float(self.active.sum()) / self.slots
            for slot in range(self.slots):
                if not self.active[slot]:
                    continue
                req = self.slot_req[slot]
                tok = _sample(logits_np[slot], self._slot_key[slot],
                              int(self.pos[slot]), req.temperature)
                req.out.append(tok)
                self.pos[slot] += 1
                self.tokens[slot, 0] = tok
                self._maybe_finish(slot, tok)
            if rep.steps >= budget:
                for slot in range(self.slots):
                    if self.active[slot]:
                        self._evict(slot, "budget")
                break
        rep.wall_s = time.perf_counter() - t_start
        rep.generated = sum(len(r.out) for r in rep.requests)
        rep.occupancy = occ_sum / rep.steps if rep.steps else 0.0
        return rep


# -- references ----------------------------------------------------------

def decode_offline(lm, params, req: Request, *, seed: int, s_max: int,
                   eos_id: int | None = None) -> list[int]:
    """Single-request lock-step decode — the scheduler's oracle.

    Deliberately a *different* code path from the batcher: scalar cache
    positions (``dynamic_update_slice`` writes instead of per-slot
    scatter), no padding, no gating, batch 1 throughout.  Row
    independence says the streamed tokens must match exactly;
    ``tests/test_scheduler.py`` asserts it."""
    cfg = lm.cfg
    key = _request_key(seed, req.rid)
    caches = lm.init_caches(1, s_max)
    step = _jitted_step(lm)
    img = (_image_of(key, cfg.n_img_tokens, cfg.d_model)
           if cfg.frontend == "vision" else None)

    def batch_at(t: int, tok: int | None) -> dict:
        batch = {"pos": jnp.asarray(t, jnp.int32)}
        if cfg.frontend == "audio_frames":
            batch["frames"] = _frames_at(key, t, cfg.d_model)
        elif tok is None:
            batch["tokens"] = jnp.asarray(req.prompt[t],
                                          jnp.int32).reshape(1, 1)
        else:
            batch["tokens"] = jnp.asarray(tok, jnp.int32).reshape(1, 1)
        if img is not None:
            batch["img_embeds"] = img
        return batch

    logits = None
    for t in range(req.prompt_len):
        logits, caches = step(params, batch_at(t, None), caches)
    out: list[int] = []
    tok = _sample(np.asarray(logits[0, -1]), key, req.prompt_len - 1,
                  req.temperature)
    out.append(tok)
    t = req.prompt_len
    while len(out) < req.max_new and not (eos_id is not None
                                          and tok == eos_id):
        logits, caches = step(params, batch_at(t, tok), caches)
        tok = _sample(np.asarray(logits[0, -1]), key, t, req.temperature)
        out.append(tok)
        t += 1
    return out


def run_static(lm, params, requests: list[Request], *, seed: int,
               s_max: int, slots: int | None = None,
               eos_id: int | None = None) -> ServeReport:
    """The pre-PR lock-step baseline at the same hardware batch width:
    requests are grouped into waves of ``slots`` rows in submission
    order, each wave's prompts padded to its longest, and every row of
    a wave decodes until the wave's largest ``max_new`` — finished and
    short-prompt rows keep burning full steps, and no new request can
    start until the whole wave drains.  The report counts only useful
    tokens (each request's own ``max_new``), which is exactly why this
    loses to continuous batching on mixed-length traces."""
    cfg = lm.cfg
    slots = slots or len(requests)
    rep = ServeReport(slots=slots)
    if not requests:
        return rep
    step = _jitted_step(lm)
    t_start = time.perf_counter()
    for w0 in range(0, len(requests), slots):
        wave = requests[w0:w0 + slots]
        B = len(wave)
        l_max = max(r.prompt_len for r in wave)
        g_max = max(r.max_new for r in wave)
        keys = [_request_key(seed, r.rid) for r in wave]
        prompts = np.zeros((B, l_max), np.int32)
        for i, r in enumerate(wave):
            if r.prompt is not None:
                prompts[i, :r.prompt_len] = r.prompt
        imgs = (jnp.concatenate(
            [_image_of(k, cfg.n_img_tokens, cfg.d_model) for k in keys])
            if cfg.frontend == "vision" else None)

        def batch_at(t: int, toks: np.ndarray | None) -> dict:
            batch = {"pos": jnp.asarray(t, jnp.int32)}
            if cfg.frontend == "audio_frames":
                batch["frames"] = jnp.concatenate(
                    [_frames_at(k, t, cfg.d_model) for k in keys])
            elif toks is None:
                batch["tokens"] = jnp.asarray(prompts[:, t:t + 1])
            else:
                batch["tokens"] = jnp.asarray(toks)
            if imgs is not None:
                batch["img_embeds"] = imgs
            return batch

        caches = lm.init_caches(B, s_max)
        t_wave = time.perf_counter()
        logits = None
        for t in range(l_max):
            logits, caches = step(params, batch_at(t, None), caches)
        rep.prefill_s += time.perf_counter() - t_wave
        t0 = time.perf_counter()
        logits_np = np.asarray(logits[:, -1])
        toks = np.zeros((B, 1), np.int32)
        done = [False] * B
        for i, r in enumerate(wave):
            tok = _sample(logits_np[i], keys[i], l_max - 1,
                          r.temperature)
            r.out = [tok]
            toks[i, 0] = tok
            done[i] = eos_id is not None and tok == eos_id
        for g in range(1, g_max):
            logits, caches = step(params, batch_at(l_max + g - 1, toks),
                                  caches)
            logits_np = np.asarray(logits[:, -1])
            rep.steps += 1
            for i, r in enumerate(wave):
                tok = _sample(logits_np[i], keys[i], l_max + g - 1,
                              r.temperature)
                if not done[i] and len(r.out) < r.max_new:
                    r.out.append(tok)
                    done[i] = eos_id is not None and tok == eos_id
                toks[i, 0] = tok
        rep.decode_s += time.perf_counter() - t0
        for r in wave:
            r.t_first = r.t_first or time.perf_counter()
            r.t_done = time.perf_counter()   # wave finishes together
            r.finish = "length"
            rep.requests.append(r)
        rep.occupancy += sum(r.max_new for r in wave)
    rep.wall_s = time.perf_counter() - t_start
    rep.generated = sum(len(r.out) for r in rep.requests)
    rep.occupancy = (rep.occupancy
                     / max(1, (rep.steps + 1) * slots))
    return rep
