"""Batched serving driver: prefill a prompt batch, then decode with the
KV / SSM / xLSTM caches (deliverable b).

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, list_archs
from ..models.lm import LM


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    lm = LM(cfg, remat="none")
    rng = jax.random.PRNGKey(args.seed)
    params, _ = lm.init(rng)

    B = args.batch
    S_max = args.prompt_len + args.gen
    prompts = jax.random.randint(rng, (B, args.prompt_len), 0, cfg.vocab)

    # Prefill: replay the prompt through decode_step to fill caches (an
    # incremental server; the fused full-sequence prefill path is
    # exercised by the prefill_32k dry-run cells).
    caches = lm.init_caches(B, S_max)
    step = jax.jit(lm.decode_step)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        batch = {"pos": jnp.asarray(t, jnp.int32)}
        if cfg.frontend == "audio_frames":
            batch["frames"] = jax.random.normal(
                rng, (B, 1, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = prompts[:, t:t + 1]
        if cfg.frontend == "vision":
            batch["img_embeds"] = jax.random.normal(
                rng, (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        logits, caches = step(params, batch, caches)
    prefill_s = time.perf_counter() - t0

    out_tokens = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    for t in range(args.prompt_len, args.prompt_len + args.gen):
        batch = {"pos": jnp.asarray(t, jnp.int32)}
        if cfg.frontend == "audio_frames":
            batch["frames"] = jax.random.normal(
                rng, (B, 1, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = tok
        if cfg.frontend == "vision":
            batch["img_embeds"] = jax.random.normal(
                rng, (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        logits, caches = step(params, batch, caches)
        if args.temperature > 0:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(np.asarray(tok[:, 0]))
    decode_s = time.perf_counter() - t0
    toks = args.gen * B
    print(f"[serve] {args.arch}: prefill {args.prompt_len} toks in "
          f"{prefill_s:.2f}s; decoded {toks} tokens in {decode_s:.2f}s "
          f"({toks/decode_s:.1f} tok/s)")
    return {"tok_per_s": toks / decode_s,
            "tokens": np.stack(out_tokens, 1)}


if __name__ == "__main__":
    main()
