"""Production serving driver: continuous batching over ``decode_step``
with a persistent warm-start plan cache.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --slots 4 --requests 12 --gen-range 16 64 --static

Pipeline per invocation:

1. **Plan fetch** (:func:`fetch_plan`): the serving shape is quantized
   onto a bucket (:func:`repro.core.shape_bucket`) and looked up in the
   persistent :class:`repro.core.PlanCache` — a hit is a sub-ms fetch
   (statically re-verified against the mesh), a miss runs the DSE,
   warm-started from the nearest cached donor when one exists.  The
   cache root comes from ``--plan-cache`` or ``$REPRO_PLAN_CACHE``;
   without either the DSE still runs but nothing persists.
2. **Continuous batching** (:class:`repro.launch.scheduler
   .ContinuousBatcher`): a request queue drained through a fixed-width
   decode batch with per-step admit/evict and shape-bucketed batched
   prefill.  ``--static`` additionally runs the lock-step wave baseline
   (:func:`repro.launch.scheduler.run_static`) for comparison.

RNG hygiene: the seed splits once into independent init / trace
streams, and every request gets its own fold_in-derived sampling stream
keyed by decode position (see ``scheduler._request_key``) — no key is
ever reused across draws, and a request's tokens do not depend on what
shares the batch with it.  MoE configs are served on the static path
(expert capacity couples batch rows; the batcher refuses them).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from ..configs import get_config, list_archs
from ..configs.base import ShapeSpec
from ..core import (SINGLE_POD, MeshSpec, PlanCache, PlanKey, analyze_plan,
                    build_lm_graph, fetch_or_optimize, shape_bucket)
from ..models.lm import LM
from .scheduler import ContinuousBatcher, Request, prefill_bucket, run_static


def fetch_plan(cfg, *, slots: int, s_max: int,
               cache_root: str | os.PathLike | None,
               mesh: MeshSpec = SINGLE_POD,
               cache: PlanCache | None = None,
               optimize_kwargs: dict | None = None):
    """Serving-side compile: cache hit → warm re-DSE → cold DSE.

    Returns ``(plan, info)`` where ``info`` has the fetch ``source``
    (``hit``/``warm``/``cold``), wall ``fetch_ms``, the bucket, and the
    :class:`OptimizeReport` when a DSE ran."""
    cache = cache if cache is not None else PlanCache(cache_root)
    bucket = shape_bucket("decode", s_max, slots)
    key = PlanKey.make(cfg, mesh, bucket)
    shape = ShapeSpec(bucket, s_max, slots, "decode")
    t0 = time.perf_counter()
    plan, source, report = fetch_or_optimize(
        cache, key, mesh, lambda: build_lm_graph(cfg, shape),
        optimize_kwargs=optimize_kwargs)
    return plan, {"source": source, "fetch_ms": (time.perf_counter() - t0)
                  * 1e3, "bucket": bucket, "report": report,
                  "cache_stats": dict(cache.stats)}


def make_trace(cfg, n_requests: int, *, seed: int,
               prompt_len_range=(4, 48), gen_range=(16, 64),
               temperature: float = 0.0) -> list[dict]:
    """Deterministic mixed-length request trace.  A dedicated numpy
    stream (independent of model init and sampling keys) draws the
    shapes and prompt tokens."""
    rng = np.random.default_rng(seed)
    lo, hi = prompt_len_range
    glo, ghi = gen_range
    out = []
    for _ in range(n_requests):
        pl = int(rng.integers(lo, hi + 1))
        gen = int(rng.integers(glo, ghi + 1))
        prompt = (None if cfg.frontend == "audio_frames"
                  else rng.integers(0, cfg.vocab, pl).astype(np.int32))
        out.append({"prompt": prompt, "prompt_len": pl, "max_new": gen,
                    "temperature": temperature})
    return out


def _static_requests(trace: list[dict]) -> list[Request]:
    now = time.perf_counter()
    return [Request(rid=i, prompt_len=t["prompt_len"],
                    max_new=t["max_new"], prompt=t["prompt"],
                    temperature=t["temperature"], t_submit=now)
            for i, t in enumerate(trace)]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch width (concurrent requests)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len-range", type=int, nargs=2,
                    default=(4, 48), metavar=("LO", "HI"))
    ap.add_argument("--gen-range", type=int, nargs=2, default=(16, 64),
                    metavar=("LO", "HI"))
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--static", action="store_true",
                    help="also run the lock-step wave baseline")
    ap.add_argument("--warmup", type=int, default=0,
                    help="un-timed passes over the trace first, so the "
                    "reported numbers are steady-state (compile-free) — "
                    "what a long-lived endpoint actually serves at")
    ap.add_argument("--plan-cache", default=os.environ.get(
        "REPRO_PLAN_CACHE"), help="plan cache root dir "
        "(default: $REPRO_PLAN_CACHE; unset = no persistence)")
    ap.add_argument("--no-plan", action="store_true",
                    help="skip the DSE/plan fetch entirely")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    pl_lo, pl_hi = args.prompt_len_range
    g_lo, g_hi = args.gen_range
    s_max = prefill_bucket(pl_hi, 16) + g_hi

    plan, plan_info = (None, {"source": "skipped", "fetch_ms": 0.0}) \
        if args.no_plan else fetch_plan(
            cfg, slots=args.slots, s_max=s_max,
            cache_root=args.plan_cache)
    if plan_info["source"] != "skipped":
        print(f"[serve] plan: {plan_info['source']} in "
              f"{plan_info['fetch_ms']:.1f} ms "
              f"(bucket {plan_info['bucket']})")
    if plan is not None:
        # Pre-flight hazard lint: a DSE'd plan already carries the full
        # exit analysis (report.analyze), but cache hits skip the DSE —
        # re-lint the plan-scoped rules here so no serving path starts
        # on a hazardous plan unannounced.  Informational, not fatal:
        # the endpoint owner decides (the --strict lane is
        # ``python -m repro.lint``).
        lint = analyze_plan(plan, SINGLE_POD)
        plan_info["lint"] = {"ok": lint.ok,
                             "issues": [str(i) for i in lint.issues]}
        print(f"[serve] lint: {lint.summary()}")

    # RNG hygiene: one split at the top — params init and the request
    # trace never share a key, and sampling streams are derived
    # per-request inside the scheduler.
    k_init, _k_reserved = jax.random.split(jax.random.PRNGKey(args.seed))
    lm = LM(cfg, plan=plan, remat="none")
    params, _ = lm.init(k_init)
    trace = make_trace(cfg, args.requests, seed=args.seed,
                       prompt_len_range=(pl_lo, pl_hi),
                       gen_range=(g_lo, g_hi),
                       temperature=args.temperature)

    is_moe = any(ffn == "moe" for _, ffn in cfg.layer_kinds())
    metrics: dict = {"arch": args.arch, "plan": {
        k: v for k, v in plan_info.items() if k != "report"}}
    if is_moe:
        print(f"[serve] {args.arch} has MoE layers — static path only "
              "(expert capacity couples batch rows)")
    else:
        def run_once():
            b = ContinuousBatcher(lm, params, slots=args.slots,
                                  s_max=s_max, seed=args.seed,
                                  eos_id=args.eos_id)
            for t in trace:
                b.submit(t["prompt"], t["max_new"],
                         prompt_len=t["prompt_len"],
                         temperature=t["temperature"])
            return b.run()

        for _ in range(args.warmup):
            run_once()
        rep = run_once()
        metrics["continuous"] = rep.to_dict()
        print(f"[serve] continuous: {rep.generated} tokens / "
              f"{len(rep.requests)} requests in {rep.wall_s:.2f}s "
              f"({rep.to_dict()['tok_per_s']:.0f} tok/s, occupancy "
              f"{rep.occupancy:.2f}, p50 "
              f"{rep.to_dict()['latency_p50_s'] * 1e3:.0f} ms, p99 "
              f"{rep.to_dict()['latency_p99_s'] * 1e3:.0f} ms)")

    if args.static or is_moe:
        for _ in range(args.warmup):
            run_static(lm, params, _static_requests(trace),
                       seed=args.seed, s_max=s_max, slots=args.slots,
                       eos_id=args.eos_id)
        srep = run_static(lm, params, _static_requests(trace),
                          seed=args.seed, s_max=s_max, slots=args.slots,
                          eos_id=args.eos_id)
        metrics["static"] = srep.to_dict()
        print(f"[serve] static:     {srep.generated} tokens / "
              f"{len(srep.requests)} requests in {srep.wall_s:.2f}s "
              f"({srep.to_dict()['tok_per_s']:.0f} tok/s, occupancy "
              f"{srep.occupancy:.2f})")
        if "continuous" in metrics:
            ratio = (metrics["continuous"]["tok_per_s"]
                     / max(metrics["static"]["tok_per_s"], 1e-9))
            metrics["continuous_vs_static"] = ratio
            print(f"[serve] continuous/static throughput: {ratio:.2f}x")
    return metrics


if __name__ == "__main__":
    main()
