"""CI hazard lint: compile a config and report static dataflow hazards.

    PYTHONPATH=src python -m repro.lint smollm-135m
    PYTHONPATH=src python -m repro.lint all --shape train_4k
    PYTHONPATH=src python -m repro.lint synth_1k --json

Each target runs the full ``optimize()`` pipeline (smoke-sized model
configs by default, so the sweep is CI-cheap) and reports the exit
hazard analysis (:mod:`repro.core.analyze`) alongside the legality
verdict (:mod:`repro.core.verify`) and any degradation-ladder rungs
that fired.  Exit status is nonzero when any target has hazard
*errors*, verifier errors, or — under ``--strict`` — warnings or
degradations, so the command gates in CI exactly like a compiler
``-Werror`` lane.  The ``lint`` suite in ``benchmarks/run.py`` drives
this over every config.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from .configs import get_config, list_archs
from .configs.base import SHAPES
from .core import SINGLE_POD, build_lm_graph, optimize
from .core.generate import list_synths

__all__ = ["lint_one", "main"]


def lint_one(name: str, *, shape: str = "train_4k",
             smoke: bool = True) -> dict:
    """Compile one target (arch or synth name) and collect its lint
    verdict.  Returns a JSON-friendly dict; never raises for hazards
    (that is the caller's exit-code decision)."""
    if name in list_synths():
        from .core.generate import get_synth
        graph = get_synth(name)
    else:
        graph = build_lm_graph(get_config(name, smoke=smoke),
                               SHAPES[shape])
    t0 = time.perf_counter()
    sched, plan, rep = optimize(graph, SINGLE_POD)
    wall_s = time.perf_counter() - t0
    arep, vrep = rep.analyze, rep.verify
    return {
        "target": name,
        "ok": bool(arep is not None and arep.ok
                   and vrep is not None and vrep.ok),
        "errors": [str(i) for i in (arep.errors() if arep else [])],
        "warnings": [str(i) for i in (arep.warnings() if arep else [])],
        "verify_errors": [str(i) for i in (vrep.errors() if vrep else [])],
        "degradations": [str(d) for d in rep.degradations],
        "checks": arep.checks if arep else 0,
        "rules_run": list(arep.rules_run) if arep else [],
        "analyze_s": rep.analyze_s,
        "wall_s": wall_s,
        "nodes": len(sched.nodes),
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="static dataflow hazard lint (deadlock / FIFO depth "
                    "/ shard races / ordering / index invariants)")
    ap.add_argument("targets", nargs="*", default=["all"],
                    help="arch names, synth names, or 'all' "
                         f"(archs: {', '.join(list_archs())}; "
                         f"synths: {', '.join(list_synths())})")
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES),
                    help="shape for model configs (default train_4k)")
    ap.add_argument("--full", action="store_true",
                    help="full-size configs instead of smoke-sized")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit one JSON object per line instead of text")
    ap.add_argument("--strict", action="store_true",
                    help="warnings and degradations also fail the lint")
    args = ap.parse_args(argv)

    targets = list(args.targets) or ["all"]
    if "all" in targets:
        targets = list_archs() + [t for t in targets if t != "all"
                                  and t not in list_archs()]
    failed = 0
    for name in targets:
        res = lint_one(name, shape=args.shape, smoke=not args.full)
        bad = (not res["ok"]) or (args.strict and (
            res["warnings"] or res["degradations"]))
        failed += bad
        if args.as_json:
            print(json.dumps(res, sort_keys=True))
            continue
        verdict = "FAIL" if bad else "ok"
        print(f"[lint] {name}: {verdict} — {res['checks']} checks, "
              f"{len(res['rules_run'])} rules, "
              f"analyze {res['analyze_s'] * 1e3:.2f} ms, "
              f"compile {res['wall_s']:.2f} s, {res['nodes']} nodes")
        for line in res["errors"]:
            print(f"[lint]   hazard  {line}")
        for line in res["verify_errors"]:
            print(f"[lint]   verify  {line}")
        for line in res["warnings"]:
            print(f"[lint]   warn    {line}")
        for line in res["degradations"]:
            print(f"[lint]   degrade {line}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
