"""Shared layers: norms, SwiGLU MLP, rotary embeddings, parameter builder.

Everything is functional JAX (params as pytrees).  ``ParamBuilder``
records the logical dims + HIDA buffer site of every parameter so the
launcher can derive ``NamedSharding``s for the whole tree from the
ShardingPlan without hand-written PartitionSpecs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

BF16 = jnp.bfloat16
F32 = jnp.float32


# --------------------------------------------------------------------------
# Parameter builder (records logical dims for plan-driven sharding)
# --------------------------------------------------------------------------

@dataclass
class ParamBuilder:
    rng: jax.Array | None
    params: dict = field(default_factory=dict)
    dims: dict = field(default_factory=dict)
    #: abstract mode: record ShapeDtypeStructs only (dry-run; no HBM)
    abstract: bool = False

    def _split(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def weight(self, path: str, shape: Sequence[int], dims: Sequence[str],
               dtype=BF16, scale: float | None = None,
               stack: int | None = None) -> None:
        """Register a weight; ``stack`` prepends a layer-stack axis for
        scanned groups (dims gets a leading "layers")."""
        shape = tuple(shape)
        fan_in = shape[0] if shape else 1
        std = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        full = (stack,) + shape if stack else shape
        full_dims = (("layers",) + tuple(dims)) if stack else tuple(dims)
        if self.abstract:
            leaf = jax.ShapeDtypeStruct(full, dtype)
        else:
            leaf = (jax.random.normal(self._split(), full, F32) * std
                    ).astype(dtype)
        _set(self.params, path, leaf)
        _set(self.dims, path, full_dims)

    def _const(self, fn, path, shape, dims, dtype, stack):
        full = ((stack,) + tuple(shape)) if stack else tuple(shape)
        full_dims = (("layers",) + tuple(dims)) if stack else tuple(dims)
        leaf = (jax.ShapeDtypeStruct(full, dtype) if self.abstract
                else fn(full, dtype))
        _set(self.params, path, leaf)
        _set(self.dims, path, full_dims)

    def ones(self, path: str, shape: Sequence[int], dims: Sequence[str],
             dtype=F32, stack: int | None = None) -> None:
        self._const(jnp.ones, path, shape, dims, dtype, stack)

    def zeros(self, path: str, shape: Sequence[int], dims: Sequence[str],
              dtype=F32, stack: int | None = None) -> None:
        self._const(jnp.zeros, path, shape, dims, dtype, stack)


def _set(tree: dict, path: str, leaf: Any) -> None:
    keys = path.split("/")
    for k in keys[:-1]:
        tree = tree.setdefault(k, {})
    tree[keys[-1]] = leaf


def tree_get(tree: dict, path: str) -> Any:
    for k in path.split("/"):
        tree = tree[k]
    return tree


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale.astype(F32)
    return y.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(F32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(F32) + bias.astype(F32)
    return y.astype(dtype)


def apply_norm(kind: str, x: jax.Array, p: dict) -> jax.Array:
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(pb: ParamBuilder, path: str, kind: str, d: int,
              stack: int | None = None) -> None:
    pb.ones(f"{path}/scale", (d,), ("d_model",), stack=stack)
    if kind != "rms":
        pb.zeros(f"{path}/bias", (d,), ("d_model",), stack=stack)


# --------------------------------------------------------------------------
# Rotary position embeddings (with partial-rotary support)
# --------------------------------------------------------------------------

def rope_angles(positions: jax.Array, rot_dim: int,
                base: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """positions (..., S) → cos/sin (..., S, rot_dim//2)."""
    inv = 1.0 / (base ** (np.arange(0, rot_dim, 2) / rot_dim))
    ang = positions[..., None].astype(F32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               rot_dim: int) -> jax.Array:
    """x (B,S,H,Dh); rotate the first ``rot_dim`` features (partial RoPE),
    pass the rest through (StableLM-style 25% rotary supported)."""
    rot, rest = x[..., :rot_dim], x[..., rot_dim:]
    r1, r2 = rot[..., 0::2], rot[..., 1::2]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    o1 = r1 * cos - r2 * sin
    o2 = r2 * cos + r1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(rot.shape)
    return jnp.concatenate([out.astype(x.dtype), rest], axis=-1)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(pb: ParamBuilder, path: str, d: int, d_ff: int,
             stack: int | None = None) -> None:
    pb.weight(f"{path}/w_in", (d, 2, d_ff), ("d_model", "two", "d_ff"),
              stack=stack)
    pb.weight(f"{path}/w_out", (d_ff, d), ("d_ff", "d_model"), stack=stack)


def mlp(x: jax.Array, p: dict, constrain=lambda t, d, s=None: t
        ) -> jax.Array:
    h = jnp.einsum("bsd,dgf->bsgf", x, p["w_in"])
    h = constrain(h, ("batch", "seq", None, "d_ff"), "ffn_hidden")
    gate, up = h[..., 0, :], h[..., 1, :]
    act = jax.nn.silu(gate.astype(F32)).astype(x.dtype) * up
    out = jnp.einsum("bsf,fd->bsd", act, p["w_out"])
    return out


# --------------------------------------------------------------------------
# Loss (vocab-sharding friendly: stable logsumexp, no host gather)
# --------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean token cross-entropy with optional z-loss (router-style logit
    regularisation).  Written as reductions XLA SPMD partitions cleanly
    when the vocab dim is model-sharded."""
    logits = logits.astype(F32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
