"""Model zoo: all assigned architectures assembled from shared blocks."""
from .attention import KVCache, gqa_attention, mla_attention
from .layers import ParamBuilder, cross_entropy, rms_norm
from .lm import LM
from .moe import moe_ffn, router_topk
from .ssm import mamba_block, selective_scan_assoc, selective_scan_seq
from .xlstm import mlstm_block, slstm_block

__all__ = ["LM", "KVCache", "gqa_attention", "mla_attention",
           "ParamBuilder", "cross_entropy", "rms_norm", "moe_ffn",
           "router_topk", "mamba_block", "selective_scan_assoc",
           "selective_scan_seq", "mlstm_block", "slstm_block"]
