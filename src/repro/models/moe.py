"""Mixture-of-Experts: top-k router with z-loss + load-balance aux loss,
sort-based capacity dispatch (no (T,E,C) one-hot — it would be ~60TB at
deepseek-v3 scale), expert SwiGLU matmuls, weighted combine, and optional
shared experts (DeepSeek style).

Sharding: the dispatched buffer (E, C, D) carries the plan's
``moe_dispatched`` site — sharding E over the model axis gives expert
parallelism; XLA SPMD materialises the token exchange as collectives at
the scatter/gather boundaries.  (An explicit shard_map all_to_all variant
lives in ``repro.distributed.collectives`` for the §Perf iteration.)
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, MoEConfig
from .layers import BF16, F32, ParamBuilder

Constrain = Callable[..., jax.Array]


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array
    dropped_fraction: jax.Array


def init_moe(pb: ParamBuilder, path: str, cfg: ArchConfig,
             stack: int | None = None) -> None:
    moe = cfg.moe
    D, E, Fe = cfg.d_model, moe.n_experts, moe.d_expert
    pb.weight(f"{path}/w_router", (D, E), ("d_model", "experts"),
              dtype=F32, stack=stack)
    pb.weight(f"{path}/w_in", (E, D, 2, Fe),
              ("experts", "d_model", "two", "d_ff"), stack=stack)
    pb.weight(f"{path}/w_out", (E, Fe, D),
              ("experts", "d_ff", "d_model"), stack=stack)
    if moe.n_shared:
        Fs = moe.n_shared * Fe
        pb.weight(f"{path}/w_shared_in", (D, 2, Fs),
                  ("d_model", "two", "d_ff"), stack=stack)
        pb.weight(f"{path}/w_shared_out", (Fs, D), ("d_ff", "d_model"),
                  stack=stack)


def router_topk(x: jax.Array, w_router: jax.Array, moe: MoEConfig
                ) -> tuple[jax.Array, jax.Array, MoEAux]:
    """(T,D) → gates (T,K), expert ids (T,K), aux losses."""
    logits = (x.astype(F32) @ w_router).astype(F32)      # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, moe.top_k)
    gate = gate / jnp.clip(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + z-loss.
    E = w_router.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=F32), axis=1), axis=0)
    lb = E * jnp.sum(me * ce) / moe.top_k
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gate, idx, MoEAux(lb, z, jnp.zeros(()))


def dispatch_indices(idx: jax.Array, E: int, capacity: int
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-based slotting: for each (token, k) assignment return
    (expert_id, slot, keep) where slot < capacity or the token is dropped.

    Works on flattened (T*K,) expert ids; no (T,E,C) one-hot anywhere."""
    flat = idx.reshape(-1)                                # (T*K,)
    order = jnp.argsort(flat, stable=True)
    ranked = flat[order]
    # position within its expert group = global rank - group offset
    counts = jnp.bincount(flat, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos_sorted = jnp.arange(flat.shape[0]) - offsets[ranked]
    pos = jnp.zeros_like(flat).at[order].set(pos_sorted)
    keep = pos < capacity
    return flat, jnp.where(keep, pos, 0), keep


def _ambient_mesh():
    try:
        m = jax.sharding.get_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def moe_ffn_ep(x: jax.Array, p: dict, cfg: ArchConfig,
               batch_axes: tuple[str, ...],
               expert_axes: tuple[str, ...],
               seq_axes: tuple[str, ...] = (),
               mesh=None,
               tp_axis: str | None = None) -> tuple[jax.Array, MoEAux]:
    """Expert-parallel MoE via explicit shard_map + all_to_all.

    GSPMD cannot partition the scatter/gather dispatch of the global
    formulation without replicating the (E, C, D) buffers (measured:
    ~2.3 TiB/device on deepseek-v3 train_4k).  The production path is the
    classic EP exchange: tokens sharded (batch × seq), experts sharded
    over ``expert_axes``; each device slots its local tokens per target
    expert group, ``all_to_all`` ships payloads to the expert owners,
    expert FFNs run densely per local expert, and a second all_to_all
    ships results home.  Numerically identical to ``moe_ffn`` modulo
    capacity dropping locality (capacity is enforced per source shard).

    ``expert_axes`` may span several mesh axes (e.g. ('data','model') for
    deepseek-scale expert counts): expert weights then live *fully
    sharded by expert* and are never gathered — the FSDP-style
    weight all-gather that the layer scan hoists into a stacked
    ~1 TiB temp simply does not exist in this layout.

    ``tp_axis`` adds Megatron-style tensor parallelism *within* each
    expert (d_ff column/row split + psum) for expert counts that do not
    divide the full mesh (deepseek-v2: 160 experts = data(16) EP ×
    model(16) expert-TP).
    """
    import math
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    moe = cfg.moe
    mesh = mesh if mesh is not None else _ambient_mesh()
    if tp_axis is not None:
        # expert-TP columns all need the SAME tokens (each computes a
        # d_ff slice) — seq must be replicated over the tp axis.
        seq_axes = tuple(a for a in seq_axes if a != tp_axis)
    B, S, D = x.shape
    E, K, Fe = moe.n_experts, moe.top_k, moe.d_expert
    G = 1
    for a in expert_axes:
        G *= mesh.shape[a]
    E_loc = E // G
    ep_axis = tuple(expert_axes) if len(expert_axes) > 1 else expert_axes[0]

    def body(x_loc, w_router, w_in, w_out):
        Bl, Sl, _ = x_loc.shape
        T_loc = Bl * Sl
        xt = x_loc.reshape(T_loc, D)
        gate, idx, aux = router_topk(xt, w_router, moe)
        cap = max(1, math.ceil(T_loc * K * moe.capacity_factor / E))
        eid, slot, keep = dispatch_indices(idx, E, cap)
        src = jnp.repeat(xt, K, axis=0)
        payload = jnp.zeros((E, cap, D), x.dtype)
        payload = payload.at[eid, slot].set(
            jnp.where(keep[:, None], src, 0), mode="drop")
        # (E, cap, D) -> (G, E_loc, cap, D) -> exchange source<->group
        payload = payload.reshape(G, E_loc, cap, D)
        recv = jax.lax.all_to_all(payload, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        toks = recv.reshape(G, E_loc, cap, D).transpose(1, 0, 2, 3) \
            .reshape(E_loc, G * cap, D)
        h = jnp.einsum("ecd,edgf->ecgf", toks, w_in)
        act = jax.nn.silu(h[..., 0, :].astype(F32)).astype(x.dtype) \
            * h[..., 1, :]
        out = jnp.einsum("ecf,efd->ecd", act, w_out)
        if tp_axis is not None:
            # d_ff is column-split over tp_axis: w_in produced a local
            # hidden slice, w_out contracted it → partial sums.
            out = jax.lax.psum(out, tp_axis)
        back = out.reshape(E_loc, G, cap, D).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(back.reshape(G, E_loc, cap, D),
                                  ep_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        buf = back.reshape(E, cap, D)
        got = buf[eid, slot]
        got = jnp.where(keep[:, None], got, 0)
        got = got * gate.reshape(-1)[:, None].astype(x.dtype)
        y = got.reshape(T_loc, K, D).sum(axis=1).reshape(Bl, Sl, D)
        dropped = 1.0 - jnp.mean(keep.astype(F32))
        paxes = tuple(dict.fromkeys(tuple(batch_axes) + tuple(seq_axes)
                                    + tuple(expert_axes)))
        aux_out = MoEAux(
            jax.lax.pmean(aux.load_balance_loss, paxes),
            jax.lax.pmean(aux.router_z_loss, paxes),
            jax.lax.pmean(dropped, paxes))
        return y, aux_out

    bspec = tuple(batch_axes) if batch_axes else None
    sspec = tuple(seq_axes) if seq_axes else None
    espec = tuple(expert_axes) if len(expert_axes) > 1 else expert_axes[0]
    if tp_axis is None:
        w_in_spec, w_out_spec = P(espec), P(espec)
    else:
        # (E, D, 2, Fe) column-split on Fe; (E, Fe, D) row-split on Fe.
        w_in_spec = P(espec, None, None, tp_axis)
        w_out_spec = P(espec, tp_axis, None)
    in_specs = (P(bspec, sspec, None),            # x: batch × seq sharded
                P(None, None),                    # router replicated
                w_in_spec, w_out_spec)
    out_specs = (P(bspec, sspec, None), P())
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    y, aux = fn(x, p["w_router"], p["w_in"], p["w_out"])

    if moe.n_shared:
        hs = jnp.einsum("bsd,dgf->bsgf", x, p["w_shared_in"])
        acts = jax.nn.silu(hs[..., 0, :].astype(F32)).astype(x.dtype) \
            * hs[..., 1, :]
        y = y + jnp.einsum("bsf,fd->bsd", acts, p["w_shared_out"])
    return y, aux


def moe_ffn(x: jax.Array, p: dict, cfg: ArchConfig, constrain: Constrain,
            ep: tuple[tuple[str, ...], str] | None = None
            ) -> tuple[jax.Array, MoEAux]:
    """x (B,S,D) → (B,S,D) with capacity-factor dropping.  With ``ep``
    given as (batch_axes, expert_axes, seq_axes) and a live mesh whose
    expert axes span >1 device, dispatch goes through the explicit
    all_to_all path (``moe_ffn_ep``)."""
    moe = cfg.moe
    B, S, D = x.shape
    if ep is not None:
        batch_axes, expert_axes, seq_axes, mesh, tp_axis = ep
        if mesh is not None and expert_axes:
            G = 1
            for a in expert_axes:
                G *= mesh.shape.get(a, 0)
            sshard = 1
            for a in seq_axes:
                if a != tp_axis:
                    sshard *= mesh.shape.get(a, 1)
            bshard = 1
            for a in batch_axes:
                bshard *= mesh.shape.get(a, 1)
            tp_ok = (tp_axis is None
                     or moe.d_expert % mesh.shape.get(tp_axis, 1) == 0)
            if (G > 1 and moe.n_experts % G == 0 and S > 1 and tp_ok
                    and S % max(sshard, 1) == 0 and B % max(bshard, 1) == 0):
                return moe_ffn_ep(x, p, cfg, batch_axes, expert_axes,
                                  seq_axes, mesh, tp_axis=tp_axis)
    T = B * S
    E, K, Fe = moe.n_experts, moe.top_k, moe.d_expert
    # Capacity: cf-scaled mean load with a floor of 8 slots (decode batches
    # route few tokens — a floor of 1 would drop on any collision), capped
    # at T (an expert can receive each token at most once).
    import math
    capacity = min(T, max(math.ceil(T * K * moe.capacity_factor / E), 8))

    xt = x.reshape(T, D)
    gate, idx, aux = router_topk(xt, p["w_router"], moe)
    eid, slot, keep = dispatch_indices(idx, E, capacity)

    # Scatter token copies into the (E, C, D) dispatch buffer.
    src = jnp.repeat(xt, K, axis=0)                        # (T*K, D)
    disp = jnp.zeros((E, capacity, D), x.dtype)
    disp = disp.at[eid, slot].set(
        jnp.where(keep[:, None], src, 0), mode="drop")
    disp = constrain(disp, ("experts", "cap", "d_model"), "moe_dispatched")

    h = jnp.einsum("ecd,edgf->ecgf", disp, p["w_in"])
    act = jax.nn.silu(h[..., 0, :].astype(F32)).astype(x.dtype) \
        * h[..., 1, :]
    out_e = jnp.einsum("ecf,efd->ecd", act, p["w_out"])
    out_e = constrain(out_e, ("experts", "cap", "d_model"), "expert_out")

    # Gather back, weight by gate, sum over k.
    back = out_e[eid, slot]                                # (T*K, D)
    back = jnp.where(keep[:, None], back, 0)
    back = back * gate.reshape(-1)[:, None].astype(x.dtype)
    combined = back.reshape(T, K, D).sum(axis=1)

    if moe.n_shared:
        hs = jnp.einsum("td,dgf->tgf", xt, p["w_shared_in"])
        acts = jax.nn.silu(hs[..., 0, :].astype(F32)).astype(x.dtype) \
            * hs[..., 1, :]
        combined = combined + jnp.einsum("tf,fd->td", acts,
                                         p["w_shared_out"])

    dropped = 1.0 - jnp.mean(keep.astype(F32))
    aux = aux._replace(dropped_fraction=dropped)
    return combined.reshape(B, S, D), aux
